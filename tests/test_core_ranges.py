"""Range construction (paper §2.1, Fig. 2)."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    GB,
    MB,
    AddressSpace,
    pow2_floor,
    svm_alignment,
)


def test_pow2_floor():
    assert pow2_floor(1) == 1
    assert pow2_floor(2) == 2
    assert pow2_floor(3) == 2
    assert pow2_floor(1024) == 1024
    assert pow2_floor(1025) == 1024
    with pytest.raises(ValueError):
        pow2_floor(0)


def test_alignment_rule():
    # paper: 48 GB available => floor(48/32) GB = 1.5 GB -> pow2 floor = 1 GB
    assert svm_alignment(48 * GB) == 1 * GB
    assert svm_alignment(64 * GB) == 2 * GB
    assert svm_alignment(63 * GB) == 1 * GB
    # minimum 2 MB
    assert svm_alignment(1 * MB) == 2 * MB
    assert svm_alignment(32 * MB) == 2 * MB
    assert svm_alignment(128 * MB) == 4 * MB


def test_figure2_range_construction():
    """Paper Fig. 2: three 1.5 GB allocations on a 1 GB-aligned GPU produce
    7 ranges, smallest 175 MB, largest 1 GB (base offset 175 MB)."""
    space = AddressSpace(48 * GB, base=175 * MB)
    assert space.alignment == 1 * GB
    for i in range(3):
        space.alloc(int(1.5 * GB), f"m{i}")
    assert len(space.ranges) == 7
    sizes = sorted(r.size for r in space.ranges)
    assert sizes[0] == 175 * MB
    assert sizes[-1] == 1 * GB
    # ranges per allocation: 2 + 3 + 2
    per_alloc = [len(space.ranges_of(a)) for a in space.allocations]
    assert per_alloc == [2, 3, 2]


def test_ranges_tile_allocations_exactly():
    space = AddressSpace(48 * GB, base=175 * MB)
    a = space.alloc(int(2.5 * GB))
    rs = space.ranges_of(a)
    assert rs[0].start == a.start
    assert rs[-1].end == a.end
    for r1, r2 in zip(rs, rs[1:]):
        assert r1.end == r2.start


def test_range_at_lookup():
    space = AddressSpace(48 * GB, base=175 * MB)
    a = space.alloc(3 * GB)
    r = space.range_at(a.start)
    assert r.contains(a.start)
    r2 = space.range_at(a.end - 1)
    assert r2.contains(a.end - 1)
    with pytest.raises(KeyError):
        space.range_at(a.end + 10 * GB)


def test_ranges_overlapping():
    space = AddressSpace(48 * GB, base=175 * MB)
    a = space.alloc(3 * GB)
    rs = list(space.ranges_overlapping(a.start, a.end))
    assert rs == space.ranges_of(a)


@settings(max_examples=60, deadline=None)
@given(
    base=st.integers(min_value=0, max_value=2 * GB),
    sizes=st.lists(st.integers(min_value=4096, max_value=4 * GB),
                   min_size=1, max_size=6),
    cap_gb=st.integers(min_value=1, max_value=96),
)
def test_property_range_invariants(base, sizes, cap_gb):
    """Invariants for any allocation sequence:
    - ranges tile each allocation exactly (no gaps/overlap),
    - every range size <= alignment,
    - interior edges are alignment-aligned."""
    space = AddressSpace(cap_gb * GB, base=base)
    for s in sizes:
        space.alloc(s)
    for a in space.allocations:
        rs = space.ranges_of(a)
        assert rs[0].start == a.start and rs[-1].end == a.end
        for r1, r2 in zip(rs, rs[1:]):
            assert r1.end == r2.start
            assert r2.start % space.alignment == 0  # interior cut aligned
        for r in rs:
            assert 0 < r.size <= space.alignment
    # rids are dense and ordered by address
    for i, r in enumerate(space.ranges):
        assert r.rid == i
    starts = [r.start for r in space.ranges]
    assert starts == sorted(starts)
