"""Shared neural layers: norms, gated MLP, rotary embeddings, embedding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key, shape, scale: float = 0.02) -> Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale
            ).astype(jnp.bfloat16)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def activation(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind!r}")


# ----------------------------------------------------------------- gated MLP

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi_up": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
    }
    if gated:
        p["wi_gate"] = dense_init(k1, (d_model, d_ff))
    return p


def mlp_apply(p: dict, x: Array, act: str = "silu") -> Array:
    if "wi_gate" in p:
        h = activation(x @ p["wi_gate"], act) * (x @ p["wi_up"])
    else:
        h = activation(x @ p["wi_up"], act)
    return h @ p["wo"]


# -------------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, theta: float, rotary_dim: int) -> Array:
    """Inverse frequencies for the rotated sub-dimension."""
    half = rotary_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float,
               partial: float = 1.0) -> Array:
    """Rotary position embedding.

    x: (..., S, H, D); positions: broadcastable to (..., S) absolute indices.
    `partial` < 1 rotates only the leading fraction of D (ChatGLM-style
    2D/partial rotary).
    """
    d = x.shape[-1]
    rot = int(d * partial)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_frequencies(d, theta, rot)                     # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, r/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate(
        [y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


# ----------------------------------------------------------------- embedding

def embed_init(key, vocab: int, d_model: int) -> Array:
    return dense_init(key, (vocab, d_model))


def embed_apply(table: Array, ids: Array, scale: bool, d_model: int) -> Array:
    x = jnp.take(table, ids, axis=0)
    if scale:
        x = x * jnp.asarray(d_model ** 0.5, dtype=x.dtype)
    return x
