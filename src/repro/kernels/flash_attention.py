"""Causal flash-attention forward Pallas kernel (32k-prefill hot spot).

Grid (batch*heads, q_blocks, kv_blocks); kv innermost so the running
(max, denom, accumulator) state lives in VMEM scratch across kv steps —
the S×T score matrix never touches HBM. Causal masking is positional via
iota; fully-masked kv blocks still execute (static grid) but contribute
zeros, matching the XLA-blockwise reference semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ, BKV = 256, 256
NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nkv: int, bq: int, bkv: int, scale: float,
                  causal: bool, T: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    kpos = t * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < T                                   # OOB kv padding
    if causal:
        i = pl.program_id(1)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    # zero-fill padded v rows: OOB VMEM rows are unspecified (NaN in
    # interpret mode) and even a p==0 coefficient would poison the dot
    # (0 * NaN = NaN in the MXU accumulation)
    vrow = t * bkv + jax.lax.broadcasted_iota(jnp.int32, v_ref[0].shape, 0)
    v_clean = jnp.where(vrow < T, v_ref[0], 0).astype(v_ref.dtype)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v_clean.dtype), v_clean,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(t == nkv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           interpret: bool = False) -> jax.Array:
    """q,k,v: (B,H,S,D) (pre-repeated KV heads; D a 128-multiple ideally)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    bq, bkv = min(BQ, S), min(BKV, T)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, D)
    grid = (B * H, pl.cdiv(S, bq), pl.cdiv(T, bkv))
    kern = functools.partial(
        _flash_kernel, nkv=grid[2], bq=bq, bkv=bkv,
        scale=D ** -0.5, causal=causal, T=T)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, t: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, t: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
