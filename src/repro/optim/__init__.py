from repro.optim.adamw import (
    OptConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)

__all__ = [
    "OptConfig", "make_optimizer", "adamw_init", "adamw_update",
    "adafactor_init", "adafactor_update", "clip_by_global_norm",
    "cosine_schedule",
]
