"""Streaming-executor fetch path: push-based eviction (O(1) in leaf count),
the hidden-overlap ledger (monotonic event clocks), and the public
previct/spill API."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import GB, MB, AddressSpace, SVMManager
from repro.svm import StreamingExecutor
from repro.svm.executor import run_layer_stream


def _params(n_layers, d=64):
    key = jax.random.PRNGKey(0)
    return {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (d, d),
                                       jnp.float32)
            for i in range(n_layers)}


def _mk(n_layers, budget_frac, **kw):
    params = _params(n_layers)
    total = n_layers * 64 * 64 * 4
    return StreamingExecutor(params, int(total * budget_frac), **kw)


# ------------------------------------------------- O(1) fetch scan work

def test_fetch_work_independent_of_leaf_count():
    """A warm fetch costs exactly the fetched leaf's range count — not a
    rescan over every leaf in the model."""
    deltas = {}
    for n_layers in (8, 32):
        ex = _mk(n_layers, budget_frac=2.0)
        for p in ex.plan.leaf_ranges:
            ex.fetch(p)                       # warm: everything resident
        w0 = ex.fetch_scan_work
        ex.fetch("l3")
        deltas[n_layers] = ex.fetch_scan_work - w0
        assert deltas[n_layers] == len(ex.plan.leaf_ranges["l3"])
    assert deltas[8] == deltas[32]


def test_fetch_work_bounded_by_ranges_plus_evictions():
    """Under thrash, total invalidation work is range touches plus leaves
    actually dropped — bounded by evictions, not fetches × leaves."""
    n_layers, steps = 16, 4
    ex = _mk(n_layers, budget_frac=0.5)
    paths = list(ex.plan.leaf_ranges)
    n_fetches = 0
    for _ in range(steps):
        for p in paths:
            ex.fetch(p)
            n_fetches += 1
    ranges_touched = sum(len(ex.plan.leaf_ranges[p]) for p in paths) * steps
    drops = ex.fetch_scan_work - ranges_touched
    assert ex.mgr.n_evictions > 0
    assert 0 < drops <= ex.mgr.n_evictions
    # the old implementation's cost shape, for contrast:
    assert ex.fetch_scan_work < n_fetches * sum(
        len(r) for r in ex.plan.leaf_ranges.values())


def test_device_pool_invariant_under_eviction():
    """Push-based invalidation keeps the pool exact: a tensor is cached
    iff all its ranges are resident (brute-force cross-check)."""
    ex = _mk(12, budget_frac=0.6)
    paths = list(ex.plan.leaf_ranges)
    for _ in range(3):
        for p in paths:
            ex.fetch(p)
            for cached, rids in ex.plan.leaf_ranges.items():
                if cached in ex._device:
                    assert all(r in ex.mgr.resident for r in rids)


def test_leaf_larger_than_pool_self_evicts_but_returns_tensor():
    """A multi-range leaf that cannot fully fit evicts its own earlier
    ranges mid-fetch: the tensor is still returned (math must proceed)
    but it must not stay cached while partially non-resident."""
    key = jax.random.PRNGKey(1)
    params = {"big": jax.random.normal(key, (1254, 1254), jnp.float32)}
    ex = StreamingExecutor(params, 4 * MB)
    assert len(ex.plan.leaf_ranges["big"]) >= 2
    t = ex.fetch("big")
    assert t.shape == (1254, 1254)
    assert ex.mgr.n_evictions > 0
    rids = ex.plan.leaf_ranges["big"]
    if not all(r in ex.mgr.resident for r in rids):
        assert "big" not in ex._device


def test_eviction_listener_and_epoch():
    fired = []
    ex = _mk(12, budget_frac=0.5)
    ex.mgr.add_evict_listener(fired.append)
    for p in list(ex.plan.leaf_ranges):
        ex.fetch(p)
    assert ex.mgr.n_evictions > 0
    assert len(fired) == ex.mgr.n_evictions == ex.mgr.eviction_epoch
    assert all(isinstance(r, int) for r in fired)


# ------------------------------------------- hidden-overlap ledger (§4.2)

def _stream(n_layers=8, budget_frac=0.6, steps=4, prefetch=False):
    ex = _mk(n_layers, budget_frac, prefetch=prefetch)
    paths = [[p] for p in ex.plan.leaf_ranges]

    def apply_layer(i, tensors):
        return 2.0 * 64 * 64

    m = run_layer_stream(ex, paths, apply_layer, steps=steps)
    return ex, m


def test_prefetch_keeps_event_clock_monotonic():
    """Hidden overlap is ledgered, never a wall rewind: recorded event
    timestamps are non-decreasing even with prefetch on."""
    ex, m = _stream(prefetch=True)
    ts = [e.t for e in ex.mgr.events]
    assert ts == sorted(ts)
    assert ex.overlap_hidden_s > 0.0
    assert m["wall_s"] == ex.mgr.wall - ex.overlap_hidden_s
    assert m["overlap_hidden_s"] == ex.overlap_hidden_s


def test_prefetch_still_reduces_reported_wall():
    _, base = _stream(prefetch=False)
    _, pre = _stream(prefetch=True)
    assert pre["migrations"] == base["migrations"]
    assert pre["wall_s"] < base["wall_s"]


# --------------------------------------------------- previct / spill API

def _space(n=8, rng_mb=2):
    s = AddressSpace(n * rng_mb * MB // 2, base=0, alignment=rng_mb * MB)
    for i in range(n):
        s.alloc(rng_mb * MB, f"a{i}")
    return s


def test_previct_public_api():
    space = _space()
    mgr = SVMManager(space, profile=True)
    mgr.touch(0, concurrency=1)
    mgr.touch(1, concurrency=1)
    w0 = mgr.wall
    cost = mgr.previct(0, overlap=0.5)
    assert cost > 0.0
    assert 0 not in mgr.resident
    assert mgr.n_evictions == 1
    # half the eviction cost hidden off the critical path
    assert mgr.wall == pytest.approx(w0 + 0.5 * cost)
    # non-resident and pinned ranges are not evictable
    assert mgr.previct(0) == 0.0
    mgr.pin(1)
    assert mgr.previct(1) == 0.0
    assert 1 in mgr.resident


def test_spill_oldest_follows_policy_order():
    space = _space()
    mgr = SVMManager(space)
    for rid in (2, 0, 1):
        mgr.touch(rid, concurrency=1)
    assert mgr.spill_oldest() == 2        # LRF: first-faulted first
    assert mgr.spill_oldest() == 0
    assert mgr.spill_oldest() == 1
    assert mgr.spill_oldest() is None     # nothing evictable left
