"""falcon-mamba-7b: 64L d_model=4096, attention-free mamba1 blocks (no FFN),
ssm_state=16, vocab=65024 [arXiv:2410.05355; unverified]."""

import dataclasses

from repro.models.config import MAMBA, NONE, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    vocab=65024,
    d_model=4096,
    n_layers=64,
    d_ff=0,
    n_heads=0,
    n_kv_heads=0,
    layer_pattern=(MAMBA,),
    ffn_pattern=(NONE,),
    ssm_state=16,
    ssm_expand=2,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, ssm_state=4)
