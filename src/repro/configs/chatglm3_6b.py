"""chatglm3-6b: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 —
partial (2d) rotary on half the head dims [arXiv:2406.12793; hf]."""

import dataclasses

from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    vocab=65024,
    d_model=4096,
    n_layers=28,
    d_ff=13696,
    n_heads=32,
    n_kv_heads=2,
    layer_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    partial_rotary=0.5,
    rope_theta=10_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=128,
        n_heads=4, n_kv_heads=2)
