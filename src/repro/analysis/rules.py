"""svmlint rules: the engine's equivalence contracts as AST checks.

Each rule enforces one invariant from ``docs/contracts.md``:

  * ``opcode-exhaustive``     — every interpreter dispatch site handles
    or explicitly rejects every ``OP_*`` tag (universe derived from
    ``repro/core/engine.py`` itself, so adding e.g. ``OP_KV_GROW`` flags
    every stale dispatch chain until it is taught the new op).
  * ``frozen-mutation``       — compiled-trace op columns are immutable
    after `CompiledTrace.freeze`; no subscript store, in-place NumPy
    mutation, or writeable-flag flip outside the freeze path.
  * ``manager-encapsulation`` — runtime-layer modules (``repro.svm``,
    ``repro.launch``) never drive a manager op-by-op or reach into its
    privates; every access is a recorded op replayed through
    `TraceSession`.
  * ``determinism``           — no unseeded RNG, no salted ``hash()``
    feeding a seed, no wall-clock reads in the simulation layers, no
    direct set-order iteration.
  * ``counter-pairing``       — attribution code reads manager counters
    as before/after snapshot *pairs* around a replay; an unpaired read
    breaks per-request conservation against the shared manager.
  * ``bounded-retry``         — a loop that catches an exception and
    re-invokes the same work must reference a bounded attempt budget
    (`repro.ft.retry`); open-ended recovery loops never terminate under
    a persistent fault.
"""

from __future__ import annotations

import ast
import functools
import os
import re

from repro.analysis.core import (
    Finding,
    LintModule,
    Rule,
    attr_chain,
    register_rule,
    walk_functions,
)

_ENGINE_PY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "core", "engine.py")


@functools.lru_cache(maxsize=1)
def opcode_universe() -> tuple[frozenset[str], frozenset[str]]:
    """(opcode constant names, trace-op tag strings) parsed from the live
    ``repro/core/engine.py`` — module-level ``OP_* = int`` assignments
    plus the ``OP_TAGS`` table (and the lowering-only ``"kernel"``
    marker).  Parsing the source instead of importing keeps svmlint
    fully static and means a newly added opcode widens the universe
    the moment it is defined."""
    with open(_ENGINE_PY, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=_ENGINE_PY)
    ops: set[str] = set()
    tags: set[str] = {"kernel"}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            if tgt.id.startswith("OP_") and tgt.id != "OP_TAGS" and \
                    isinstance(node.value, ast.Constant) and \
                    isinstance(node.value.value, int):
                ops.add(tgt.id)
            elif tgt.id == "OP_TAGS" and isinstance(node.value, ast.Dict):
                tags.update(k.value for k in node.value.keys
                            if isinstance(k, ast.Constant)
                            and isinstance(k.value, str))
    return frozenset(ops), frozenset(tags)


# ------------------------------------------------------ opcode-exhaustive

def _chain_constants(test: ast.expr, ops: frozenset[str],
                     tags: frozenset[str]) -> tuple[set[str], set[str]]:
    """Opcode names / tag strings an if-branch test compares against."""
    found_ops: set[str] = set()
    found_tags: set[str] = set()

    def scan(expr: ast.expr) -> None:
        if isinstance(expr, ast.BoolOp):
            for v in expr.values:
                scan(v)
            return
        if not isinstance(expr, ast.Compare):
            return
        if not all(isinstance(op, (ast.Eq, ast.In)) for op in expr.ops):
            return
        for comp in expr.comparators:
            items = comp.elts if isinstance(comp,
                                            (ast.Tuple, ast.Set,
                                             ast.List)) else [comp]
            for item in items:
                if isinstance(item, ast.Name) and item.id in ops:
                    found_ops.add(item.id)
                elif isinstance(item, ast.Constant) and item.value in tags:
                    found_tags.add(item.value)

    scan(test)
    return found_ops, found_tags


def _has_rejection(stmts: list[ast.stmt]) -> bool:
    """Does a final else-branch reject (raise) or delegate (call another
    dispatcher) the remaining opcodes?"""
    for stmt in stmts:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Raise, ast.Call)):
                return True
    return False


@register_rule
class OpcodeExhaustive(Rule):
    name = "opcode-exhaustive"
    doc = ("interpreter dispatch sites must handle or explicitly reject "
           "every OP_* opcode / trace-op tag")
    invariant = ("adding a new op to repro/core/engine.py cannot "
                 "silently fall through any dispatch chain")

    def check(self, mod: LintModule):
        ops, tags = opcode_universe()
        seen: set[int] = set()        # elif-members already consumed
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.If) or id(node) in seen:
                continue
            # collect the full if/elif chain
            chain: list[ast.If] = [node]
            while len(chain[-1].orelse) == 1 and \
                    isinstance(chain[-1].orelse[0], ast.If):
                chain.append(chain[-1].orelse[0])
            for member in chain[1:]:
                seen.add(id(member))
            got_ops: set[str] = set()
            got_tags: set[str] = set()
            for member in chain:
                o, t = _chain_constants(member.test, ops, tags)
                got_ops |= o
                got_tags |= t
            # a dispatch site compares >= 2 universe members
            if len(got_ops) + len(got_tags) < 2:
                continue
            missing = sorted(ops - got_ops) if got_ops \
                else sorted(tags - got_tags)
            if not missing:
                continue
            orelse = chain[-1].orelse
            if orelse and _has_rejection(orelse):
                continue
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                f"opcode dispatch does not handle {', '.join(missing)} "
                "and has no rejecting/delegating else branch — a new op "
                "would silently fall through")


# -------------------------------------------------------- frozen-mutation

#: CompiledTrace op-column fields (everything `freeze` marks read-only)
COLUMN_FIELDS = ("codes", "rids", "concs", "hints", "fargs", "boundaries",
                 "touch_pos_np", "touch_rid_np", "seg_bounds")

_INPLACE_METHODS = frozenset({"fill", "sort", "put", "partition",
                              "resize", "itemset", "byteswap"})

#: qualnames allowed to flip writeable flags (the freeze path itself)
_FREEZE_QUALNAMES = frozenset({"CompiledTrace.freeze"})


@register_rule
class FrozenMutation(Rule):
    name = "frozen-mutation"
    doc = ("no subscript store / in-place NumPy mutation / writeable-flag "
           "flip on CompiledTrace op columns outside the freeze path")
    invariant = ("frozen trace columns are shared across sweep points, "
                 "sessions, and relocated SegmentCache copies — one "
                 "in-place write corrupts every sharer")

    def check(self, mod: LintModule):
        cols = frozenset(COLUMN_FIELDS)
        qual_of: dict[int, str] = {}
        for fn, q in walk_functions(mod.tree):
            for n in ast.walk(fn):
                qual_of.setdefault(id(n), q)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    yield from self._check_store(mod, node, tgt, cols,
                                                 qual_of)
            elif isinstance(node, ast.Call):
                yield from self._check_call(mod, node, cols)

    def _check_store(self, mod, node, tgt, cols, qual_of):
        # ct.codes[i] = ... / ct.rids[m] += ... — in-place column write
        if isinstance(tgt, ast.Subscript) and \
                isinstance(tgt.value, ast.Attribute) and \
                tgt.value.attr in cols:
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                f"subscript store into compiled-trace column "
                f"'.{tgt.value.attr}' — frozen columns are shared; "
                "build new arrays and dataclasses.replace instead")
        # ct.codes = ... rebinding on a foreign object (self.<col> = ...
        # in a builder's __init__ stays legal)
        elif isinstance(tgt, ast.Attribute) and tgt.attr in cols and \
                not (isinstance(tgt.value, ast.Name)
                     and tgt.value.id == "self"):
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                f"rebinding compiled-trace column '.{tgt.attr}' on a "
                "shared trace — use CompiledTrace.relocate/copy/"
                "dataclasses.replace")
        # *.flags.writeable = ... anywhere outside CompiledTrace.freeze
        elif isinstance(tgt, ast.Attribute) and tgt.attr == "writeable" \
                and isinstance(tgt.value, ast.Attribute) \
                and tgt.value.attr == "flags":
            qual = qual_of.get(id(node), "")
            if qual not in _FREEZE_QUALNAMES:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    "writeable-flag flip outside CompiledTrace.freeze — "
                    "un-freezing shared columns breaks the immutability "
                    "contract")

    def _check_call(self, mod, node, cols):
        fn = node.func
        # ct.codes.sort() and friends
        if isinstance(fn, ast.Attribute) and \
                fn.attr in _INPLACE_METHODS and \
                isinstance(fn.value, ast.Attribute) and \
                fn.value.attr in cols:
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                f"in-place '.{fn.attr}()' on compiled-trace column "
                f"'.{fn.value.attr}'")
        # np.foo(..., out=ct.codes)
        for kw in node.keywords:
            if kw.arg == "out" and isinstance(kw.value, ast.Attribute) \
                    and kw.value.attr in cols:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"NumPy out= targets compiled-trace column "
                    f"'.{kw.value.attr}'")


# -------------------------------------------------- manager-encapsulation

#: op-driving entry points the runtime layer must reach via TraceSession
MANAGER_DRIVE = frozenset({"touch", "advance", "pin", "unpin",
                           "writeback", "spill_oldest", "previct"})

_MANAGER_NAMES = frozenset({"mgr", "manager"})
_MANAGER_CTORS = frozenset({"SVMManager", "UVMManager"})


def _manager_aliases(scope_body: list[ast.stmt]) -> set[str]:
    """Local names bound to a manager: ``m = self.mgr``,
    ``mgr = SVMManager(...)``, ``m = plan.manager(...)``."""
    aliases = set(_MANAGER_NAMES)
    for stmt in scope_body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Assign):
                continue
            src = node.value
            is_mgr = False
            if isinstance(src, (ast.Name, ast.Attribute)):
                chain = attr_chain(src)
                is_mgr = chain is not None and \
                    chain.split(".")[-1] in _MANAGER_NAMES
            elif isinstance(src, ast.Call):
                f = src.func
                if isinstance(f, ast.Name):
                    is_mgr = f.id in _MANAGER_CTORS
                elif isinstance(f, ast.Attribute):
                    is_mgr = f.attr in _MANAGER_CTORS or \
                        f.attr == "manager"
            if is_mgr:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        aliases.add(tgt.id)
    return aliases


def _is_manager_recv(recv: ast.expr, aliases: set[str]) -> bool:
    chain = attr_chain(recv)
    return chain is not None and chain.split(".")[-1] in aliases


@register_rule
class ManagerEncapsulation(Rule):
    name = "manager-encapsulation"
    doc = ("repro.svm / repro.launch never drive a manager op-by-op or "
           "touch its private members; ops go through TraceSession")
    invariant = ("every runtime-layer manager access is a recorded op "
                 "replayed on the engine, so scalar and batched tiers "
                 "see the identical op stream")
    scope = ("repro.svm", "repro.launch")

    def check(self, mod: LintModule):
        scopes = [(mod.tree, mod.tree.body)]
        scopes += [(fn, fn.body) for fn, _ in walk_functions(mod.tree)]
        checked: set[int] = set()
        for scope_node, body in scopes:
            aliases = _manager_aliases(body)
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) and \
                            node is not scope_node:
                        break     # inner scopes handled on their own pass
                    if id(node) in checked:
                        continue
                    if isinstance(node, ast.Call) and \
                            isinstance(node.func, ast.Attribute) and \
                            node.func.attr in MANAGER_DRIVE and \
                            _is_manager_recv(node.func.value, aliases):
                        checked.add(id(node))
                        yield Finding(
                            self.name, mod.path, node.lineno,
                            node.col_offset,
                            f"direct manager drive "
                            f"'.{node.func.attr}()' — record the op on a "
                            "TraceSession and replay it instead")
                    elif isinstance(node, ast.Attribute) and \
                            node.attr.startswith("_") and \
                            not node.attr.startswith("__") and \
                            _is_manager_recv(node.value, aliases):
                        checked.add(id(node))
                        yield Finding(
                            self.name, mod.path, node.lineno,
                            node.col_offset,
                            f"private manager member '.{node.attr}' "
                            "accessed from the runtime layer")


# ------------------------------------------------------------ determinism

_WALLCLOCK = {
    "time": {"time", "time_ns", "monotonic", "monotonic_ns",
             "perf_counter", "perf_counter_ns", "process_time"},
    "datetime": {"now", "utcnow", "today"},
}
_SEEDED_CTORS = frozenset({"default_rng", "SeedSequence", "Generator",
                           "Random"})
_NP_RANDOM_OK = _SEEDED_CTORS | frozenset({"PCG64", "Philox", "SFC64",
                                           "MT19937", "BitGenerator"})


def _is_set_expr(expr: ast.expr) -> bool:
    if isinstance(expr, ast.Set):
        return True
    return (isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name)
            and expr.func.id in ("set", "frozenset"))


@register_rule
class Determinism(Rule):
    name = "determinism"
    doc = ("no unseeded RNG, no salted hash() feeding a seed, no "
           "wall-clock reads in repro.core/repro.svm, no direct "
           "set-order iteration")
    invariant = ("same inputs + same seed => byte-identical traces, "
                 "sweep keys, and schedules, across processes and runs")

    #: wall-clock reads are only forbidden in the simulation layers;
    #: launch/ft measure real host time legitimately
    WALLCLOCK_SCOPE = ("repro.core", "repro.svm", "repro.analysis")

    def check(self, mod: LintModule):
        clock_scoped = any(
            mod.package == s or mod.package.startswith(s + ".")
            for s in self.WALLCLOCK_SCOPE)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(mod, node, clock_scoped)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if _is_set_expr(it):
                    line = getattr(node, "lineno", it.lineno)
                    col = getattr(node, "col_offset", it.col_offset)
                    yield Finding(
                        self.name, mod.path, line, col,
                        "iteration over a set expression — order is "
                        "value-dependent; sort it before it can feed "
                        "trace emission or sweep keys")

    def _check_call(self, mod, node, clock_scoped):
        chain = attr_chain(node.func)
        if chain is None:
            # list(set(...)) / tuple(set(...)) materialise set order
            if isinstance(node.func, ast.Name) and \
                    node.func.id in ("list", "tuple", "sorted"):
                pass
            return
        parts = chain.split(".")
        # np.random.* — legacy global-state samplers are unseedable per
        # call; default_rng()/SeedSequence() need an explicit seed
        if len(parts) >= 2 and parts[-2] == "random":
            fn = parts[-1]
            if parts[0] in ("np", "numpy"):
                if fn not in _NP_RANDOM_OK:
                    yield Finding(
                        self.name, mod.path, node.lineno, node.col_offset,
                        f"global-state RNG 'np.random.{fn}' — use an "
                        "explicitly seeded np.random.default_rng(seed)")
                elif fn in ("default_rng", "SeedSequence") and \
                        not node.args and not node.keywords:
                    yield Finding(
                        self.name, mod.path, node.lineno, node.col_offset,
                        f"unseeded 'np.random.{fn}()' — pass an explicit "
                        "seed")
        elif parts[0] == "random" and len(parts) == 2:
            fn = parts[-1]
            if fn != "Random":
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"global-state RNG 'random.{fn}' — use a seeded "
                    "random.Random(seed) instance")
            elif not node.args and not node.keywords:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    "unseeded 'random.Random()' — pass an explicit seed")
        # wall-clock reads (simulation layers only)
        elif clock_scoped and len(parts) >= 2 and \
                parts[-1] in _WALLCLOCK.get(parts[-2], ()):
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                f"wall-clock read '{chain}()' in a simulation module — "
                "the simulated clock is the manager's wall")
        # hash() inside a seed expression: str hashes are salted per
        # process (PYTHONHASHSEED), so the 'seed' differs across runs
        if parts[-1] in _SEEDED_CTORS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Name) and \
                            sub.func.id == "hash":
                        yield Finding(
                            self.name, mod.path, sub.lineno,
                            sub.col_offset,
                            "salted builtin hash() feeds an RNG seed — "
                            "str hashes differ across processes; use a "
                            "stable digest (e.g. zlib.crc32)")


# -------------------------------------------------------- counter-pairing

#: manager counters used for per-request attribution
ATTRIBUTION_COUNTERS = frozenset({"wall", "n_migrations", "n_evictions",
                                  "bytes_migrated", "bytes_evicted"})

_REPLAY_ATTRS = frozenset({"replay", "replay_scalar", "run", "flush",
                           "decode_step", "decode_steps"})
_REPLAY_FUNCS = frozenset({"execute_compiled", "execute_fused",
                           "apply_trace"})


@register_rule
class CounterPairing(Rule):
    name = "counter-pairing"
    doc = ("attribution code must read manager counters as before/after "
           "pairs around a replay — unpaired reads break conservation")
    invariant = ("per-request counter deltas sum exactly to the shared "
                 "manager's aggregates")
    scope = ("repro.svm", "repro.launch")

    def check(self, mod: LintModule):
        for fn, qual in walk_functions(mod.tree):
            yield from self._check_fn(mod, fn, qual)

    def _check_fn(self, mod, fn, qual):
        aliases = _manager_aliases(fn.body)
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs
                  + fn.args.posonlyargs}
        replay_lines: list[int] = []
        fused_result = False
        reads: dict[str, list[tuple[int, int, int]]] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            if isinstance(node, ast.Call):
                f = node.func
                name = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else None)
                if name in _REPLAY_FUNCS or \
                        (isinstance(f, ast.Attribute)
                         and f.attr in _REPLAY_ATTRS) or \
                        (isinstance(f, ast.Name) and f.id in params):
                    replay_lines.append(node.lineno)
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                f = node.value.func
                name = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else None)
                if name == "execute_fused":
                    # the returned cut snapshots ARE the after-reads
                    fused_result = True
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load) and \
                    node.attr in ATTRIBUTION_COUNTERS and \
                    _is_manager_recv(node.value, aliases):
                reads.setdefault(node.attr, []).append(
                    (node.lineno, node.col_offset, node.lineno))
        if not replay_lines or not reads:
            return
        first, last = min(replay_lines), max(replay_lines)
        for counter, sites in sorted(reads.items()):
            before = any(line <= first for line, _, _ in sites)
            after = any(line >= last for line, _, _ in sites) \
                or fused_result
            if before and after:
                continue
            line, col, _ = sites[0]
            side = "after" if before else "before"
            yield Finding(
                self.name, mod.path, line, col,
                f"manager counter '{counter}' read on one side of a "
                f"replay only (missing the {side}-snapshot) — unpaired "
                "reads mis-attribute shared-pool costs")


# ---------------------------------------------------------- bounded-retry

#: identifier fragments that mark an explicit attempt budget
_BUDGET_NAME = re.compile(
    r"(max_)?(attempts?|restarts?|retr(y|ies)|budget|patience)",
    re.IGNORECASE)


def _walk_same_scope(node: ast.AST):
    """`ast.walk` that does not descend into nested function/class
    definitions — their loops and handlers are their own scope."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


def _handler_swallows(handler: ast.ExceptHandler) -> bool:
    """A handler that neither re-raises nor exits (break/return) swallows
    the failure, so the enclosing loop re-invokes the same work."""
    for n in _walk_same_scope(handler):
        if isinstance(n, (ast.Raise, ast.Break, ast.Return)):
            return False
    return True


@register_rule
class BoundedRetry(Rule):
    name = "bounded-retry"
    doc = ("a while-loop that catches an exception and retries the same "
           "work must reference a bounded attempt budget "
           "(repro.ft.retry)")
    invariant = ("every recovery loop terminates under a persistent "
                 "fault: retries are spent against an explicit budget, "
                 "never open-ended")

    def check(self, mod: LintModule):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.While):
                continue
            body = list(_walk_same_scope(node))
            handlers = [h for n in body if isinstance(n, ast.Try)
                        for h in n.handlers]
            if not handlers or \
                    not any(_handler_swallows(h) for h in handlers):
                continue
            names: set[str] = set()
            for n in body:
                if isinstance(n, ast.Name):
                    names.add(n.id)
                elif isinstance(n, ast.Attribute):
                    names.add(n.attr)
            if any(_BUDGET_NAME.search(x) for x in names):
                continue
            yield Finding(
                self.name, mod.path, node.lineno, node.col_offset,
                "retry loop swallows exceptions with no bounded attempt "
                "budget in scope — use repro.ft.retry (retry_call / "
                "RetryBudget) or reference an explicit attempt counter")


# --------------------------------------------------------------- hot-loop

#: per-op column identifiers (flat op columns and their derived
#: touch-stream views) — the arrays whose length scales with trace ops
_OP_COLUMN_NAMES = frozenset({
    "codes", "rids", "concs", "hints", "fargs",
    "tpos", "trid", "tpos_np", "trid_np",
    "touch_pos", "touch_rid", "touch_pos_np", "touch_rid_np",
})


def _op_columns_iterated(it: ast.expr) -> set[str]:
    """Op-column names a for-loop's iterable walks per element.

    Sees through ``enumerate``/``zip``/``reversed`` wrappers and
    ``.tolist()`` — but deliberately not ``range(...)``: an index loop's
    body is usually O(1) per *miss or victim*, not per op, and the
    sequential reference paths that do scale per op iterate the column
    itself."""
    out: set[str] = set()

    def scan(expr: ast.expr) -> None:
        if isinstance(expr, ast.Call):
            f = expr.func
            fname = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None)
            if fname in ("enumerate", "zip", "reversed"):
                for a in expr.args:
                    scan(a)
            elif fname == "tolist" and isinstance(f, ast.Attribute):
                scan(f.value)
            return
        chain = attr_chain(expr)
        if chain is not None:
            tail = chain.rsplit(".", 1)[-1]
            if tail in _OP_COLUMN_NAMES:
                out.add(tail)

    scan(it)
    return out


@register_rule
class HotLoop(Rule):
    name = "hot-loop"
    doc = ("engine execute/fold functions must not iterate op-column "
           "arrays in per-op Python for loops")
    invariant = ("engine hot paths are single-pass NumPy column "
                 "operations (cumsum/searchsorted/reduceat-free ordinal "
                 "sweeps); a Python for loop over an op column scales "
                 "wall time with trace length, which the fused tiers "
                 "exist to avoid — sequential reference paths live in "
                 "dedicated `_phase_a_*` oracles, not execute/fold "
                 "functions")
    scope = ("repro.core",)

    def check(self, mod: LintModule):
        if os.path.basename(mod.path) != "engine.py":
            return
        for fn, qualname in walk_functions(mod.tree):
            leaf = qualname.rsplit(".", 1)[-1]
            if "execute" not in leaf and "fold" not in leaf:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.For):
                    continue
                cols = _op_columns_iterated(node.iter)
                if cols:
                    yield Finding(
                        self.name, mod.path, node.lineno,
                        node.col_offset,
                        f"per-op Python loop over op column(s) "
                        f"{', '.join(sorted(cols))} in hot function "
                        f"{qualname!r} — vectorise (column ops / "
                        f"cumsum folds) or move the sequential walk to "
                        f"a reference oracle")
