"""Mamba-1 selective SSM block (falcon-mamba / jamba hybrid layers).

Training/prefill uses a chunked associative scan: the sequence is split into
chunks of CHUNK tokens; within a chunk the linear recurrence
    h_t = a_t * h_{t-1} + b_t,   a_t = exp(dt_t * A),  b_t = (dt_t x_t) B_t
is solved with `jax.lax.associative_scan` (elementwise over (d_inner, N) —
materialises only (B, CHUNK, d_inner, N) transients, which shard over the
`model` axis via d_inner), and the carry h crosses chunks through a
`jax.lax.scan`. Decode is a single recurrence step with a conv ring buffer.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array

CHUNK = 128


def mamba_init(key, cfg: ModelConfig) -> dict:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, conv = cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (conv, di), scale=0.1),
        "conv_b": jnp.zeros((di,), dtype=jnp.bfloat16),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ns)),
        "dt_proj": dense_init(ks[3], (dtr, di), scale=dtr ** -0.5),
        "dt_bias": jnp.full((di,), -4.6, dtype=jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, ns + 1, dtype=jnp.float32)), (di, ns)
        ).copy(),
        "D": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. x: (B,S,di), w: (K,di)."""
    K = w.shape[0]
    y = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[K - 1 - i]
    return y + b


def _ssm_inputs(p: dict, cfg: ModelConfig, xc: Array):
    """Project conv output to (dt, B, C) selective parameters."""
    ns, dtr = cfg.ssm_state, cfg.resolved_dt_rank
    proj = xc @ p["x_proj"]                                   # (B,S,dtr+2N)
    dt_r = proj[..., :dtr]
    B_ssm = proj[..., dtr: dtr + ns].astype(jnp.float32)      # (B,S,N)
    C_ssm = proj[..., dtr + ns:].astype(jnp.float32)          # (B,S,N)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])  # (B,S,di)
    return dt, B_ssm, C_ssm


def _scan_chunk(a: Array, b: Array, h0: Array):
    """Associative scan of h_t = a_t h_{t-1} + b_t within one chunk.
    a,b: (B,L,di,N) fp32; h0: (B,di,N). Returns (h_all (B,L,di,N), h_last)."""

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    a_star, b_star = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = a_star * h0[:, None] + b_star
    return h_all, h_all[:, -1]


def mamba_forward(p: dict, cfg: ModelConfig, x: Array,
                  return_state: bool = False):
    """Full-sequence (train / prefill) pass. x: (B,S,d) -> (B,S,d).
    With return_state=True also returns the decode cache (final SSM state +
    conv ring buffer) so prefill can hand off to decode."""
    B, S, d = x.shape
    di, ns = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di)
    xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    dt, B_ssm, C_ssm = _ssm_inputs(p, cfg, xc)
    A = -jnp.exp(p["A_log"])                                  # (di,N)
    u = (dt * xc.astype(jnp.float32))                         # (B,S,di)

    L = min(CHUNK, S)
    pad = (-S) % L
    nc = (S + pad) // L

    def chunked(t):
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)

    dt_c, u_c = chunked(dt), chunked(u)                       # (nc,B,L,di)
    Bc, Cc = chunked(B_ssm), chunked(C_ssm)                   # (nc,B,L,N)
    # padded tail steps must not pollute the carried state: a=1, b=0
    if pad:
        valid = chunked(jnp.ones((B, S), jnp.float32))        # (nc,B,L)
    else:
        valid = None

    def step(h, inp):
        dt_i, u_i, B_i, C_i, v_i = inp
        a = jnp.exp(dt_i[..., None] * A)                      # (B,L,di,N)
        b = u_i[..., None] * B_i[:, :, None, :]               # (B,L,di,N)
        if v_i is not None:
            m = v_i[..., None, None]
            a = a * m + (1.0 - m)
            b = b * m
        h_all, h_last = _scan_chunk(a, b, h)
        y = jnp.einsum("blnd,bln->bld", h_all.swapaxes(-1, -2), C_i)
        return h_last, y

    h0 = jnp.zeros((B, di, ns), dtype=jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, (dt_c, u_c, Bc, Cc, valid))
    y = ys.swapaxes(0, 1).reshape(B, nc * L, di)[:, :S]
    y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    K = cfg.ssm_conv
    conv_tail = x_in[:, S - (K - 1):].astype(jnp.bfloat16) if S >= K - 1 \
        else jnp.pad(x_in, ((0, 0), (K - 1 - S, 0), (0, 0))
                     ).astype(jnp.bfloat16)
    return out, {"h": h_last, "conv": conv_tail}


def mamba_init_cache(cfg: ModelConfig, B: int, dtype=jnp.float32) -> dict:
    di, ns, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((B, di, ns), dtype=jnp.float32),
        "conv": jnp.zeros((B, K - 1, di), dtype=jnp.bfloat16),
    }


def mamba_decode_step(p: dict, cfg: ModelConfig, x: Array,
                      cache: dict) -> tuple[Array, dict]:
    """Single-token step. x: (B,1,d)."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B,di)
    K = cfg.ssm_conv
    win = jnp.concatenate([cache["conv"],
                           x_in[:, None].astype(jnp.bfloat16)], axis=1)
    xc = jax.nn.silu(
        jnp.sum(win * p["conv_w"][None], axis=1) + p["conv_b"])
    dt, B_ssm, C_ssm = _ssm_inputs(p, cfg, xc[:, None])
    dt, B_ssm, C_ssm = dt[:, 0], B_ssm[:, 0], C_ssm[:, 0]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                            # (B,di,N)
    b = (dt * xc.astype(jnp.float32))[..., None] * B_ssm[:, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, C_ssm).astype(x.dtype)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": win[:, 1:]}
