"""Unified model configuration covering all assigned architecture families:
dense / GQA transformers, MoE, Mamba (SSM), hybrid, VLM (cross-attention),
and encoder-decoder."""

from __future__ import annotations

import dataclasses
from typing import Optional

# layer kinds usable in `layer_pattern`
ATTN = "attn"            # global causal self-attention
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
MAMBA = "mamba"          # mamba1 SSM block
CROSS = "cross"          # self-attention + gated cross-attention (VLM)

# ffn kinds usable in `ffn_pattern`
MLP = "mlp"
MOE = "moe"
NONE = "none"            # mamba blocks carry their own mixing; no FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    d_ff: int
    n_heads: int = 0                   # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    head_dim: int = 0                  # 0 => d_model // n_heads
    layer_pattern: tuple[str, ...] = (ATTN,)
    ffn_pattern: tuple[str, ...] = (MLP,)
    # --- attention ---
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0     # gemma3: separate theta for globals
    partial_rotary: float = 1.0        # chatglm3 "2d RoPE": rotate half dims
    sliding_window: int = 0            # for ATTN_LOCAL layers
    embed_scale: bool = False          # gemma: scale embeds by sqrt(d_model)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- mamba ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                   # 0 => ceil(d_model / 16)
    # --- encoder (enc-dec archs) ---
    encoder_layers: int = 0
    encoder_frames: int = 1024         # stub modality frontend length
    # --- VLM ---
    image_tokens: int = 0              # stub patch-embedding count
    # --- numerics / misc ---
    mlp_gated: bool = True             # False => classic 2-matrix MLP
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    remat: str = "full"                # "none" | "full" (per layer period)

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a 256-multiple so the vocab dim shards
        evenly on any mesh axis (49155 -> 49408 etc.); logits over the pad
        are masked to -inf."""
        return -(-self.vocab // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_vlm(self) -> bool:
        return self.image_tokens > 0

    @property
    def attention_free(self) -> bool:
        return all(k == MAMBA for k in self.layer_pattern)

    def layer_kinds(self) -> list[tuple[str, str]]:
        """Full per-layer (mixer, ffn) kind list."""
        kinds = []
        for i in range(self.n_layers):
            kinds.append((self.layer_pattern[i % len(self.layer_pattern)],
                          self.ffn_pattern[i % len(self.ffn_pattern)]))
        return kinds

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting; uses the
        padded vocab — that is what the hardware allocates and computes)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        mlp_mats = 3 if self.mlp_gated else 2
        total = v * d                      # embeddings
        if not self.tie_embeddings:
            total += v * d
        for mixer, ffn in self.layer_kinds():
            if mixer in (ATTN, ATTN_LOCAL, CROSS):
                total += d * (n_q + 2 * n_kv) + n_q * d
                if mixer == CROSS:         # extra cross-attention block
                    total += d * (n_q + 2 * n_kv) + n_q * d
            elif mixer == MAMBA:
                di, ns = self.d_inner, self.ssm_state
                dtr = self.resolved_dt_rank
                total += d * 2 * di                      # in_proj
                total += self.ssm_conv * di + di         # conv_w, conv_b
                total += di * (dtr + 2 * ns)             # x_proj
                total += dtr * di + di                   # dt_proj, dt_bias
                total += di * ns + di                    # A_log, D
                total += di * d                          # out_proj
            if ffn == MLP:
                total += mlp_mats * d * f
            elif ffn == MOE:
                total += d * self.n_experts              # router
                total += self.n_experts * 3 * d * f
            total += d                                   # norm1
            if ffn in (MLP, MOE):
                total += d                               # norm2
        total += d                                       # final norm
        if self.encoder_layers:
            per = (d * (n_q + 2 * n_kv) + n_q * d + mlp_mats * d * f
                   + 2 * d)
            total += self.encoder_layers * per
            # decoder cross-attention blocks
            total += self.n_layers * (d * (n_q + 2 * n_kv) + n_q * d + d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive_experts = self.n_experts - self.top_k
        n_moe_layers = sum(1 for _, ffn in self.layer_kinds() if ffn == MOE)
        return self.param_count() - n_moe_layers * inactive_experts * 3 * d * f
