"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against in interpret mode, shape/dtype-swept)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def triad_ref(b: jax.Array, c: jax.Array, alpha: float) -> jax.Array:
    """STREAM triad: a = b + alpha * c."""
    return b + alpha * c


def jacobi2d_ref(a: jax.Array) -> jax.Array:
    """One 5-point Jacobi sweep; boundary rows/cols pass through."""
    interior = 0.2 * (a[1:-1, 1:-1] + a[:-2, 1:-1] + a[2:, 1:-1]
                      + a[1:-1, :-2] + a[1:-1, 2:])
    return a.at[1:-1, 1:-1].set(interior.astype(a.dtype))


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with fp32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q,k,v: (B,H,S,D) -> (B,H,S,D). Plain softmax attention."""
    S, T = q.shape[-2], k.shape[-2]
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -2.0e38)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(v.dtype), v)


def mamba_scan_ref(dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
                   x: jax.Array) -> jax.Array:
    """Selective-scan oracle (sequential over time, fp32 state).

    dt, x: (Bt, S, D); A: (D, N); B, C: (Bt, S, N)  ->  y: (Bt, S, D)
        h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t ;  y_t = C_t . h_t
    """
    Bt, S, D = x.shape
    N = A.shape[1]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        a = jnp.exp(dt_t[..., None] * A)              # (Bt, D, N)
        h = a * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((Bt, D, N), jnp.float32)
    xs = (jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32),
          jnp.moveaxis(x, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
