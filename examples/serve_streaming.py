"""Serve a model whose weights exceed the device budget: SVM weight
streaming with batched decode requests, comparing the paper-faithful
demand-paging baseline against SVM-aware serving (pinning + overlapped
prefetch) and policy alternatives.

The executor runs on the compiled-session runtime: each decode step's
layer-fetch trace is recorded and compiled once (first token) and
replayed as cached op-column segments every later token — the per-row
session column shows compiled segments vs cached replays.

    PYTHONPATH=src python examples/serve_streaming.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.svm import StreamingExecutor
from repro.svm.executor import run_layer_stream


def main() -> None:
    from repro.models.config import ATTN, MLP
    n_layers = 12
    # pattern longer than n_layers => every layer is an unstacked
    # "remainder" layer with its own leaves — the natural streaming unit
    cfg = dataclasses.replace(
        get_reduced("granite-3-2b"), n_layers=n_layers, d_model=256,
        d_ff=1024, layer_pattern=(ATTN,) * (n_layers + 1),
        ffn_pattern=(MLP,) * (n_layers + 1))
    params = init_params(cfg, jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(params))
    budget = int(total * 0.55)          # DOS ~ 180%
    print(f"weights {total/1e6:.1f}MB, device budget {budget/1e6:.1f}MB "
          f"(DOS {total/budget*100:.0f}%)  batch=8 decode, 6 steps")

    flat = ["/".join(str(getattr(k, 'key', k)) for k in kp)
            for kp, _ in jax.tree_util.tree_leaves_with_path(params)]
    layer_paths = [["embed"]] + [
        sorted(p for p in flat if p.startswith(f"remainder/r{i}/"))
        for i in range(n_layers)] + [["embed"]]   # tied head re-read

    flops_per_layer = 8 * 8 * cfg.d_model * cfg.d_ff * 3

    def apply_layer(i, tensors):
        _ = [t.block_until_ready() for t in tensors.values()]
        return float(flops_per_layer)

    # the paper's §4.2 hybrid placement: pin the layers that fit, access
    # the remainder via zero-copy — no demand-paging cycle at all
    pin_half = tuple(f"remainder/r{i}/" for i in range(5)) + ("embed",)
    zc_half = tuple(f"remainder/r{i}/" for i in range(5, n_layers))

    rows = []
    for label, kw in (
        ("naive_lrf", {}),
        ("clock", {"policy": "clock"}),
        ("aware_pin+prefetch", {"prefetch": True, "pin": ("embed",)}),
        ("hybrid_pin+zerocopy", {"pin": pin_half, "zero_copy": zc_half}),
    ):
        ex = StreamingExecutor(params, budget, **kw)
        m = run_layer_stream(ex, layer_paths, apply_layer, steps=6)
        rows.append((label, m))
        print(f"  {label:22s} wall={m['wall_s']*1e3:8.2f}ms "
              f"migs={m['migrations']:4d} evicts={m['evictions']:4d} "
              f"e2m={m['evict_to_mig']:.2f} "
              f"session={m['segment_cache_misses']}c/"
              f"{m['segment_cache_hits']}r")

    base = rows[0][1]["wall_s"]
    best = min(rows, key=lambda r: r[1]["wall_s"])
    print(f"best: {best[0]} — {base/best[1]['wall_s']:.2f}x over naive LRF "
          f"demand paging (the paper's §4 mitigations, on weights)")


if __name__ == "__main__":
    main()
