"""Analytic per-cell FLOP and HBM-traffic models.

The dry-run roofline tier yields *exact* HLO FLOPs (scan-free lowering +
depth differencing) and per-device collective bytes. HBM bytes from
`cost_analysis` are an unfused upper bound (every op's operands+results),
which on the CPU stand-in backend is far above what a fused TPU program
moves. This module provides the fused-traffic estimate used as the memory
term, with the following assumptions (documented in EXPERIMENTS.md):

  * weights stream HBM->VMEM once per use: forward + remat-recompute +
    backward = 3 reads per microbatch (training); once per step (serving);
  * attention runs flash-style (Pallas kernel): no S x T score traffic,
    only q/k/v/o streams;
  * layer-boundary activations: write + (remat) re-read + backward read;
  * optimizer: moments read+write, grads write+read (ZeRO-local);
  * decode: full KV/SSM-state cache read + one-slot write per step.

Every term is per device on the (16,16) production mesh.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.launch.settings import SHAPES, settings_for
from repro.models.config import ATTN, ATTN_LOCAL, CROSS, MAMBA, MLP, MOE

MODEL_AX = 16
DP_AX = 16
CHIPS = MODEL_AX * DP_AX


def _per_layer_act_bytes(cfg, B_loc: int, S: int, train: bool) -> float:
    """Fused activation traffic per layer (bytes)."""
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    io = 2  # bf16
    total = 0.0
    for mixer, ffn in cfg.layer_kinds():
        t = 4 * d                      # residual in/out, norm rw
        if mixer in (ATTN, ATTN_LOCAL, CROSS):
            t += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd * 2  # qkv w + r
            t += cfg.n_heads * hd * 2                         # attn out
        elif mixer == MAMBA:
            t += 2 * cfg.d_inner * 3                          # xz, conv, y
            t += cfg.ssm_state * 4                            # B,C streams
        if ffn == MLP:
            t += f * 4                                        # gate/up/act/dn
        elif ffn == MOE:
            t += cfg.top_k * cfg.capacity_factor * f * 4 + cfg.n_experts
        total += t
    mult = 3.0 if train else 1.0       # fwd + remat re-fwd + bwd reads
    return total * B_loc * S * io * mult / max(cfg.n_layers, 1) \
        * cfg.n_layers


def _param_bytes_local(cfg) -> float:
    return cfg.param_count() * 2 / MODEL_AX     # bf16, TP-sharded reads


def _active_param_bytes_local(cfg) -> float:
    return cfg.active_param_count() * 2 / MODEL_AX


def _cache_bytes_local(cfg, B: int, S: int) -> float:
    hd = cfg.resolved_head_dim
    total = 0.0
    for mixer, _ in cfg.layer_kinds():
        if mixer in (ATTN, CROSS):
            total += 2 * cfg.n_kv_heads * hd * S * 2
        elif mixer == ATTN_LOCAL:
            W = min(cfg.sliding_window or S, S)
            total += 2 * cfg.n_kv_heads * hd * W * 2
        elif mixer == MAMBA:
            total += cfg.d_inner * cfg.ssm_state * 4
    shards = CHIPS if (B >= DP_AX) else DP_AX  # batch x model or seq-shard
    return total * B / shards


def analytic_bytes_per_device(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    st = settings_for(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    B_loc = max(1, B // DP_AX)
    p_loc = _param_bytes_local(cfg)

    if kind == "train":
        mb = st.microbatches
        weights = 3.0 * p_loc * mb          # fwd+re-fwd+bwd per microbatch
        grads = 2.0 * p_loc
        opt = 16.0 * cfg.param_count() / CHIPS   # fp32 m+v rw, ZeRO-local
        acts = _per_layer_act_bytes(cfg, B_loc // mb, S, True) * mb
        head = 4.0 * (B_loc * S) * cfg.padded_vocab / MODEL_AX * 2
        return weights + grads + opt + acts + head
    if kind == "prefill":
        weights = _active_param_bytes_local(cfg)
        acts = _per_layer_act_bytes(cfg, B_loc, S, False)
        cache_w = _cache_bytes_local(cfg, B, S)
        return weights + acts + cache_w
    # decode: one token over the full cache
    weights = _active_param_bytes_local(cfg)
    cache_rw = 1.1 * _cache_bytes_local(cfg, B, S)
    acts = _per_layer_act_bytes(cfg, B_loc, 1, False)
    return weights + cache_rw + acts


def analytic_flops_global(arch: str, shape: str) -> float:
    """Hardware FLOPs incl. attention quadratics, remat and CE (cross-check
    band for the HLO-differenced numbers)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    T = B * (S if kind != "decode" else 1)
    n = cfg.active_param_count()
    base = 2.0 * n * T
    # attention quadratic term (computed full S x T then masked)
    attn = 0.0
    hd = cfg.resolved_head_dim
    for mixer, _ in cfg.layer_kinds():
        if mixer in (ATTN, ATTN_LOCAL):
            ctx = S if kind != "decode" else S
            q = S if kind != "decode" else 1
            attn += 4.0 * B * q * ctx * cfg.n_heads * hd
        elif mixer == CROSS:
            ctxlen = cfg.image_tokens or cfg.encoder_frames
            q = S if kind != "decode" else 1
            attn += 4.0 * B * q * ctxlen * cfg.n_heads * hd
    if kind == "train":
        return 3.0 * (base + attn) + 1.0 * (base + attn)  # bwd 2x + remat
    return base + attn
