"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp
oracles, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.jacobi2d import jacobi2d_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.stream_triad import triad_pallas

F32, BF16 = jnp.float32, jnp.bfloat16


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 \
        else dict(rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- triad

@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", [(8, 128), (256, 512), (300, 640),
                                   (1024, 1024)])
def test_triad(shape, dtype):
    b, c = _rand(0, shape, dtype), _rand(1, shape, dtype)
    out = triad_pallas(b, c, 2.5, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.triad_ref(b, c, 2.5), np.float32), **_tol(dtype))


# ----------------------------------------------------------------- jacobi2d

@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("shape", [(16, 128), (256, 256), (384, 512),
                                   (100, 128)])
def test_jacobi2d(shape, dtype):
    a = _rand(2, shape, dtype)
    out = jacobi2d_pallas(a, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.jacobi2d_ref(a), np.float32), **_tol(dtype))


def test_jacobi2d_boundary_passthrough():
    a = _rand(3, (64, 128), F32)
    out = jacobi2d_pallas(a, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(a[0]))
    np.testing.assert_array_equal(np.asarray(out[-1]), np.asarray(a[-1]))
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(a[:, 0]))
    np.testing.assert_array_equal(np.asarray(out[:, -1]),
                                  np.asarray(a[:, -1]))


# ------------------------------------------------------------------- matmul

@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 512, 384),
                                 (512, 256, 1024), (64, 128, 256)])
def test_matmul(mnk, dtype):
    m, n, k = mnk
    a, b = _rand(4, (m, k), dtype), _rand(5, (k, n), dtype)
    out = matmul_pallas(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = dict(rtol=3e-2, atol=3e-1) if dtype == BF16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


# ---------------------------------------------------------- flash attention

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bhsd", [(1, 2, 256, 64), (2, 4, 512, 128),
                                  (1, 1, 384, 64)])
def test_flash_attention(bhsd, causal):
    B, H, S, D = bhsd
    q = _rand(6, (B, H, S, D), F32)
    k = _rand(7, (B, H, S, D), F32)
    v = _rand(8, (B, H, S, D), F32)
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- mamba scan

@pytest.mark.parametrize("dims", [(1, 128, 512, 16), (2, 256, 1024, 16),
                                  (2, 128, 640, 8)])
def test_mamba_scan(dims):
    Bt, S, D, N = dims
    dt = jax.nn.softplus(_rand(9, (Bt, S, D), F32))
    A = -jnp.exp(_rand(10, (D, N), F32) * 0.3)
    B = _rand(11, (Bt, S, N), F32)
    C = _rand(12, (Bt, S, N), F32)
    x = _rand(13, (Bt, S, D), F32)
    out = mamba_scan_pallas(dt, A, B, C, x, interpret=True)
    want = ref.mamba_scan_ref(dt, A, B, C, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192]),
    d=st.sampled_from([128, 256]),
    n=st.sampled_from([8, 16]),
)
def test_property_mamba_scan_matches_oracle(s, d, n):
    dt = jax.nn.softplus(_rand(s, (1, s, d), F32))
    A = -jnp.exp(_rand(d, (d, n), F32) * 0.3)
    B = _rand(s + 1, (1, s, n), F32)
    C = _rand(s + 2, (1, s, n), F32)
    x = _rand(s + 3, (1, s, d), F32)
    out = mamba_scan_pallas(dt, A, B, C, x, interpret=True)
    want = ref.mamba_scan_ref(dt, A, B, C, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ ops dispatch

def test_ops_dispatch_jnp_path():
    from repro.kernels import ops
    a, b = _rand(20, (64, 64), F32), _rand(21, (64, 64), F32)
    np.testing.assert_allclose(np.asarray(ops.matmul(a, b, impl="jnp")),
                               np.asarray(ref.matmul_ref(a, b)))
    with pytest.raises(ValueError):
        ops.matmul(a, b, impl="bogus")
