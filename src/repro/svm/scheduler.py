"""Multi-tenant serving scheduler over one shared SVM device pool.

The paper's central finding — aggressive prefetch plus eviction thrashes
under oversubscription — bites hardest when *many concurrent decode
streams* contend for one device pool.  This module multiplexes N serving
requests (heterogeneous architectures, seeded synthetic arrival process)
over a **single** `SVMManager`:

  * each admitted request's weights are planned at its own offset into the
    shared `AddressSpace` (`plan_leaf_ranges(space=…, align_start=True)` —
    alignment-padded starts keep same-architecture plans congruent),
  * every token is driven through the request's own `TraceSession`, and
    sessions share one `SegmentCache`: the first token of the first
    request of an architecture records + compiles the per-token trace,
    every same-architecture request thereafter **relocates and replays the
    same compiled segment** (the cross-request analogue of the sweep
    runner's cross-point ``TRACE_CACHE``),
  * per-request wall/migration/eviction accounting is attributed from
    manager counter deltas around each replay, so the per-request rows
    sum exactly to the shared manager's aggregates (conservation —
    tested).

Scheduling policies (`policy=`):

  * ``fifo``       — admit every arrived request immediately and
                     round-robin one token per request: the thrashing
                     baseline.  Aggregate working set = all arrived
                     requests; under oversubscription LRF evicts each
                     tenant's earliest-fetched layers right before its
                     next token needs them (the paper's cyclic-traversal
                     pathology, multiplied by N tenants).
  * ``admission``  — cap the *admitted* working-set bytes at
                     ``admit_watermark × capacity``; later arrivals queue
                     (head-of-line, FIFO).  Trades queueing delay for a
                     pool that actually fits what is running — the
                     paper's §5 "SVM-aware scheduling" direction: treat
                     placement pressure as an admission input.
  * ``svm_aware``  — admission, plus per-request pinning of the hottest
                     leaf (app-directed placement, §4.1; skipped when the
                     leaf would monopolise the pool — the pinned-full-pool
                     deadlock guard), plus same-architecture token
                     batching in the round-robin order so consecutive
                     replays hit the same shared compiled segment.

The scheduler never drives the manager's touch/advance entry points
directly — every access is a recorded op replayed through the engine
(`scalar=True` replays op-for-op; byte-identical by the engine's
equivalence guarantee), and the whole run is deterministic under a fixed
seed."""

from __future__ import annotations

import dataclasses
import functools
import math
from collections import OrderedDict, deque
from typing import Any, Sequence

import numpy as np

from repro.core import (
    AddressSpace,
    MigrationError,
    SVMManager,
    SegmentCache,
    TraceSession,
    execute_fused,
)
from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.core.ranges import DEFAULT_BASE
from repro.ft.retry import RetryError, RetryPolicy, retry_call
from repro.svm.faults import FaultInjector, FaultPlan
from repro.svm.hotset import ProfileCache, spec_profile
from repro.svm.planner import ParamRanges, plan_leaf_ranges

PyTree = Any

POLICIES = ("fifo", "admission", "svm_aware")
ARRIVALS = ("burst", "poisson", "uniform")
#: what the admission watermark caps (docs/prefetching.md):
#:   bytes    — total plan bytes (the paper's baseline: admit by what a
#:              tenant *allocates*)
#:   measured — estimated resident working-set bytes from the tenant's
#:              own touch columns (`repro.svm.hotset.spec_profile`):
#:              admit by what it actually keeps resident, so sparse /
#:              streaming tenants stop reserving room they never use
ADMIT_MODES = ("bytes", "measured")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A serving request's weight-streaming shape: named leaves in fetch
    order, the per-token layer→leaf fetch groups, and per-layer FLOPs.

    Frozen and hashable — equal specs share compiled per-token segments
    across requests (the spec itself is the segment key)."""

    arch: str
    leaves: tuple[tuple[str, int], ...]          # (path, nbytes)
    layer_paths: tuple[tuple[str, ...], ...]     # per-layer leaf groups
    flops_per_layer: tuple[float, ...]

    @functools.cached_property
    def total_bytes(self) -> int:
        # cached: `_fits` reads this on every admission probe (cached_
        # property writes the instance __dict__ directly, which a frozen
        # dataclass permits; equality/hash stay field-based)
        return sum(n for _, n in self.leaves)

    def __hash__(self) -> int:
        # specs key every segment-cache lookup (twice per token); the
        # generated dataclass hash re-walks the leaf/path tuples each
        # call, so memoise it (same __dict__ side door as total_bytes)
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.arch, self.leaves, self.layer_paths,
                      self.flops_per_layer))
            self.__dict__["_hash"] = h
        return h

    @property
    def hot_leaf(self) -> tuple[str, int]:
        """The largest leaf — the pinning candidate under ``svm_aware``."""
        return max(self.leaves, key=lambda pn: pn[1])

    @classmethod
    def from_params(cls, arch: str, params: PyTree,
                    batch: int = 1) -> "ModelSpec":
        """Spec from a real parameter tree: one fetch group per leaf in
        model order, per-leaf decode FLOPs ≈ 2 · batch · params (the
        `WeightStream` convention)."""
        import jax

        leaves, layer_paths, flops = [], [], []
        for kp, leaf in jax.tree_util.tree_leaves_with_path(params):
            path = "/".join(
                getattr(k, "key", getattr(k, "name", str(k))) for k in kp)
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            leaves.append((path, n * leaf.dtype.itemsize))
            layer_paths.append((path,))
            flops.append(2.0 * batch * n)
        return cls(arch=arch, leaves=tuple(leaves),
                   layer_paths=tuple(layer_paths),
                   flops_per_layer=tuple(flops))

    @classmethod
    def synthetic(cls, arch: str, n_layers: int, layer_bytes: int, *,
                  embed_bytes: int = 0, batch: int = 1) -> "ModelSpec":
        """A uniform synthetic decoder: optional embedding leaf (touched
        first and last per token — the hot leaf) plus ``n_layers`` equal
        weight leaves.  FLOPs assume fp32 leaves (2 · batch · params)."""
        leaves: list[tuple[str, int]] = []
        layer_paths: list[tuple[str, ...]] = []
        flops: list[float] = []

        def add(path: str, nbytes: int) -> None:
            leaves.append((path, int(nbytes)))
            layer_paths.append((path,))
            flops.append(2.0 * batch * (nbytes / 4.0))

        if embed_bytes:
            add(f"{arch}/embed", embed_bytes)
        for i in range(n_layers):
            add(f"{arch}/l{i:03d}", layer_bytes)
        if embed_bytes:
            # tied head re-read: the embedding leaf is touched again
            layer_paths.append((f"{arch}/embed",))
            flops.append(2.0 * batch * (embed_bytes / 4.0))
        return cls(arch=arch, leaves=tuple(leaves),
                   layer_paths=tuple(layer_paths),
                   flops_per_layer=tuple(flops))

    @classmethod
    def synthetic_moe(cls, arch: str, n_layers: int, layer_bytes: int, *,
                      n_experts: int = 8, active_experts: int = 1,
                      expert_bytes: int | None = None,
                      embed_bytes: int = 0, batch: int = 1) -> "ModelSpec":
        """A sparse mixture-of-experts decoder: per layer, one dense leaf
        plus ``n_experts`` expert leaves of which only the first
        ``active_experts`` are routed to (greedy decode with a fixed
        router — deterministic, so the spec stays a pure data shape).

        The inactive experts are *planned* (they count toward
        ``total_bytes`` — the plan must hold them) but never appear in
        ``layer_paths``, so they are never touched: plan bytes ≫ touched
        bytes.  This is exactly the tenant shape plan-bytes admission
        over-charges and measured admission (``admit_by="measured"``,
        docs/prefetching.md) admits at its true resident cost."""
        if not 0 <= active_experts <= n_experts:
            raise ValueError(f"active_experts {active_experts} outside "
                             f"[0, {n_experts}]")
        eb = layer_bytes if expert_bytes is None else int(expert_bytes)
        leaves: list[tuple[str, int]] = []
        layer_paths: list[tuple[str, ...]] = []
        flops: list[float] = []
        if embed_bytes:
            leaves.append((f"{arch}/embed", int(embed_bytes)))
            layer_paths.append((f"{arch}/embed",))
            flops.append(2.0 * batch * (embed_bytes / 4.0))
        for i in range(n_layers):
            dense = f"{arch}/l{i:03d}/dense"
            leaves.append((dense, int(layer_bytes)))
            routed = tuple(f"{arch}/l{i:03d}/e{j:02d}"
                           for j in range(active_experts))
            leaves.extend((f"{arch}/l{i:03d}/e{j:02d}", eb)
                          for j in range(n_experts))
            layer_paths.append((dense,) + routed)
            layer_flops = (layer_bytes + active_experts * eb) / 4.0
            flops.append(2.0 * batch * layer_flops)
        if embed_bytes:
            layer_paths.append((f"{arch}/embed",))
            flops.append(2.0 * batch * (embed_bytes / 4.0))
        return cls(arch=arch, leaves=tuple(leaves),
                   layer_paths=tuple(layer_paths),
                   flops_per_layer=tuple(flops))


@dataclasses.dataclass(eq=False)
class Request:
    """One decode stream: its spec, arrival time, decode length, and —
    once admitted — its plan/session plus attributed accounting.

    ``eq=False``: requests are unique mutable objects; identity equality
    keeps ``active.remove(req)`` a pointer scan instead of a full
    field-by-field compare against every co-active request."""

    req_id: int
    spec: ModelSpec
    arrival_s: float
    n_tokens: int
    # filled at admission
    plan: ParamRanges | None = None
    session: TraceSession | None = None
    admit_seq: int = -1
    admit_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    tokens_done: int = 0
    pinned_rids: tuple[int, ...] = ()
    pinned_bytes: int = 0
    # manager-counter deltas attributed to this request's replays
    migrations: int = 0
    evictions: int = 0
    bytes_migrated: int = 0
    bytes_evicted: int = 0
    svm_wall_s: float = 0.0
    # chaos / recovery accounting (docs/robustness.md)
    faults: int = 0            # migration faults this request absorbed
    retries: int = 0           # bounded-retry attempts after faults
    backoff_s: float = 0.0     # simulated backoff wall charged to it
    crashes: int = 0           # mid-decode crashes survived
    preemptions: int = 0       # thrash-guard preemptions survived
    resumes: int = 0           # re-admissions from carried session state
    failed: bool = False       # dropped after retry-budget exhaustion
    not_before_s: float = 0.0  # re-admission backoff gate

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queue_wait_s(self) -> float:
        return self.admit_s - self.arrival_s

    def row(self) -> dict:
        """Flat per-request result row."""
        return {
            "req_id": self.req_id, "arch": self.spec.arch,
            "bytes": self.spec.total_bytes, "arrival_s": self.arrival_s,
            "admit_s": self.admit_s, "finish_s": self.finish_s,
            "latency_s": self.latency_s,
            "queue_wait_s": self.queue_wait_s,
            "ttft_s": ((self.first_token_s - self.arrival_s)
                       if self.tokens_done else 0.0),
            "tokens": self.tokens_done,
            "migrations": self.migrations, "evictions": self.evictions,
            "bytes_migrated": self.bytes_migrated,
            "bytes_evicted": self.bytes_evicted,
            "svm_wall_s": self.svm_wall_s,
            "pinned_bytes": self.pinned_bytes,
            "faults": self.faults, "retries": self.retries,
            "backoff_s": self.backoff_s, "crashes": self.crashes,
            "preemptions": self.preemptions, "resumes": self.resumes,
            "failed": self.failed,
        }


def make_requests(specs: Sequence[ModelSpec], n_requests: int, *,
                  seed: int = 0, mean_interarrival_s: float = 0.0,
                  arrival: str = "poisson", tokens: int = 32,
                  token_jitter: int = 0,
                  spec_choice: str = "random") -> list[Request]:
    """Seeded synthetic arrival process.

    ``arrival``: ``burst`` (everything at t=0 — also forced when
    ``mean_interarrival_s`` is 0), ``poisson`` (exponential
    interarrivals), or ``uniform`` (fixed spacing).  Specs are drawn
    ``random``-ly or assigned ``roundrobin``; decode lengths are
    ``tokens ± token_jitter``.  Same seed ⇒ same request list."""
    if arrival not in ARRIVALS:
        raise ValueError(f"unknown arrival {arrival!r}; "
                         f"available: {ARRIVALS}")
    if spec_choice not in ("random", "roundrobin"):
        raise ValueError(f"unknown spec_choice {spec_choice!r}")
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        if i > 0 and mean_interarrival_s > 0.0 and arrival != "burst":
            t += (float(rng.exponential(mean_interarrival_s))
                  if arrival == "poisson" else mean_interarrival_s)
        spec = (specs[i % len(specs)] if spec_choice == "roundrobin"
                else specs[int(rng.integers(len(specs)))])
        n_tok = tokens if not token_jitter else int(
            rng.integers(max(1, tokens - token_jitter),
                         tokens + token_jitter + 1))
        out.append(Request(req_id=i, spec=spec, arrival_s=t,
                           n_tokens=n_tok))
    return out


class PoolScheduler:
    """Multiplex decode requests over one shared SVM device pool.

    One `AddressSpace` + one `SVMManager` + one shared `SegmentCache`;
    requests are admitted, planned, and interleaved per the scheduling
    ``policy`` (see module docstring).  `run(requests)` drives every
    request to completion on the simulated clock and returns the
    aggregate/percentile report."""

    def __init__(self, capacity_bytes: int, *, policy: str = "svm_aware",
                 evict_policy: str = "lrf",
                 cost_params: CostParams = TPU_V5E_HOST,
                 admit_watermark: float = 1.0, admit_by: str = "bytes",
                 pin_frac: float = 0.25,
                 concurrency: int = 64, compute_rate: float | None = None,
                 scalar: bool = False, fused: bool = True,
                 base: int = DEFAULT_BASE,
                 segment_cache_size: int = 512,
                 concat_memo_size: int = 16,
                 fault_plan: FaultPlan | None = None,
                 retry_policy: RetryPolicy | None = None,
                 thrash_watermark: float | None = None,
                 thrash_window: int = 64):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"available: {POLICIES}")
        if admit_by not in ADMIT_MODES:
            raise ValueError(f"unknown admit_by {admit_by!r}; "
                             f"available: {ADMIT_MODES}")
        self.policy = policy
        self.admit_by = admit_by
        self.capacity = capacity_bytes
        self.space = AddressSpace(capacity_bytes, base=base)
        self.mgr = SVMManager(self.space, policy=evict_policy,
                              params=cost_params, profile=False)
        self.shared_cache = SegmentCache(segment_cache_size)
        self.admit_watermark = admit_watermark
        self.pin_frac = pin_frac
        self.concurrency = concurrency
        self.compute_rate = (compute_rate if compute_rate is not None
                             else cost_params.serve_flops)
        self.scalar = scalar
        # fused round replay: one concatenated mega-trace per scheduler
        # round, executed in a single batched-interpreter pass with
        # per-request attribution sampled at the segment cuts.  Byte-
        # identical to the per-token loop; ``fused=False`` (and scalar
        # mode, which has no batched interpreter) keep the golden
        # reference path.
        self.fused = bool(fused) and not scalar
        self.now = 0.0
        self.admitted_bytes = 0
        self.peak_admitted_bytes = 0
        self.peak_active_requests = 0
        self.pinned_bytes_total = 0
        # measured admission: per-spec profile + memoised admission cost
        # (the cost is a pure function of (spec, nominal capacity), so
        # the same number is added at admit and subtracted at retire /
        # evacuate even if chaos resizes the live pool in between)
        self._profile_cache = ProfileCache()
        self._admit_cost_memo: dict[ModelSpec, int] = {}
        self._admit_seq = 0
        self._geometry: dict[ModelSpec, tuple] = {}
        self._plan_proto: dict[ModelSpec, ParamRanges] = {}
        self._sessions: list[TraceSession] = []
        # round-shape memo: identical segment tuples (by identity — the
        # per-session LRUs hand back the same relocated objects every
        # steady-state round) reuse one concatenated mega-trace.  Bounded
        # (LRU) so thousand-round schedules with churning round shapes
        # cannot grow host memory without limit; evictions are counted
        # and surfaced in the result's ``shared_cache`` block.
        self._concat_memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._concat_memo_size = max(int(concat_memo_size), 1)
        self._concat_evictions = 0

        # ---- chaos layer + runtime guards (docs/robustness.md)
        self.injector = (FaultInjector(fault_plan)
                         if fault_plan is not None else None)
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy(max_attempts=4,
                                              base_delay_s=1e-4))
        # thrash detector: sliding evictions-per-token watermark over
        # manager counter deltas (None = guard off)
        self.thrash_watermark = thrash_watermark
        self.thrash_window = max(int(thrash_window), 1)
        self._thrash_hist: "deque[tuple[int, int]]" = deque()
        self._thrash_cooldown = 0
        self._tokens_total = 0
        self._pending_fail_attempts = 0
        self.cost_scale = 1.0
        self.failed: list[Request] = []
        self.incidents: list[str] = []
        self._chaos = {
            "migration_faults": 0, "retries": 0, "retry_exhausted": 0,
            "crashes": 0, "preemptions": 0, "resumes": 0,
            "capacity_events": 0, "slow_page_windows": 0,
            "degraded_rounds": 0, "fused_fallbacks": 0,
            "thrash_trips": 0, "backoff_wall_s": 0.0,
        }

    # -------------------------------------------------------- admission

    def _admit_cost(self, spec: ModelSpec) -> int:
        """What a tenant charges against the admission watermark.

        ``bytes`` mode: total plan bytes.  ``measured`` mode: the
        estimated resident working set from the spec's own touch columns
        (hot set + one streaming buffer, capped at plan bytes — a
        measured cost must never exceed the allocation it measures).
        Memoised per spec with the *nominal* capacity as the pressure
        window, so the ledger adds and subtracts the identical number
        for a tenant even when chaos resizes the live pool mid-flight,
        and congruent tenants share one profile via the cache."""
        if self.admit_by == "bytes":
            return spec.total_bytes
        cost = self._admit_cost_memo.get(spec)
        if cost is None:
            prof = spec_profile(spec, cache=self._profile_cache,
                                concurrency=self.concurrency)
            cost = min(spec.total_bytes,
                       prof.resident_bytes(self.capacity))
            self._admit_cost_memo[spec] = cost
        return cost

    def _fits(self, spec: ModelSpec) -> bool:
        # admission probes the *effective* pool: a chaos capacity loss
        # (mgr.resize_capacity) tightens admission until it is restored
        cap = min(self.capacity, self.mgr.capacity)
        return (self.admitted_bytes + self._admit_cost(spec)
                <= self.admit_watermark * cap)

    def _admit(self, queued: "deque[Request]",
               active: list[Request]) -> None:
        while queued:
            head = queued[0]
            if head.not_before_s > self.now + 1e-12:
                # crash/preemption re-admission backoff: the head waits
                # out its gate (head-of-line, like admission control)
                break
            if self.policy != "fifo" and not self._fits(head.spec):
                # head-of-line admission control; an oversized request
                # that can never fit is admitted alone rather than
                # deadlocking the queue
                if active or self.admitted_bytes > 0:
                    break
            self._admit_one(queued.popleft(), active)

    def _admit_one(self, req: Request, active: list[Request]) -> None:
        if req.plan is None:
            proto_plan = self._plan_proto.get(req.spec)
            if proto_plan is not None:
                # repeated architecture: congruent clone of the
                # prototype plan (geometry equality by construction)
                req.plan = proto_plan.clone_into(self.space)
            else:
                req.plan = plan_leaf_ranges(
                    req.spec.leaves, self.capacity, space=self.space,
                    align_start=True)
                geo = req.plan.geometry()
                proto = self._geometry.setdefault(req.spec, geo)
                if geo != proto:  # pragma: no cover — congruent by design
                    raise AssertionError(
                        f"req {req.req_id}: plan geometry diverged from "
                        f"its spec's prototype; segment sharing would be "
                        f"unsound")
                self._plan_proto[req.spec] = req.plan
            req.session = TraceSession(
                self.mgr, scalar=self.scalar, cache_size=8,
                shared_cache=self.shared_cache, rid_base=req.plan.rid_base)
            self._sessions.append(req.session)
            req.admit_s = self.now
        else:
            # crash/preemption resume: the plan, session, and compiled
            # segments carry over — re-admission replays nothing
            req.resumes += 1
            self._chaos["resumes"] += 1
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.admitted_bytes += self._admit_cost(req.spec)
        self.peak_admitted_bytes = max(self.peak_admitted_bytes,
                                       self.admitted_bytes)
        active.append(req)
        self.peak_active_requests = max(self.peak_active_requests,
                                        len(active))
        if self.policy == "svm_aware":
            self._pin_hot_leaf(req)

    def _pin_hot_leaf(self, req: Request) -> None:
        """App-directed placement (§4.1): pin the request's hottest leaf —
        unless it would monopolise the pool (no leaf above half the
        capacity, and all pins together stay under ``pin_frac``): a
        pinned-full pool deadlocks every later migration."""
        path, nbytes = req.spec.hot_leaf
        if nbytes > self.capacity // 2:
            return
        if self.pinned_bytes_total + nbytes > self.pin_frac * self.capacity:
            return
        rids = tuple(req.plan.leaf_ranges[path])
        self._replay_attributed(
            req, lambda: self._run_pin_segment(req, "pin", rids))
        req.pinned_rids = rids
        req.pinned_bytes = nbytes
        self.pinned_bytes_total += nbytes

    def _run_pin_segment(self, req: Request, kind: str,
                         rids: tuple[int, ...]) -> None:
        """Replay the request's (un)pin segment via the keyed segment
        path: every same-architecture request records the congruent rid
        block, so after the first admission the segment comes out of the
        shared cache as a pure rid-shift relocation instead of a
        per-request record + seal."""
        op = TraceSession.pin if kind == "pin" else TraceSession.unpin

        def record(s: TraceSession) -> None:
            for rid in rids:
                op(s, rid)
        req.session.run((kind, req.spec), record)

    # -------------------------------------------------------- decode loop

    def _round_order(self, active: list[Request]) -> list[Request]:
        """One-token-per-request round order.  ``svm_aware`` groups
        same-architecture requests back to back so consecutive replays
        hit the same shared compiled segment; the others round-robin in
        admission order."""
        if self.policy == "svm_aware":
            return sorted(active, key=lambda r: (r.spec.arch, r.admit_seq))
        return sorted(active, key=lambda r: r.admit_seq)

    def _replay_attributed(self, req: Request, fn) -> None:
        """Run one session replay and attribute the manager's counter
        deltas (wall, migrations, evictions, bytes) to ``req`` — the
        per-request rows sum exactly to the shared manager's totals.
        Attribution lands in ``finally``: a replay that raises mid-way
        (an injected `MigrationError`) still charges whatever work the
        manager did before the fault, so conservation holds across
        failed attempts too."""
        m = self.mgr
        w0, mig0, ev0 = m.wall, m.n_migrations, m.n_evictions
        bm0, be0 = m.bytes_migrated, m.bytes_evicted
        try:
            fn()
        finally:
            req.svm_wall_s += m.wall - w0
            req.migrations += m.n_migrations - mig0
            req.evictions += m.n_evictions - ev0
            req.bytes_migrated += m.bytes_migrated - bm0
            req.bytes_evicted += m.bytes_evicted - be0
            self.now += m.wall - w0

    def _record_token(self, session: TraceSession, spec: ModelSpec,
                      plan: ParamRanges) -> None:
        """Record one decode token's layer-fetch ops into ``session``."""
        rate, conc = self.compute_rate, self.concurrency
        for paths, fl in zip(spec.layer_paths, spec.flops_per_layer):
            for p in paths:
                for rid in plan.leaf_ranges[p]:
                    session.touch(rid, concurrency=conc)
            session.compute(fl / rate)

    def _decode_token(self, req: Request) -> None:
        key = ("tok", req.spec)

        def rec(s, spec=req.spec, plan=req.plan):
            self._record_token(s, spec, plan)

        if self._pending_fail_attempts or self.cost_scale != 1.0:
            # active hazard: route through the golden scalar path with
            # bounded retry (may raise RetryError — the caller drops the
            # request; no token is counted then)
            self._chaos_token(req, key, rec)
        else:
            self._replay_attributed(req, lambda: req.session.run(key, rec))
        req.tokens_done += 1
        self._tokens_total += 1
        if req.tokens_done == 1:
            req.first_token_s = self.now

    # ------------------------------------------------------- chaos layer

    def _chaos_token(self, req: Request, key, rec) -> None:
        """Decode one token under active hazards.

        Armed migration faults must surface at the exact faulting op with
        the manager untouched past it — only op-for-op scalar dispatch
        guarantees that unconditionally (the vectorized tiers batch
        migrations), so the hazard token replays via
        `TraceSession.replay_scalar` (byte-identical when nothing
        raises).  Recovery is the shared bounded retry
        (`repro.ft.retry`): one armed fault per attempt for the event's
        first ``fail_attempts`` attempts, deterministic exponential
        backoff charged to the simulated clock via
        `SVMManager.inject_latency`.  A slow-page window charges its
        multiplicative migration-cost surcharge from the token's
        measured cost delta.  Everything — failed attempts included —
        runs inside one attribution window, so conservation holds."""
        session = req.session
        ct = session.fetch(key, rec)
        fail_attempts = self._pending_fail_attempts
        self._pending_fail_attempts = 0
        m = self.mgr
        mf0 = m.migration_faults

        def on_backoff(attempt: int, delay_s: float) -> None:
            req.retries += 1
            req.backoff_s += delay_s
            self._chaos["retries"] += 1
            self._chaos["backoff_wall_s"] += delay_s
            m.inject_latency(delay_s)

        def attempt_token(attempt: int) -> None:
            m.arm_migration_faults(1 if attempt <= fail_attempts else 0)
            try:
                c0 = m.cost.total()
                session.replay_scalar(ct)
                if self.cost_scale != 1.0:
                    m.inject_latency((self.cost_scale - 1.0)
                                     * (m.cost.total() - c0))
            finally:
                # never leak an armed fault into later vectorized replays
                m.arm_migration_faults(0)

        self._replay_attributed(
            req, lambda: retry_call(attempt_token,
                                    policy=self.retry_policy,
                                    retry_on=(MigrationError,),
                                    on_backoff=on_backoff))
        if fail_attempts:
            if m.migration_faults > mf0:
                req.faults += 1
                self._chaos["migration_faults"] += 1
            else:
                # the token ran fully resident — nothing migrated, so
                # there was no migration to fail; the armed hazard
                # carries to the next decoded token
                self._pending_fail_attempts = fail_attempts

    def _chaos_step(self, req: Request, queued: "deque[Request]",
                    active: list[Request]) -> bool:
        """Pump the injector at the current token counter: apply every
        due environment event, then at most one token-targeted event
        aimed at ``req`` (the next decoder).  Returns True when the
        event consumed the request's turn (a crash — no token
        decodes)."""
        for ev in self.injector.due_env(self._tokens_total):
            if ev.kind in ("capacity_loss", "capacity_restore"):
                self._apply_capacity_event(ev, req, active)
            elif ev.kind == "slow_page":
                self.cost_scale = float(ev.frac)
                self._chaos["slow_page_windows"] += 1
                self.incidents.append(
                    f"tok={self._tokens_total} slow_page window opens "
                    f"(migration cost x{ev.frac:g})")
            else:  # slow_page_end
                self.cost_scale = 1.0
        ev = self.injector.pop_token_event(self._tokens_total)
        if ev is None:
            return False
        if ev.kind == "migration_fault":
            # arm the next decode; _chaos_token recovers via bounded retry
            self._pending_fail_attempts = max(1, int(ev.fail_attempts))
            return False
        # crash: the request dies mid-decode — drain its ranges eagerly
        # and re-queue it to resume from its TraceSession carried state
        req.crashes += 1
        self._chaos["crashes"] += 1
        self.incidents.append(
            f"tok={self._tokens_total} crash req={req.req_id} at "
            f"tokens_done={req.tokens_done} — drained, re-queued")
        self._evacuate(req, active, queued, requeue=True)
        return True

    def _apply_capacity_event(self, ev, req: Request,
                              active: list[Request]) -> None:
        """Transient co-tenancy via the public `resize_capacity` hook.
        The shrink target is clamped above pinned bytes plus the largest
        active leaf — a pool smaller than that deadlocks the next
        migration — and the emergency-eviction work is attributed to the
        next decoder so conservation stays exact."""
        self._chaos["capacity_events"] += 1
        target = max(int(self.capacity * ev.frac), 1)
        floor_b = self.pinned_bytes_total
        if active:
            floor_b += max(max(n for _, n in r.spec.leaves)
                           for r in active)
        target = max(target, floor_b, 1)
        self._replay_attributed(
            req, lambda: self.mgr.resize_capacity(target))
        self.incidents.append(
            f"tok={self._tokens_total} {ev.kind}: pool -> {target} bytes "
            f"({target / self.capacity:.0%} of nominal)")

    def _evacuate(self, req: Request, active: list[Request],
                  queued: "deque[Request]", *, requeue: bool) -> None:
        """Eagerly drain a request out of the pool: unpin its pins,
        write back every resident range of its plan (counted as
        evictions, like any algorithmic device→host transfer), and
        either re-queue it behind a deterministic backoff gate or drop
        it to the failed list.  Plan, session, and compiled segments are
        carried, so a re-admission resumes byte-identically at the next
        un-decoded token."""
        def drain(session=req.session, plan=req.plan,
                  pinned=req.pinned_rids):
            for rid in pinned:
                session.unpin(rid)
            for rids in plan.leaf_ranges.values():
                for rid in rids:
                    session.writeback(rid)
            session.flush()
        self._replay_attributed(req, drain)
        if req.pinned_rids:
            self.pinned_bytes_total -= req.pinned_bytes
            req.pinned_rids = ()
            req.pinned_bytes = 0
        self.admitted_bytes -= self._admit_cost(req.spec)
        active.remove(req)
        if requeue:
            attempt = max(1, req.crashes + req.preemptions)
            req.not_before_s = self.now + self.retry_policy.delay(attempt)
            queued.append(req)
        else:
            req.failed = True
            req.finish_s = self.now
            self.failed.append(req)

    def _thrash_check(self, active: list[Request],
                      queued: "deque[Request]") -> None:
        """Thrash detector (opt-in via ``thrash_watermark``): a sliding
        window of (token counter, manager eviction counter) snapshots.
        When evictions-per-token over the window crosses the watermark,
        degrade: preempt the largest active tenant (eager drain,
        re-queue with backoff, resume from carried session state) and
        tighten admission — the paper's thrashing signature turned into
        a runtime control loop."""
        if self.thrash_watermark is None:
            return
        self._thrash_hist.append((self._tokens_total,
                                  self.mgr.n_evictions))
        cutoff = self._tokens_total - self.thrash_window
        while len(self._thrash_hist) > 1 and \
                self._thrash_hist[0][0] < cutoff:
            self._thrash_hist.popleft()
        t0, e0 = self._thrash_hist[0]
        dt = self._tokens_total - t0
        if dt < self.thrash_window:
            return
        rate = (self.mgr.n_evictions - e0) / dt
        if rate <= self.thrash_watermark:
            return
        if len(active) <= 1 or self._tokens_total < self._thrash_cooldown:
            return
        victim = max(active, key=lambda r: (r.spec.total_bytes,
                                            -r.admit_seq))
        victim.preemptions += 1
        self._chaos["preemptions"] += 1
        self._chaos["thrash_trips"] += 1
        self.admit_watermark = max(0.3, self.admit_watermark * 0.85)
        self.incidents.append(
            f"tok={self._tokens_total} thrash-guard trip "
            f"(ev/token={rate:.2f} > {self.thrash_watermark:g}): preempt "
            f"req={victim.req_id}, "
            f"admit_watermark->{self.admit_watermark:.2f}")
        self._evacuate(victim, active, queued, requeue=True)
        self._thrash_cooldown = self._tokens_total + self.thrash_window
        self._thrash_hist.clear()

    def _chaos_round_pending(self, order: list[Request]) -> bool:
        """True when a hazard is live or due within this round — the
        fused tier degrades the whole round to the golden per-token path
        (chaos events key off the per-token counter, which a fused block
        only advances in bulk)."""
        if self.cost_scale != 1.0 or self._pending_fail_attempts:
            return True
        if self.injector is None:
            return False
        return self.injector.next_at() <= self._tokens_total + len(order)

    # ---------------------------------------------------- fused round tier

    def _fetch_segments(self, block: list[Request]) -> list:
        """Resolve every block member's next-token compiled segment
        without replaying: per-session LRU hits first, then **one**
        shared-cache probe per distinct key (`SegmentCache.batch_relocate`
        rebased to every member's rid base at once), recording only on
        the first-ever encounter of a key.  Session/shared counter totals
        match the sequential per-token `TraceSession.fetch` chain."""
        segs: list = [None] * len(block)
        groups: "OrderedDict[object, list]" = OrderedDict()
        for k, req in enumerate(block):
            key = ("tok", req.spec)
            ct = req.session.get(key)
            if ct is not None:
                req.session.cache_hits += 1
                segs[k] = ct
            else:
                groups.setdefault(key, []).append((k, req))
        for key, members in groups.items():
            cts = self.shared_cache.batch_relocate(
                key, [req.plan.rid_base for _, req in members])
            if cts is None:
                # first encounter: the head records + publishes, the rest
                # re-probe as shared hits (same counters as sequentially)
                k0, r0 = members[0]
                sess = r0.session
                sess.cache_misses += 1
                self._record_token(sess, r0.spec, r0.plan)
                ct0 = sess.seal(key)
                self.shared_cache.put(key, r0.plan.rid_base, ct0)
                segs[k0] = ct0
                members = members[1:]
                if not members:
                    continue
                cts = self.shared_cache.batch_relocate(
                    key, [req.plan.rid_base for _, req in members])
            for (k, req), ct in zip(members, cts):
                req.session.shared_hits += 1
                req.session._cache_put(key, ct)
                segs[k] = ct
        return segs

    def _concat_round(self, segs: list) -> "Any":
        """Memoised `SegmentCache.concat` over the block's segment tuple.
        Keyed by object identity; the memo holds strong references, so a
        key can never alias a freed segment."""
        key = tuple(id(ct) for ct in segs)
        ent = self._concat_memo.get(key)
        if ent is not None:
            self._concat_memo.move_to_end(key)
            return ent[1]
        mega = self.shared_cache.concat(segs)
        self._concat_memo[key] = (tuple(segs), mega)
        while len(self._concat_memo) > self._concat_memo_size:
            self._concat_memo.popitem(last=False)
            self._concat_evictions += 1
        return mega

    def _run_round_fused(self, order: list[Request], waiting,
                         queued: "deque[Request]", active: list[Request],
                         done: list[Request], ingest) -> None:
        """One scheduler round as fused blocks.

        A block is a maximal run of ``order`` whose segments may replay
        back-to-back with **no interleaved manager mutation**: it ends at
        a finishing request (its retirement unpins ranges and admits
        queued tenants — both mutate policy state for later segments) and,
        under ``svm_aware`` with arrivals still pending, every block is
        unit-sized (a mid-round admission pins at a wall-dependent
        position).  fifo/admission mid-round admissions never touch the
        manager, so they replay their bookkeeping inside the attribution
        loop at the exact per-token clock."""
        i, n = 0, len(order)
        while i < n:
            req = order[i]
            if req.tokens_done >= req.n_tokens:
                # zero-token (or raced-complete) request: retire without
                # a decode — and, as in the per-token loop, without the
                # post-token ingest/admit step
                self._retire(req, active, done)
                i += 1
                continue
            block: list[Request] = []
            j = i
            while j < n:
                r = order[j]
                if r.tokens_done >= r.n_tokens:
                    break
                block.append(r)
                j += 1
                if r.tokens_done + 1 >= r.n_tokens:
                    break              # finisher: retire/admit next
                if self.policy == "svm_aware" and waiting:
                    break              # pending arrivals may pin mid-round
            self._run_block_fused(block, queued, active, done, ingest)
            i = j

    # ------------------------------------------- vectorized window tier

    def _window_rounds(self, order: list[Request], waiting,
                       queued: "deque[Request]") -> int:
        """How many *whole rounds* beyond this one can fuse into a single
        multi-round window pass — the count ``r`` such that rounds
        1..r are provably identical replays of the same segment tuple
        with every between-token bookkeeping step a no-op:

          * no pending arrival can ingest mid-window (``waiting`` empty),
          * the admission queue cannot move: empty, or (non-fifo) its
            head fails the working-set watermark check — admitted bytes
            and pool capacity are both constant inside a window, so the
            check's outcome is constant too (fifo admits on the backoff
            gate alone, which expiring mid-window would flip),
          * the thrash guard is off (it samples eviction counters at
            every round boundary and may preempt),
          * no member finishes inside the window (a retirement unpins
            and re-admits — the finisher round runs on the block tier),
          * no chaos event falls due inside the window (the injector
            keys off the token counter; the window decodes
            ``r × len(order)`` tokens).

        Returns 0 when no multi-round window applies (callers then run
        the normal one-round block tier)."""
        if waiting or self.thrash_watermark is not None:
            return 0
        if queued and (self.policy == "fifo"
                       or self._fits(queued[0].spec)):
            return 0
        r = min(q.n_tokens - q.tokens_done for q in order) - 1
        if r < 2:
            return 0
        if self.injector is not None:
            nxt = self.injector.next_at()
            if math.isfinite(nxt):
                # every round i in the window must satisfy the per-round
                # fused gate: next_at > tokens_total + (i+1)*K
                r = min(r, int(nxt - self._tokens_total - 1)
                        // len(order))
        return r if r >= 2 else 0

    def _run_window_fused(self, order: list[Request], r: int,
                          queued: "deque[Request]", active: list[Request],
                          done: list[Request], ingest) -> None:
        """Replay ``r`` identical scheduler rounds in **one**
        `execute_fused` pass over the round mega-trace tiled ``r`` times,
        with all per-request bookkeeping done as NumPy column operations
        over the (round × request) cut table.

        Byte-identity with the per-token oracle: the tiled trace executes
        bit-identically to ``r`` back-to-back mega replays (the engine's
        resumability contract), the wall/`now` trajectories are exact
        seeded ``np.cumsum`` folds in the oracle's add order (column-wise
        per request, flat for the shared clock), and the integer counters
        attribute through exact cut-row differences.  Session counters
        bump by the closed forms of what the per-round loop would do:
        round 1's fetch runs for real, rounds 2..r are per-session LRU
        hits."""
        segs = self._fetch_segments(order)
        if len(segs) == 1:
            mega = segs[0]
            cuts1 = np.array([len(mega)], dtype=np.int64)
        else:
            mega = self._concat_round(segs)
            cuts1 = mega.seg_bounds[1:]
        if self._fused_diverged(segs, mega, cuts1):
            # same degradation as the block tier's round 1: golden
            # per-token fallback, then let the outer loop re-evaluate
            self._fused_fallback(order, len(segs), queued, active, done,
                                 ingest)
            return
        K = len(order)
        window = mega.tile(r)
        cuts = window.seg_bounds[1:]
        m = self.mgr
        prev_w = m.wall
        prev_c = np.array([m.n_migrations, m.n_evictions,
                           m.bytes_migrated, m.bytes_evicted],
                          dtype=np.int64)
        snaps = execute_fused(window, m, cuts)
        live = np.array([m.wall, float(m.n_migrations),
                         float(m.n_evictions), float(m.bytes_migrated),
                         float(m.bytes_evicted)])
        if not np.array_equal(snaps[-1], live):
            # post-hoc reconciliation guard, as in the block tier
            self.incidents.append(
                f"tok={self._tokens_total} fused reconciliation: final "
                f"cut row != live counters — residual charged to "
                f"req={order[-1].req_id}")
            snaps = snaps.copy()
            snaps[-1] = live
        # request-table attribution: column k of the (r, K) delta matrix
        # is request k's per-round charge stream
        walls = snaps[:, 0]
        dws = np.diff(walls, prepend=prev_w)
        now_traj = np.cumsum(np.concatenate(([self.now], dws)))
        seeds = np.array([q.svm_wall_s for q in order])
        wall_fin = np.cumsum(
            np.vstack((seeds, dws.reshape(r, K))), axis=0)[-1]
        cdiff = np.diff(snaps[:, 1:].astype(np.int64), axis=0,
                        prepend=prev_c[None, :])
        csum = cdiff.reshape(r, K, 4).sum(axis=0)
        first_tok = now_traj[1:K + 1]
        for k, req in enumerate(order):
            req.svm_wall_s = float(wall_fin[k])
            req.migrations += int(csum[k, 0])
            req.evictions += int(csum[k, 1])
            req.bytes_migrated += int(csum[k, 2])
            req.bytes_evicted += int(csum[k, 3])
            sess = req.session
            sess.cache_hits += r - 1
            sess.segments_replayed += r
            sess.ops_replayed += r * len(segs[k])
            if req.tokens_done == 0:
                req.first_token_s = float(first_tok[k])
            req.tokens_done += r
        self._tokens_total += r * K
        self.now = float(now_traj[-1])

    def _fused_fallback(self, block: list[Request], n_segs: int,
                        queued: "deque[Request]", active: list[Request],
                        done: list[Request], ingest) -> None:
        """Golden per-token replay of one diverged fused block, with the
        incident logged."""
        self._chaos["fused_fallbacks"] += 1
        self.incidents.append(
            f"tok={self._tokens_total} fused divergence: cut prefix "
            f"sums != segment totals ({n_segs}-segment block) — "
            f"per-token fallback")
        for req in block:
            self._decode_token(req)
            if req.tokens_done >= req.n_tokens:
                self._retire(req, active, done)
            ingest()
            self._admit(queued, active)

    @staticmethod
    def _fused_diverged(segs: list, mega, cuts) -> bool:
        """Structural cross-check before a fused pass: the cut prefix
        sums must reproduce the member segment op totals exactly and the
        last cut must cover the whole mega-trace."""
        if len(cuts) != len(segs):
            return True
        if len(segs) == 1:
            return int(cuts[0]) != len(segs[0]) or len(mega) != len(segs[0])
        bounds = np.concatenate(
            [np.zeros(1, dtype=np.int64), np.asarray(cuts, np.int64)])
        expected = np.asarray([len(s) for s in segs], dtype=np.int64)
        return (int(bounds[-1]) != len(mega)
                or not np.array_equal(np.diff(bounds), expected))

    def _run_block_fused(self, block: list[Request],
                         queued: "deque[Request]", active: list[Request],
                         done: list[Request], ingest) -> None:
        """Replay one block's concatenated segments in a single
        `execute_fused` pass and attribute the per-request counter deltas
        from the sampled cut rows — the same floats/ints the per-token
        loop reads from the manager between replays."""
        segs = self._fetch_segments(block)
        if len(segs) == 1:
            mega = segs[0]
            cuts = np.array([len(mega)], dtype=np.int64)
        else:
            mega = self._concat_round(segs)
            cuts = mega.seg_bounds[1:]
        if self._fused_diverged(segs, mega, cuts):
            # fused-divergence guard: the concatenated round's cut
            # prefix sums disagree with the member segment totals.
            # Nothing has executed yet, so fall back to the golden
            # per-token path for this block — no double charge.
            self._fused_fallback(block, len(segs), queued, active, done,
                                 ingest)
            return
        m = self.mgr
        prev_w = m.wall
        prev_c = [m.n_migrations, m.n_evictions,
                  m.bytes_migrated, m.bytes_evicted]
        snaps = execute_fused(mega, m, cuts)
        live = np.array([m.wall, float(m.n_migrations),
                         float(m.n_evictions), float(m.bytes_migrated),
                         float(m.bytes_evicted)])
        if not np.array_equal(snaps[-1], live):
            # fused-divergence guard, post-hoc half: the final sampled
            # cut must equal the live counters; fold any residual into
            # the last member's row so conservation stays exact
            self.incidents.append(
                f"tok={self._tokens_total} fused reconciliation: final "
                f"cut row != live counters — residual charged to "
                f"req={block[-1].req_id}")
            snaps = snaps.copy()
            snaps[-1] = live
        if len(block) == 1:
            # unit block (finisher/admission rounds): scalar attribution
            # without the array round-trips
            walls = [float(snaps[0, 0])]
            counts = [[int(snaps[0, 1]), int(snaps[0, 2]),
                       int(snaps[0, 3]), int(snaps[0, 4])]]
        else:
            walls = snaps[:, 0].tolist()
            counts = snaps[:, 1:].astype(np.int64).tolist()
        for k, req in enumerate(block):
            w, c = walls[k], counts[k]
            dw = w - prev_w
            req.svm_wall_s += dw
            req.migrations += c[0] - prev_c[0]
            req.evictions += c[1] - prev_c[1]
            req.bytes_migrated += c[2] - prev_c[2]
            req.bytes_evicted += c[3] - prev_c[3]
            self.now += dw
            prev_w, prev_c = w, c
            sess = req.session
            sess.segments_replayed += 1
            sess.ops_replayed += len(segs[k])
            req.tokens_done += 1
            self._tokens_total += 1
            if req.tokens_done == 1:
                req.first_token_s = self.now
            if req.tokens_done >= req.n_tokens:
                self._retire(req, active, done)
            ingest()
            self._admit(queued, active)

    def _retire(self, req: Request, active: list[Request],
                done: list[Request]) -> None:
        if req.pinned_rids:
            # release app-directed placement; the ranges rejoin the
            # eviction policy and age out under other tenants' pressure
            self._replay_attributed(
                req, lambda: self._run_pin_segment(req, "unpin",
                                                   req.pinned_rids))
            self.pinned_bytes_total -= req.pinned_bytes
        req.finish_s = self.now
        self.admitted_bytes -= self._admit_cost(req.spec)
        active.remove(req)
        done.append(req)

    def _run_round_tokenwise(self, order: list[Request],
                             queued: "deque[Request]",
                             active: list[Request], done: list[Request],
                             ingest) -> None:
        """One scheduler round on the golden per-token path — the
        non-fused tier, and the fused tier's degradation target whenever
        a chaos hazard is live or due this round."""
        for req in order:
            if req not in active:
                continue   # crashed/preempted out earlier this round
            if req.tokens_done >= req.n_tokens:
                # zero-token (or raced-complete) request: retire it
                # here, not via a decode, or the loop never drains
                self._retire(req, active, done)
                continue
            if self.injector is not None and \
                    self._chaos_step(req, queued, active):
                # a crash consumed this request's turn — no token
                ingest()
                self._admit(queued, active)
                continue
            try:
                self._decode_token(req)
            except RetryError as e:
                # retry budget exhausted: the request is dropped, its
                # charged work stays on its row (conservation)
                self._chaos["retry_exhausted"] += 1
                self.incidents.append(
                    f"tok={self._tokens_total} req={req.req_id} retry "
                    f"budget exhausted after {e.attempts} attempts — "
                    f"request dropped")
                self._evacuate(req, active, queued, requeue=False)
            else:
                if req.tokens_done >= req.n_tokens:
                    self._retire(req, active, done)
            # arrivals during this token can be admitted mid-round;
            # they join the next round's order
            ingest()
            self._admit(queued, active)

    # --------------------------------------------------------------- run

    def _idle_advance(self, waiting: "deque[Request]",
                      queued: "deque[Request]") -> None:
        """Pool idle: fast-forward to the next arrival or the queue
        head's re-admission backoff gate, whichever is sooner.  (The
        gate matters: with every arrival drained and the head waiting
        out a crash/preemption backoff, the old arrival-only
        fast-forward had nothing to index.)"""
        nxt = math.inf
        if waiting:
            nxt = min(nxt, waiting[0].arrival_s)
        if queued:
            nxt = min(nxt, queued[0].not_before_s)
        if math.isfinite(nxt):
            self.now = max(self.now, nxt)

    def run(self, requests: Sequence[Request]) -> dict:
        """Drive every request to completion; returns the report dict."""
        waiting = deque(sorted(requests,
                               key=lambda r: (r.arrival_s, r.req_id)))
        queued: "deque[Request]" = deque()
        active: list[Request] = []
        done: list[Request] = []
        eps = 1e-12

        def ingest() -> None:
            while waiting and waiting[0].arrival_s <= self.now + eps:
                queued.append(waiting.popleft())

        while waiting or queued or active:
            ingest()
            self._admit(queued, active)
            if not active:
                self._idle_advance(waiting, queued)
                continue
            self._thrash_check(active, queued)
            if not active:   # pragma: no cover — guard preempts ≤ N-1
                continue
            order = self._round_order(active)
            if self.fused and not self._chaos_round_pending(order):
                r = self._window_rounds(order, waiting, queued)
                if r:
                    self._run_window_fused(order, r, queued, active,
                                           done, ingest)
                else:
                    self._run_round_fused(order, waiting, queued, active,
                                          done, ingest)
                continue
            if self.fused:
                # hazard live/due: degrade this round to per-token
                self._chaos["degraded_rounds"] += 1
            self._run_round_tokenwise(order, queued, active, done,
                                      ingest)
        return self._result(done)

    # ------------------------------------------------------------ report

    def _result(self, done: list[Request]) -> dict:
        done = sorted(done, key=lambda r: r.req_id)
        failed = sorted(self.failed, key=lambda r: r.req_id)
        # conservation spans everything that consumed pool work —
        # dropped requests keep their charged rows
        accounted = done + failed
        decoded = [r for r in done if r.tokens_done > 0]
        lat = np.array([r.latency_s for r in done])
        ttft = np.array([r.first_token_s - r.arrival_s for r in decoded])
        waits = np.array([r.queue_wait_s for r in done])

        def pct(arr: np.ndarray, q: float) -> float:
            return float(np.percentile(arr, q)) if len(arr) else 0.0
        total_tokens = sum(r.tokens_done for r in done)
        offered = sum(r.spec.total_bytes for r in done)
        m = self.mgr
        seg_local_hits = sum(s.cache_hits for s in self._sessions)
        seg_shared_hits = sum(s.shared_hits for s in self._sessions)
        seg_misses = sum(s.cache_misses for s in self._sessions)
        lookups = seg_local_hits + seg_shared_hits + seg_misses
        chaos = dict(self._chaos)
        chaos["admit_watermark_final"] = self.admit_watermark
        if self.injector is not None:
            chaos["injector"] = self.injector.stats()
        return {
            "policy": self.policy,
            "admit_by": self.admit_by,
            "fused": self.fused,
            "capacity_bytes": self.capacity,
            "n_requests": len(done),
            "peak_active_requests": self.peak_active_requests,
            "profile_cache": self._profile_cache.stats(),
            "total_tokens": total_tokens,
            "makespan_s": self.now,
            "agg_tok_s": total_tokens / self.now if self.now else 0.0,
            "latency_p50_s": pct(lat, 50),
            "latency_p90_s": pct(lat, 90),
            "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p99_s": pct(ttft, 99),
            "queue_wait_mean_s": float(waits.mean()) if len(waits) else 0.0,
            "dos_offered": offered / self.capacity * 100.0,
            "dos_peak": self.peak_admitted_bytes / self.capacity * 100.0,
            "migrations": m.n_migrations,
            "evictions": m.n_evictions,
            "evict_to_mig": m.evict_to_mig_ratio,
            "evictions_per_token": (m.n_evictions / total_tokens
                                    if total_tokens else 0.0),
            "segment_hit_rate": ((seg_local_hits + seg_shared_hits)
                                 / lookups if lookups else 0.0),
            "segment_local_hits": seg_local_hits,
            "segment_shared_hits": seg_shared_hits,
            "segment_misses": seg_misses,
            "shared_cache": {**self.shared_cache.stats(),
                             "concat_memo_entries": len(self._concat_memo),
                             "concat_memo_evictions":
                                 self._concat_evictions},
            "requests": [r.row() for r in done],
            "n_failed": len(failed),
            "failed_requests": [r.row() for r in failed],
            "incidents": list(self.incidents),
            "chaos": chaos,
            "conservation": {
                "svm_wall_s": sum(r.svm_wall_s for r in accounted),
                "migrations": sum(r.migrations for r in accounted),
                "evictions": sum(r.evictions for r in accounted),
                "bytes_migrated": sum(r.bytes_migrated
                                      for r in accounted),
                "bytes_evicted": sum(r.bytes_evicted for r in accounted),
            },
            "mgr": m.summary(),
        }


def run_schedule(specs: Sequence[ModelSpec], n_requests: int,
                 capacity_bytes: int, *, policy: str = "svm_aware",
                 seed: int = 0, mean_interarrival_s: float = 0.0,
                 arrival: str = "poisson", tokens: int = 32,
                 token_jitter: int = 0, spec_choice: str = "random",
                 **scheduler_kw) -> dict:
    """Build a seeded request mix and run it through a fresh
    `PoolScheduler` — the one-call entry point for benchmarks, figures,
    and the serving CLI."""
    reqs = make_requests(specs, n_requests, seed=seed,
                         mean_interarrival_s=mean_interarrival_s,
                         arrival=arrival, tokens=tokens,
                         token_jitter=token_jitter,
                         spec_choice=spec_choice)
    sched = PoolScheduler(capacity_bytes, policy=policy, **scheduler_kw)
    return sched.run(reqs)
