"""Streaming executor: serve a model whose weights exceed the HBM budget.

Weights live host-side (numpy); a fixed-size device pool holds the resident
ranges. Each layer's weight fetch drives the SVMManager (faults -> range
migrations -> LRF/Clock/LRU evictions, with the paper's cost model supplying
the simulated clock), while the math itself runs for real, so correctness
and policy behaviour are validated together.

Streaming modes map the paper's findings onto serving:
  * naive        — demand-fetch in layer order; under oversubscription LRF
                   evicts the *earliest-fetched* layers, which are exactly
                   the ones the next token needs first: the decode loop is
                   Jacobi2d's cyclic-traversal pathology (Category II/III).
  * svm_aware    — pin the hottest leaves (embeddings + head: touched twice
                   per token) and prefetch the next layer overlapped with
                   compute (paper §4.1 pinning + §4.2 parallel eviction).
  * zero_copy    — leave designated cold leaves host-resident at remote-
                   access cost (paper §4.2).

The executor never drives the manager's `touch`/`advance`/`pin` methods
directly: every access is **recorded** into a `repro.core.engine.
TraceSession`, compiled into op-column segments, and **replayed** on the
batched engine (`scalar=True` replays the same segments op-for-op — the
imperative reference path, byte-identical by the engine's equivalence
guarantee).  `decode_step` is the serving hot path: the whole token's
layer-fetch trace seals into cached segments on the first token and
replays as compiled columns every later token (the session counts the
cache hits), which is what moves serving onto the ≥5x fast tier.

Device-pool invalidation is push-based: the executor registers an eviction
listener on the `SVMManager`, and evicted rids map back to their leaf via
the plan's rid→leaf reverse index.  Each fetch therefore does O(ranges of
the fetched leaf + leaves actually evicted since the last drain) work.
Hidden prefetch overlap is tracked in a separate ``overlap_hidden_s``
ledger (subtracted in `metrics()`), never by rewinding the manager's wall
clock, so recorded `Event.t` timestamps stay monotonic.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.core.engine import CompiledTrace, TraceSession
from repro.svm.hotset import HotSetProfile, token_trace
from repro.svm.planner import ParamRanges, plan_param_ranges

PyTree = Any

#: streaming prefetch policies (docs/prefetching.md):
#:   none       — pure demand paging
#:   aggressive — stage every next layer (the paper's default; thrashes
#:                under oversubscription)
#:   measured   — profile the first token's touch columns and pin only
#:                leaves above the touch-frequency threshold
PREFETCH_MODES = ("none", "aggressive", "measured")


class StreamingExecutor:
    """Serve a model whose weights exceed the HBM budget by streaming
    parameter leaves through a managed device pool (see module
    docstring): real tensors + simulated SVM placement, every access
    recorded and replayed as compiled session segments."""

    def __init__(self, params: PyTree, hbm_budget: int, *,
                 policy: str = "lrf",
                 cost_params: CostParams = TPU_V5E_HOST,
                 parallel_evict: bool = False,
                 prefetch: bool = False,
                 prefetch_mode: str | None = None,
                 hot_threshold: float = 2.0,
                 hot_frac: float = 0.5,
                 pin: tuple[str, ...] = (),
                 zero_copy: tuple[str, ...] = (),
                 concurrency: int = 64,
                 compute_rate: float | None = None,
                 profile: bool = True,
                 scalar: bool = False,
                 plan: ParamRanges | None = None,
                 manager: Any | None = None,
                 shared_cache: Any | None = None):
        self.host_params = jax.tree.map(np.asarray, params)
        # shared-pool mode: an externally planned `plan` (offset into a
        # shared AddressSpace) plus the pool's shared `manager` lets this
        # executor co-tenant one device pool with other executors /
        # scheduler requests; `shared_cache` (a core SegmentCache) then
        # shares compiled segments between congruent tenants
        self.plan = plan if plan is not None \
            else plan_param_ranges(params, hbm_budget)
        # profile=False for long-lived serving loops: per-event
        # Event/DensitySample records grow without bound, one per
        # migration/eviction per token
        self.mgr = manager if manager is not None \
            else self.plan.manager(policy=policy, params=cost_params,
                                   parallel_evict=parallel_evict,
                                   profile=profile)
        # serving compute rate: from the cost model unless overridden
        self.compute_rate = (compute_rate if compute_rate is not None
                             else cost_params.serve_flops)
        # prefetch policy: the bool flag keeps its historical meaning
        # (True == "aggressive"); `prefetch_mode` supersedes it when set
        if prefetch_mode is None:
            prefetch_mode = "aggressive" if prefetch else "none"
        if prefetch_mode not in PREFETCH_MODES:
            raise ValueError(f"unknown prefetch_mode {prefetch_mode!r}; "
                             f"available: {PREFETCH_MODES}")
        self.prefetch_mode = prefetch_mode
        self.prefetch = prefetch_mode == "aggressive"
        # measured mode: hot = touched >= hot_threshold times per token,
        # pinned bytes bounded to hot_frac of the pool (deadlock guard)
        self.hot_threshold = float(hot_threshold)
        self.hot_frac = float(hot_frac)
        self.hot_profile: HotSetProfile | None = None
        self.measured_hot_leaves: tuple[str, ...] = ()
        self.measured_hot_bytes = 0
        self._measured_done = prefetch_mode != "measured"
        self.concurrency = concurrency
        # every manager access goes through the session: record -> compile
        # segments -> replay (batched engine, or op-for-op when scalar).
        # LRU sized to hold several whole decode steps: prefetch mode keys
        # ~2 segments per leaf per token, and an undersized cache would
        # recompile every token instead of replaying
        self.session = TraceSession(
            self.mgr, scalar=scalar,
            cache_size=max(64, 4 * len(self.plan.leaf_ranges)),
            shared_cache=shared_cache, rid_base=self.plan.rid_base)
        # shared-cache key namespace: segment relocation is only sound
        # between congruent tenants, so keys carry a fingerprint of the
        # plan geometry + touch concurrency — co-tenant executors of
        # *different* models (even with identical leaf path names) can
        # never alias each other's segments
        self._seg_ns = (hash((self.plan.geometry(), concurrency))
                        if shared_cache is not None else None)
        self._device: dict[str, jnp.ndarray] = {}
        self._flat = dict(self._leaves(self.host_params))
        self._zc_leaves: set[str] = set()
        for pat in zero_copy:
            for path, rids in self.plan.leaf_ranges.items():
                if pat in path:
                    aid = self.plan.space.ranges[rids[0]].alloc_id
                    self.mgr.set_zero_copy(aid)
                    self._zc_leaves.add(path)
        # compute-time ledger (simulated clock shares the SVM manager wall)
        self.compute_flops = 0.0
        # prefetch hidden behind compute: separate ledger, never a wall
        # rewind (keeps Event.t monotonic)
        self.overlap_hidden_s = 0.0
        # push-based pool invalidation (O(1) per eviction, not per fetch)
        self._pending_evictions: deque[int] = deque()
        self.mgr.add_evict_listener(self._pending_evictions.append)
        # double-buffered next-layer prefetch queue
        self._prefetch_q: deque[tuple[str, float]] = deque()
        # fused multi-token replay: memoised concatenation of one step
        # segment repeated N times (`decode_steps`); identity-keyed with
        # a strong segment ref so the id stays valid while memoised
        self._steps_memo: "OrderedDict[tuple, CompiledTrace]" = \
            OrderedDict()
        # instrumentation: units of invalidation work done by fetches
        # (range touches + evicted-leaf drops); regression-tested to be
        # O(ranges of fetched leaf + actual evictions), not O(all leaves)
        self.fetch_scan_work = 0
        self._step_scan: dict = {}   # step key -> demand-fetch scan units
        # app-directed placement rides the session too (OP_PIN boundary
        # ops migrate-then-pin exactly like the scalar mgr.pin path)
        pinned = [rid for pat in pin
                  for path, rids in self.plan.leaf_ranges.items()
                  if pat in path for rid in rids]
        if pinned:
            for rid in pinned:
                self.session.pin(rid)
            self.session.flush(("setup_pin", tuple(pinned)))

    @staticmethod
    def _leaves(tree: PyTree):
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
            path = "/".join(
                getattr(k, "key", getattr(k, "name", str(k))) for k in kp)
            yield path, leaf

    # ----------------------------------------------------------- fetching

    def _key(self, key):
        """Session segment key, namespaced when a shared cache is wired
        (see ``_seg_ns`` above)."""
        return key if self._seg_ns is None else (self._seg_ns, key)

    def _record_leaf(self, path: str) -> None:
        for rid in self.plan.leaf_ranges[path]:
            self.session.touch(rid, concurrency=self.concurrency)

    def _leaf_resident(self, path: str) -> bool:
        """Would a fetch of this leaf hit?  Zero-copy leaves always do;
        managed leaves hit iff every range is resident (no touch can then
        migrate or evict, so pre- and per-touch residency coincide)."""
        if path in self._zc_leaves:
            return True
        resident = self.mgr.resident
        return all(rid in resident for rid in self.plan.leaf_ranges[path])

    def fetch(self, path: str) -> jnp.ndarray:
        """Touch a leaf's ranges (demand paging) and return the tensor.

        Any leaves staged in the prefetch buffer are issued first (their
        migration cost was overlappable with the *previous* layer's
        compute window), so this fetch usually hits.  The touches replay
        as a cached compiled segment (one compile per leaf per session).
        """
        if self._prefetch_q:
            self.drain_prefetch()
        resident_before = self._leaf_resident(path)
        self.session.run(self._key(("fetch", path)),
                         lambda s: self._record_leaf(path))
        self.fetch_scan_work += len(self.plan.leaf_ranges[path])
        if not resident_before or path not in self._device:
            tensor = self._device[path] = jnp.asarray(self._flat[path])
        else:
            tensor = self._device[path]
        # drain after caching: a leaf larger than the pool evicts its own
        # earlier ranges mid-fetch and must fall straight back out of the
        # pool (the tensor itself is still returned for this use)
        self._drain_evictions()
        return tensor

    def prefetch_leaf(self, path: str, overlap_s: float) -> None:
        """Issue next-layer migrations overlapped with current compute
        (paper §4.2 'parallel implementation'): up to `overlap_s` of the
        migration cost is hidden (ledgered, not rewound)."""
        w0 = self.mgr.wall
        self.session.run(self._key(("fetch", path)),
                         lambda s: self._record_leaf(path))
        self.overlap_hidden_s += min(self.mgr.wall - w0, overlap_s)
        self._drain_evictions()

    def queue_prefetch(self, paths: list[str], overlap_s: float) -> None:
        """Stage the next layer's leaves in the prefetch buffer (double
        buffering: at most one upcoming layer is staged at a time; the
        buffer is consumed by the next `fetch`, or an explicit
        `drain_prefetch`)."""
        self._prefetch_q.clear()
        self._prefetch_q.extend((p, overlap_s) for p in paths)

    def drain_prefetch(self) -> None:
        while self._prefetch_q:
            path, overlap_s = self._prefetch_q.popleft()
            self.prefetch_leaf(path, overlap_s)

    def _drain_evictions(self) -> None:
        """Drop device tensors for leaves whose ranges were evicted since
        the last drain — pushed by the manager, O(#evictions)."""
        pending = self._pending_evictions
        if not pending:
            return
        rid_to_leaf = self.plan.rid_to_leaf
        device = self._device
        for rid in pending:
            leaf = rid_to_leaf.get(rid)
            if leaf is not None and device.pop(leaf, None) is not None:
                self.fetch_scan_work += 1
        pending.clear()

    def charge_compute(self, flops: float) -> None:
        self.compute_flops += flops
        seconds = flops / self.compute_rate
        self.session.run(self._key(("compute", seconds)),
                         lambda s: s.compute(seconds))

    def tensor(self, path: str) -> jnp.ndarray:
        """The leaf's tensor for compute: the cached device copy when the
        pool holds it, else a fresh host materialisation (values are
        identical either way — the pool is a placement model)."""
        t = self._device.get(path)
        return t if t is not None else jnp.asarray(self._flat[path])

    # ------------------------------------------------- measured prefetch

    def _measured_setup(self, layer_paths: Sequence[Sequence[str]]) -> None:
        """First-decode measured-prefetch setup (docs/prefetching.md).

        One token's fetch schedule is lowered to touch columns (pure —
        no manager is driven) and profiled; leaves touched at least
        ``hot_threshold`` times per token are the measured hot set.
        Those leaves — byte-bounded to ``hot_frac`` of the pool, largest
        frequency first, and never a leaf that would monopolise half the
        pool — are migrated once and pinned via the session (OP_PIN
        boundary ops, so scalar and batched replays stay byte-identical).
        Everything else demand-pages: the measured policy prefetches
        only what the touch columns prove is reused."""
        if self._measured_done:
            return
        self._measured_done = True
        plan = self.plan
        ct = token_trace(plan.leaf_ranges, layer_paths,
                         concurrency=self.concurrency, tokens=1)
        size_arr = np.asarray([r.end - r.start
                               for r in plan.space.ranges], dtype=np.int64)
        prof = HotSetProfile.from_trace(ct, size_arr,
                                        rid_base=plan.rid_base)
        self.hot_profile = prof
        freq = dict(zip(prof.rids.tolist(), prof.freq.tolist()))
        cand = []
        for path, rids in plan.leaf_ranges.items():
            f = freq.get(rids[0] - plan.rid_base, 0)
            nbytes = plan.leaf_bytes[path]
            if f >= self.hot_threshold and nbytes <= self.mgr.capacity // 2:
                cand.append((-f, path, nbytes, rids))
        cand.sort()                      # frequency desc, then fetch order
        budget = self.hot_frac * self.mgr.capacity
        picked: list[str] = []
        pinned_rids: list[int] = []
        total = 0
        for _, path, nbytes, rids in cand:
            if total + nbytes > budget:
                continue
            total += nbytes
            picked.append(path)
            pinned_rids.extend(rids)
        if pinned_rids:
            for rid in pinned_rids:
                self.session.pin(rid)
            self.session.flush(("measured_pin", tuple(pinned_rids)))
        self.measured_hot_leaves = tuple(picked)
        self.measured_hot_bytes = total

    # --------------------------------------------------- decode hot path

    def decode_step(self, layer_paths: Sequence[Sequence[str]],
                    flops: Sequence[float], *,
                    materialize: bool = True) -> None:
        """Replay one decode step's layer-fetch trace as compiled segments.

        Emits exactly the op sequence the imperative per-fetch path
        produces — per layer: staged prefetch touches (with their
        per-leaf hidden-overlap ledger), demand touches, one compute op —
        but sealed into session segments: the first token records and
        compiles them, every later token replays the cached columns
        (`session.cache_hits` counts the reuse).  Without prefetch the
        whole step is **one** segment — one batched span per token.

        ``materialize=False`` skips device-pool upkeep (metrics-only
        simulation, e.g. riding along a real serving loop)."""
        self._measured_setup(layer_paths)
        n = len(layer_paths)
        rate = self.compute_rate
        secs = tuple(f / rate for f in flops)
        paths_sig = tuple(map(tuple, layer_paths))
        if self.prefetch:
            for i in range(n):
                if i > 0:
                    # layer i was staged during layer i-1's compute window
                    budget = secs[i - 1]
                    for p in layer_paths[i]:
                        self.prefetch_leaf(p, budget)
                key = self._key(("layer", i, tuple(layer_paths[i]),
                                 secs[i]))

                def rec(s, i=i):
                    for p in layer_paths[i]:
                        self._record_leaf(p)
                    s.compute(secs[i])

                self.session.run(key, rec)
        else:
            key = self._key(("step", paths_sig, secs))

            def rec(s):
                for i in range(n):
                    for p in layer_paths[i]:
                        self._record_leaf(p)
                    s.compute(secs[i])

            self.session.run(key, rec)
        self.compute_flops += float(sum(flops))
        # demand-fetch scan units, memoised per step *shape* (flops don't
        # matter, so per-token-varying flops can't grow the memo; bounded
        # anyway so a long-lived server with churning schedules can't leak)
        scan = self._step_scan.get(paths_sig)
        if scan is None:
            if len(self._step_scan) >= 256:
                self._step_scan.clear()
            scan = sum(len(self.plan.leaf_ranges[p])
                       for paths in layer_paths for p in paths)
            self._step_scan[paths_sig] = scan
        self.fetch_scan_work += scan
        self._drain_evictions()
        if materialize:
            for paths in layer_paths:
                for p in paths:
                    if p not in self._device and self._leaf_resident(p):
                        self._device[p] = jnp.asarray(self._flat[p])

    def decode_steps(self, layer_paths: Sequence[Sequence[str]],
                     flops: Sequence[float], steps: int, *,
                     materialize: bool = True) -> None:
        """Replay ``steps`` identical decode steps in one fused pass.

        The per-token segment (same cache key as `decode_step`'s
        non-prefetch path) is fetched once and concatenated ``steps``
        times into a mega-trace — segment replays resume from the
        manager's live state, so back-to-back replay and concatenated
        replay are bit-identical (`TraceSession` contract) — then
        executed in a single batched-interpreter pass: one span walk for
        the whole token run instead of ``steps`` engine round-trips.

        Prefetch mode interleaves per-leaf overlap ledgering between
        segments and the scalar session is the op-for-op golden
        reference, so both fall back to the `decode_step` loop."""
        if steps <= 0:
            return
        self._measured_setup(layer_paths)
        if self.prefetch or self.session.scalar or steps == 1:
            for _ in range(steps):
                self.decode_step(layer_paths, flops,
                                 materialize=materialize)
            return
        n = len(layer_paths)
        rate = self.compute_rate
        secs = tuple(f / rate for f in flops)
        paths_sig = tuple(map(tuple, layer_paths))
        key = self._key(("step", paths_sig, secs))

        def rec(s):
            for i in range(n):
                for p in layer_paths[i]:
                    self._record_leaf(p)
                s.compute(secs[i])

        ct = self.session.fetch(key, rec)
        mkey = (id(ct), int(steps))
        hit = self._steps_memo.get(mkey)
        if hit is not None and hit[0] is ct:
            self._steps_memo.move_to_end(mkey)
            mega = hit[1]
        else:
            segs = [ct] * steps
            mega = (self.session.shared_cache.concat(segs)
                    if self.session.shared_cache is not None
                    else CompiledTrace.concat(segs))
            self._steps_memo[mkey] = (ct, mega)
            while len(self._steps_memo) > 8:
                self._steps_memo.popitem(last=False)
        self.session.replay(mega)
        # account the fused pass as the per-step loop would: `steps`
        # segment replays (ops_replayed already covers the mega length)
        self.session.segments_replayed += steps - 1
        self.compute_flops += float(sum(flops)) * steps
        scan = self._step_scan.get(paths_sig)
        if scan is None:
            if len(self._step_scan) >= 256:
                self._step_scan.clear()
            scan = sum(len(self.plan.leaf_ranges[p])
                       for paths in layer_paths for p in paths)
            self._step_scan[paths_sig] = scan
        self.fetch_scan_work += scan * steps
        self._drain_evictions()
        if materialize:
            for paths in layer_paths:
                for p in paths:
                    if p not in self._device and self._leaf_resident(p):
                        self._device[p] = jnp.asarray(self._flat[p])

    # ------------------------------------------------------------ metrics

    def metrics(self) -> dict:
        s = self.mgr.summary()
        s["wall_s"] = self.mgr.wall - self.overlap_hidden_s
        s["overlap_hidden_s"] = self.overlap_hidden_s
        s["dos"] = self.plan.dos()
        s["compute_flops"] = self.compute_flops
        s["prefetch_mode"] = self.prefetch_mode
        s["measured_hot_bytes"] = self.measured_hot_bytes
        s.update(self.session.stats())
        return s


def run_layer_stream(
    executor: StreamingExecutor,
    layer_paths: list[list[str]],
    apply_layer: Callable[[int, dict[str, jnp.ndarray]], float],
    *,
    steps: int = 1,
) -> dict:
    """Drive a layer-ordered streaming pass `steps` times (decode loop).

    `layer_paths[i]` lists the param-leaf paths layer i needs;
    `apply_layer(i, tensors)` runs the math and returns its FLOPs.  The
    math runs every step (tensor values never depend on placement); the
    step's SVM trace replays through `decode_step` — compiled once on the
    first step, cached-segment replays after.
    """
    n = len(layer_paths)
    for _ in range(steps):
        flops = []
        for i in range(n):
            tensors = {p: executor.tensor(p) for p in layer_paths[i]}
            flops.append(apply_layer(i, tensors))
        executor.decode_step(layer_paths, flops)
    return executor.metrics()
