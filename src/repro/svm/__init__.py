"""Executable SVM runtime: range-granular host<->HBM streaming for
oversubscribed serving (weight streaming) and training (activation
offload), driven by the paper's range/fault/eviction model."""

from repro.svm.planner import ParamRanges, plan_param_ranges
from repro.svm.executor import StreamingExecutor, run_layer_stream
from repro.svm.offload import (
    OffloadPlan,
    plan_offload,
    record_offload,
    simulate_offload,
)

__all__ = ["plan_param_ranges", "ParamRanges", "StreamingExecutor",
           "run_layer_stream", "OffloadPlan", "plan_offload",
           "record_offload", "simulate_offload"]
