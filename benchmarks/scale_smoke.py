"""Scale smoke: the 1024-request vectorized schedule, end to end.

CI gate for the vectorized scheduler core (docs/architecture.md): the
`scheduler_scale` bench configuration — 1024 requests over two repeated
architectures, burst arrival, ~2.3M replayed ops — runs on the
vectorized tier.  The run must

  * complete every request (no failures, full token budget decoded)
    inside a generous host wall budget — a hung window loop or a
    quadratic regression blows the budget long before CI times out,
  * actually engage the multi-round window tier (>= 1 window pass
    covering >= 2 rounds each) — otherwise the smoke would measure the
    per-round regime and silently stop covering the window code path,
  * be **byte-identical** to the per-token reference loop on a
    subsampled prefix of the same schedule (the full per-token run is
    the expensive half of the bench; the prefix keeps smoke wall small
    while still crossing admission, eviction sweeps, and retirement).

Exit status is nonzero on any violation, so `make bench-scale` can sit
in CI next to `chaos-smoke`.

Usage:  PYTHONPATH=src python benchmarks/scale_smoke.py
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import MB  # noqa: E402
from repro.svm import ModelSpec, PoolScheduler, make_requests  # noqa: E402

REQUESTS = 1024
PREFIX = 128            # identity check subsample
TOKENS = 110
CAP = 6000 * MB
WALL_BUDGET_S = 60.0    # measured ~0.4s on the reference box

_checks: list[str] = []


def check(ok: bool, what: str) -> None:
    _checks.append(f"{'ok  ' if ok else 'FAIL'} {what}")
    if not ok:
        print("\n".join(_checks))
        print(f"scale-smoke: FAIL ({what})")
        sys.exit(1)


def specs() -> list[ModelSpec]:
    return [ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB),
            ModelSpec.synthetic("archB", 10, 2 * MB, embed_bytes=6 * MB)]


def strip(r: dict) -> dict:
    """Drop execution-mode markers; everything else must match bytewise."""
    r = dict(r)
    r.pop("fused")
    sc = dict(r["shared_cache"])
    for k in ("shared_concats", "concat_memo_entries",
              "concat_memo_evictions"):
        sc.pop(k)
    r["shared_cache"] = sc
    return r


def run(reqs, *, fused: bool):
    sched = PoolScheduler(CAP, policy="svm_aware", pin_frac=0.4,
                          fused=fused)
    r = sched.run([dataclasses.replace(q) for q in reqs])
    ops = sum(s.ops_replayed for s in sched._sessions)
    return r, ops


def main() -> None:
    reqs = make_requests(specs(), REQUESTS, seed=5, tokens=TOKENS,
                         arrival="burst", spec_choice="roundrobin")

    # spy on the window tier so the smoke fails loudly if a future
    # change makes the guards reject every window on this schedule
    windows = {"passes": 0, "rounds": 0}
    orig = PoolScheduler._run_window_fused

    def spy(self, order, r, *a, **kw):
        windows["passes"] += 1
        windows["rounds"] += r
        return orig(self, order, r, *a, **kw)

    PoolScheduler._run_window_fused = spy
    try:
        t0 = time.perf_counter()
        r_full, ops = run(reqs, fused=True)
        host_s = time.perf_counter() - t0
    finally:
        PoolScheduler._run_window_fused = orig

    check(r_full["n_failed"] == 0 and r_full["n_requests"] == REQUESTS,
          f"all {REQUESTS} requests completed")
    check(all(q["tokens"] == TOKENS for q in r_full["requests"]),
          f"every request decoded {TOKENS}/{TOKENS} tokens")
    check(ops >= 2_000_000, f"schedule replayed {ops} ops (>= 2M)")
    check(windows["passes"] >= 1 and windows["rounds"]
          >= 2 * windows["passes"],
          f"window tier engaged ({windows['passes']} passes / "
          f"{windows['rounds']} rounds)")
    check(host_s <= WALL_BUDGET_S,
          f"host wall {host_s:.2f}s within {WALL_BUDGET_S:.0f}s budget")

    prefix = reqs[:PREFIX]
    r_vec, _ = run(prefix, fused=True)
    r_ref, _ = run(prefix, fused=False)
    check(strip(r_vec) == strip(r_ref),
          f"{PREFIX}-request prefix byte-identical to per-token replay")

    print("\n".join(_checks))
    print(f"scale-smoke: PASS — {REQUESTS} requests x {TOKENS} tokens, "
          f"{ops} ops, {windows['passes']} window passes "
          f"({windows['rounds']} fused rounds), {host_s:.2f}s host")


if __name__ == "__main__":
    main()
