"""svmlint framework: findings, rule registry, suppressions, tree walk.

The engine's correctness rests on cross-tier *contracts* (batched ==
scalar byte-identity, frozen compiled-trace columns, counter
conservation, determinism, manager encapsulation) that runtime
equivalence tests can only probe pointwise.  `repro.analysis` checks the
contracts at the **source** level: each `Rule` walks a module's AST and
reports `Finding`s; the CLI (`tools/svmlint.py`, `make lint`) fails CI on
any finding over `src/repro`.

Suppressions
------------
A finding is silenced by an inline comment on the flagged line (or on a
comment-only line directly above it)::

    t0 = time.time()   # svmlint: disable=determinism -- host-side timer,
                       # not the simulated clock

The reason string after ``--`` is **mandatory**: a bare
``# svmlint: disable=<rule>`` is itself reported (rule
``suppression-reason``), so every exemption documents why it is sound.
``disable=all`` silences every rule on that line (still needs a reason).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Sequence

SUPPRESS_RE = re.compile(
    r"#\s*svmlint:\s*disable=([A-Za-z0-9_,-]+)(?:\s+--\s*(\S.*?))?\s*$")

#: rule id reserved for the framework's bare-suppression check
SUPPRESSION_RULE = "suppression-reason"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"[{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: frozenset[str]      # rule names, possibly {"all"}
    reason: str | None
    own_line: bool             # comment-only line (covers the next line)


class LintModule:
    """One parsed source module handed to every rule.

    ``relpath`` locates the module inside the package tree (used by
    scoped rules — e.g. manager encapsulation only applies under
    ``repro/svm`` + ``repro/launch``); for fixture snippets the caller
    passes whatever path places the snippet in the scope under test.
    """

    def __init__(self, source: str, path: str):
        self.source = source
        self.path = path
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self.suppressions = _parse_suppressions(self.lines)

    @property
    def package(self) -> str:
        """Dotted package guess from the path: everything from the
        ``repro`` component to the module's parent directory."""
        parts = self.path.replace(os.sep, "/").split("/")
        if "repro" not in parts:
            return ""
        return ".".join(parts[parts.index("repro"):-1])

    def suppressed(self, finding: Finding) -> bool:
        for line in (finding.line, finding.line - 1):
            sup = self.suppressions.get(line)
            if sup is None:
                continue
            if line == finding.line - 1 and not sup.own_line:
                continue       # trailing comment only covers its own line
            if finding.rule in sup.rules or "all" in sup.rules:
                return True
        return False


def _parse_suppressions(lines: Sequence[str]) -> dict[int, Suppression]:
    out: dict[int, Suppression] = {}
    for i, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if m is None:
            continue
        rules = frozenset(r.strip() for r in m.group(1).split(",")
                          if r.strip())
        out[i] = Suppression(line=i, rules=rules, reason=m.group(2),
                             own_line=text.lstrip().startswith("#"))
    return out


class Rule:
    """Base class: subclasses set ``name``/``doc``/``invariant`` and
    implement `check`.  ``scope`` (dotted-package prefixes) limits where
    the rule applies; empty means the whole tree."""

    name = ""
    doc = ""
    invariant = ""
    scope: tuple[str, ...] = ()

    def applies(self, mod: LintModule) -> bool:
        if not self.scope:
            return True
        pkg = mod.package
        return any(pkg == s or pkg.startswith(s + ".") for s in self.scope)

    def check(self, mod: LintModule) -> Iterable[Finding]:
        raise NotImplementedError


RULES: dict[str, Rule] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator adding a rule instance to the registry."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return cls


def _resolve(rules: Sequence[str] | None) -> list[Rule]:
    if rules is None:
        return list(RULES.values())
    missing = [r for r in rules if r not in RULES]
    if missing:
        raise KeyError(f"unknown rule(s) {missing}; "
                       f"available: {sorted(RULES)}")
    return [RULES[r] for r in rules]


def _suppression_findings(mod: LintModule) -> list[Finding]:
    """Every svmlint suppression must carry a ``-- reason`` string."""
    return [
        Finding(SUPPRESSION_RULE, mod.path, sup.line, 0,
                "bare suppression: add ' -- <reason>' saying why the "
                "flagged site is sound")
        for sup in mod.suppressions.values() if not sup.reason
    ]


def lint_source(source: str, path: str = "<string>", *,
                rules: Sequence[str] | None = None) -> list[Finding]:
    """Lint one source string (fixture entry point; `lint_paths` wraps
    this for files).  Returns surviving findings, suppression-filtered,
    plus bare-suppression findings."""
    mod = LintModule(source, path)
    found: list[Finding] = []
    for rule in _resolve(rules):
        if rule.applies(mod):
            found.extend(rule.check(mod))
    found = [f for f in found if not mod.suppressed(f)]
    found.extend(_suppression_findings(mod))
    # dedupe: nested expressions can trip one rule twice at one location
    found = list(dict.fromkeys(found))
    found.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return found


def iter_py_files(paths: Iterable[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__")
                out.extend(os.path.join(dirpath, f)
                           for f in sorted(filenames)
                           if f.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_paths(paths: Iterable[str], *,
               rules: Sequence[str] | None = None) -> list[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    found: list[Finding] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        found.extend(lint_source(source, path, rules=rules))
    return found


# ---------------------------------------------------------- AST utilities

def walk_functions(tree: ast.AST):
    """Yield ``(node, qualname)`` for every (async) function, with class
    nesting reflected in the qualname (``Cls.meth``)."""

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield child, q
                yield from visit(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def attr_chain(node: ast.AST) -> str | None:
    """Dotted text of a Name/Attribute chain (``self.plan.mgr`` ->
    ``"self.plan.mgr"``), or None for non-trivial expressions."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
