"""Activation offload scheduling — the paper's Jacobi2d insight applied to
training.

Forward writes per-layer activations into a fixed device pool; a second
pass re-reads them. The *order* of the second pass decides everything under
LRF/FIFO eviction (paper §3.2/§4.1):

  * "forward" (naive) — the second pass re-reads activations in FORWARD
    order. This is the access shape of remat-segment recomputation replays
    and pipeline-parallel microbatch replays, and it is exactly the
    paper's naive Jacobi2d: a cyclic traversal where FIFO evicts each
    activation right before it is needed — every read misses.
  * "reverse" (svm-aware) — the second pass runs last→first (what plain
    backprop does naturally, and what an SVM-aware recompute/pipeline
    schedule should do): the resident tail is consumed first, each spilled
    activation migrates back exactly once, and eager spill during forward
    moves evictions off the critical path (paper Alg. 2 + §4.2 parallel
    eviction).

Both passes are **emitted as ops** (touch / compute / spill) through a
`repro.core.engine.TraceSession` and replayed on the batched engine — the
eager-spill loop is the `OP_SPILL` boundary op (drain `spill_oldest`
victims until the next activation fits).  ``engine="scalar"`` replays the
same recorded ops op-for-op through the manager — the imperative reference
path, byte-identical by the engine's equivalence guarantee.
"""

from __future__ import annotations

import dataclasses

from repro.core import AddressSpace, SVMManager, TraceSession
from repro.core.costmodel import CostParams, TPU_V5E_HOST


@dataclasses.dataclass
class OffloadPlan:
    n_layers: int
    act_bytes: int              # bytes per layer-boundary activation
    budget_bytes: int           # device pool for activations
    order: str                  # "forward" (naive) | "reverse" (svm-aware)
    spill_overlap: float = 0.85  # eager-spill fraction hidden by compute

    @property
    def resident_layers(self) -> int:
        return max(1, self.budget_bytes // self.act_bytes)


def plan_offload(n_layers: int, act_bytes: int, budget_bytes: int,
                 svm_aware: bool = True) -> OffloadPlan:
    """An offload plan whose consume pass runs reverse (svm-aware) or
    forward (the naive cyclic-traversal baseline)."""
    return OffloadPlan(n_layers, act_bytes, budget_bytes,
                       "reverse" if svm_aware else "forward")


def record_offload(session: TraceSession, plan: OffloadPlan,
                   rids: list[int], *,
                   compute_per_layer_s: float = 0.0) -> None:
    """Record produce + consume as ops, one range per activation.

    Forward: (svm-aware only) an eager-spill op making room for the next
    activation — §4.2 parallel eviction, mostly off the critical path —
    then a write-allocate touch and the layer's compute.  Second pass:
    re-read touches in the plan's order, at backward compute cost."""
    for i in range(plan.n_layers):
        if plan.order == "reverse":
            session.spill(plan.act_bytes, overlap=plan.spill_overlap)
        session.touch(rids[i], concurrency=8)  # write-allocate
        session.compute(compute_per_layer_s)
    order = (range(plan.n_layers) if plan.order == "forward"
             else range(plan.n_layers - 1, -1, -1))
    for i in order:
        session.touch(rids[i], concurrency=8)
        session.compute(compute_per_layer_s * 2.0)


def simulate_offload(plan: OffloadPlan, *,
                     params: CostParams = TPU_V5E_HOST,
                     compute_per_layer_s: float = 0.0,
                     engine: str = "session",
                     session_stats: dict | None = None) -> dict:
    """Run produce+consume through the SVM manager, one range per
    activation — recorded as ops and replayed as one compiled segment
    (``engine="session"``) or op-for-op (``engine="scalar"``)."""
    if engine not in ("session", "scalar"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "available: 'session', 'scalar'")
    space = AddressSpace(plan.budget_bytes, base=0,
                         alignment=max(plan.act_bytes, 2 * 1024 * 1024))
    allocs = [space.alloc(plan.act_bytes, f"act{i}")
              for i in range(plan.n_layers)]
    rids = [space.ranges_of(a)[0].rid for a in allocs]
    mgr = SVMManager(space, policy="lrf", params=params)

    session = TraceSession(mgr, scalar=(engine == "scalar"))
    record_offload(session, plan, rids,
                   compute_per_layer_s=compute_per_layer_s)
    session.flush()
    if session_stats is not None:
        session_stats.update(session.stats())

    s = mgr.summary()
    s["order"] = plan.order
    s["resident_layers"] = plan.resident_layers
    return s
