"""granite-20b: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 —
llama-arch code model [arXiv:2405.04324; hf]."""

import dataclasses

from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    vocab=49152,
    d_model=6144,
    n_layers=52,
    d_ff=24576,
    n_heads=48,
    n_kv_heads=1,
    layer_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=10_000.0,
    tie_embeddings=False,
    mlp_gated=False,   # GPT-BigCode-style classic 2-matrix MLP
    act="gelu",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=192,
        n_heads=4, n_kv_heads=1)
