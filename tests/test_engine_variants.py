"""Golden equivalence for the full-variant fast tier (PR 2).

Every §4.2 driver variant (deferred granularity, pre-eviction watermark,
zero-copy) and the UVM baseline manager now execute on the batched engine;
this suite pins the byte-identical-`summary()` contract for those
configurations against the scalar path, plus the fixed UVM fault-batching
and dirtiness/writeback accounting semantics, and the `dos_sweep` anchor
routing through the sweep runner."""

import pytest

from repro.core import (
    GB,
    MB,
    AddressSpace,
    SweepPoint,
    UVMManager,
    VABLOCK,
    dos_sweep,
    run_point,
    simulate,
)
from repro.core.engine import compile_trace, execute_compiled
from repro.core.simulator import apply_trace
from repro.core.svm import SVMManager
from repro.core.traces import WORKLOADS, make_workload
from repro.core.uvm import MAX_BATCH

CAP = 4 * GB
DOS_POINTS = (78, 109, 147)
POLICIES = ("lrf", "lru", "clock", "random")

VARIANTS = {
    "defer": {"defer_granule": 2 * MB, "defer_k": 3},
    "previct": {"previct_watermark": 0.1},
    "defer_previct": {"defer_granule": 4 * MB, "defer_k": 2,
                      "previct_watermark": 0.12},
    "previct_parallel": {"previct_watermark": 0.1, "parallel_evict": True},
}


def _pair(workload, policy="lrf", profile=False, cap=CAP, **kw):
    scalar = simulate(workload(), cap, policy=policy, profile=profile,
                      engine="scalar", **kw)
    batched = simulate(workload(), cap, policy=policy, profile=profile,
                       engine="batched", **kw)
    return scalar, batched


def _assert_equiv(scalar, batched, profile=False):
    assert scalar.summary == batched.summary
    ms, mb = scalar.manager, batched.manager
    assert ms.resident == mb.resident
    assert ms.free == mb.free
    assert ms.pinned == mb.pinned
    if profile:
        assert ms.events == mb.events
        assert ms.density == mb.density


# ------------------------------------------------------------ SVM variants

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_golden_variant_policies(variant, policy):
    kw = VARIANTS[variant]
    for dos in DOS_POINTS:
        scalar, batched = _pair(
            lambda: make_workload("jacobi2d", int(CAP * dos / 100)),
            policy, **kw)
        _assert_equiv(scalar, batched)
        assert scalar.manager._defer_count == batched.manager._defer_count


@pytest.mark.parametrize("name", ("stream", "sgemm", "gesummv", "bfs"))
@pytest.mark.parametrize("variant", ("defer", "previct"))
def test_golden_variant_workloads(name, variant):
    for dos in (109, 147):
        scalar, batched = _pair(
            lambda: make_workload(name, int(CAP * dos / 100)),
            **VARIANTS[variant])
        _assert_equiv(scalar, batched)


@pytest.mark.parametrize("name,zc", [("stream", ("b",)),
                                     ("gesummv", ("A",)),
                                     ("sgemm", ("B",))])
def test_golden_zero_copy_in_span(name, zc):
    """Zero-copy touches run in-span (they no longer break spans)."""
    for extra in ({}, VARIANTS["defer"], VARIANTS["previct"]):
        scalar, batched = _pair(
            lambda: make_workload(name, int(CAP * 1.25)),
            zero_copy_alloc_names=zc, **extra)
        _assert_equiv(scalar, batched)
        assert batched.summary["wall_s"] == scalar.summary["wall_s"]
        assert batched.manager.n_zerocopy == scalar.manager.n_zerocopy
        assert batched.manager.bytes_zerocopy == scalar.manager.bytes_zerocopy


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_golden_variant_profile_events(variant):
    scalar, batched = _pair(
        lambda: make_workload("stream", int(CAP * 1.25)),
        profile=True, **VARIANTS[variant])
    _assert_equiv(scalar, batched, profile=True)


def test_golden_zero_copy_profile_events():
    scalar, batched = _pair(
        lambda: make_workload("stream", int(CAP * 1.25)),
        profile=True, zero_copy_alloc_names=("b",))
    _assert_equiv(scalar, batched, profile=True)


# -------------------------------------------------------------- UVM tier

@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_golden_uvm_summary_identical(name):
    kw = {"retry_override": 1} if name in ("mvt", "gesummv") else {}
    for dos in DOS_POINTS:
        scalar, batched = _pair(
            lambda: make_workload(name, int(CAP * dos / 100), **kw),
            manager_cls=UVMManager)
        assert scalar.summary == batched.summary
        ms, mb = scalar.manager, batched.manager
        assert ms.resident == mb.resident          # exact LRU order
        assert ms.free == mb.free
        assert ms.pinned == mb.pinned
        assert ms.dirty == mb.dirty
        assert ms._pending == mb._pending


@pytest.mark.parametrize("name,kw", [
    ("sgemm", {"svm_aware": True}),      # pin/unpin boundary ops
    ("bfs", {}),                         # writeback ops
    ("gesummv", {}),                     # natural retry thrash (storms)
])
def test_golden_uvm_boundary_ops_and_profile(name, kw):
    scalar, batched = _pair(
        lambda: make_workload(name, int(CAP * 1.09), **kw),
        profile=True, manager_cls=UVMManager)
    assert scalar.summary == batched.summary
    assert scalar.manager.events == batched.manager.events
    assert scalar.manager.resident == batched.manager.resident


# ------------------------------------------- fixed UVM batching semantics

def test_uvm_faults_buffer_across_ops():
    """Faults accumulate across touch ops; BATCH_FIXED_S is charged per
    batch at a sync point, not per faulting touch."""
    space = AddressSpace(2 * GB, base=0)
    space.alloc(64 * MB, "a")          # 1 range, 32 VABlocks
    m = UVMManager(space)
    m.touch(0)
    assert m.n_batches == 0            # buffered, nothing serviced yet
    assert m.n_migrations == 0
    assert len(m._pending) == 32
    assert m.faults_serviceable == 32
    m.flush()
    assert m.n_batches == 1            # one batch for the whole range
    assert m.n_migrations == 1         # coalesced into one transfer
    assert m.bytes_migrated == 64 * MB
    assert not m._pending


def test_uvm_batch_flushes_at_max_batch_and_advance():
    space = AddressSpace(4 * GB, base=0)     # alignment 128 MB
    space.alloc(640 * MB, "a")               # 5 ranges x 64 VABlocks
    m = UVMManager(space)
    for r in space.ranges[:4]:
        m.touch(r.rid)                       # 256 faults: flush on the last
    assert m.n_batches == 1
    assert m.faults_serviceable == 4 * 64
    assert not m._pending                    # MAX_BATCH flush drained it
    assert m.faults_serviceable % MAX_BATCH == 0
    m.touch(space.ranges[4].rid)
    assert m.n_batches == 1 and len(m._pending) == 64
    m.advance(1e-3)                          # kernel boundary flushes
    assert m.n_batches == 2
    assert not m._pending


def test_uvm_batch_flushes_under_capacity_pressure():
    space = AddressSpace(8 * MB, base=0)     # 4 VABlocks of capacity
    for i in range(4):
        space.alloc(2 * MB, f"m{i}")
    m = UVMManager(space)
    for rid in range(3):
        m.touch(rid)
    assert m.n_batches == 0                  # 3 x 2MB < 8MB free
    m.touch(3)                               # 4 x 2MB >= free: flush
    assert m.n_batches == 1
    assert not m._pending


def test_uvm_duplicate_faults_dismissed_while_pending():
    space = AddressSpace(2 * GB, base=0)
    space.alloc(64 * MB, "a")
    m = UVMManager(space)
    m.touch(0, concurrency=0)
    dups_before = m.faults_duplicate
    serviceable_before = m.faults_serviceable
    m.touch(0, concurrency=0)      # same 32 blocks, still buffered
    assert m.faults_serviceable == serviceable_before
    assert m.faults_duplicate == dups_before + 32


def test_uvm_clean_evictions_are_unmap_only():
    space = AddressSpace(8 * MB, base=0)
    for i in range(5):
        space.alloc(2 * MB, f"m{i}")
    m = UVMManager(space)
    for rid in range(5):
        m.touch(rid)
    m.flush()
    assert m.n_evictions > 0
    assert m.bytes_evicted == 0              # never written: no copy back
    assert m.evict_cost_total == 0.0
    assert m.cost.cpu_unmap > 0.0            # unmap work only


def test_uvm_dirty_evictions_pay_the_transfer():
    space = AddressSpace(8 * MB, base=0)
    for i in range(5):
        space.alloc(2 * MB, f"m{i}")
    m = UVMManager(space)
    for rid in range(4):
        m.touch(rid, write=True)
    m.flush()
    m.touch(4)                               # evicts a dirty block
    m.flush()
    assert m.n_evictions > 0
    assert m.bytes_evicted == m.n_evictions * VABLOCK
    assert m.evict_cost_total > 0.0


def test_uvm_writeback_booked_as_writeback_not_eviction():
    space = AddressSpace(2 * GB, base=0)
    space.alloc(64 * MB, "a")
    m = UVMManager(space)
    m.touch(0)
    m.writeback(0)
    assert m.n_writebacks == 32
    assert m.bytes_writeback == 64 * MB
    assert m.writeback_cost_total > 0.0
    assert m.n_evictions == 0
    assert m.bytes_evicted == 0
    assert m.free == space.capacity          # blocks dropped after copy
    assert not m.resident


# ----------------------------------------------- sweep plumbing / dispatch

def test_dos_sweep_anchor_routed_through_run_sweep(tmp_path):
    """The normalize_at fallback rides the same SweepPoint/run_sweep batch
    as the main rows (content-keyed cache included) instead of an
    in-process recompute."""
    grid = (109, 125)
    rows = dos_sweep(("stream", {}), grid, CAP, normalize_at=78.0,
                     cache_dir=str(tmp_path))
    # grid rows + the anchor all went through the cache
    assert len(list(tmp_path.glob("*.json"))) == len(grid) + 1
    anchor = run_point(SweepPoint.make("stream", CAP * 0.78, CAP))
    for dos, row in zip(grid, rows):
        direct = run_point(
            SweepPoint.make("stream", CAP * dos / 100.0, CAP))
        assert row["norm_perf"] == \
            direct["throughput"] / anchor["throughput"]
    # rerun: pure cache hits, identical rows
    assert dos_sweep(("stream", {}), grid, CAP, normalize_at=78.0,
                     cache_dir=str(tmp_path)) == rows


def test_sweep_point_uvm_manager_axis():
    row = run_point(SweepPoint.make("jacobi2d", CAP * 1.09, CAP,
                                    manager="uvm"))
    direct = simulate(make_workload("jacobi2d", int(CAP * 1.09)), CAP,
                      profile=False, manager_cls=UVMManager).row()
    assert row == direct
    assert "batches" in row and "writebacks" in row


def test_sweep_point_variants_run_batched_and_match_scalar():
    """Acceptance: representative paper_figs grid points (defer, previct,
    zero-copy, UVM) produce byte-identical rows on both engines."""
    specs = [
        dict(mgr_kwargs={"defer_granule": 2 * MB, "defer_k": 3}),
        dict(mgr_kwargs={"previct_watermark": 0.1}),
        dict(zero_copy="biggest"),
        dict(manager="uvm"),
    ]
    for spec in specs:
        batched = run_point(SweepPoint.make(
            "gesummv", CAP * 1.25, CAP, engine="batched",
            wl_kwargs=({"retry_override": 1}
                       if spec.get("manager") == "uvm" else None),
            **spec))
        scalar = run_point(SweepPoint.make(
            "gesummv", CAP * 1.25, CAP, engine="scalar",
            wl_kwargs=({"retry_override": 1}
                       if spec.get("manager") == "uvm" else None),
            **spec))
        assert batched == scalar


def test_zero_copy_cost_cache_keyed_by_config():
    """One CompiledTrace executed under two different zero-copy configs
    whose zc touch streams share first/last position and count but differ
    in range sizes must not collide in the per-span cost cache."""
    def build():
        space = AddressSpace(1 * GB, base=0, alignment=2 * MB)
        a = space.alloc(2 * MB, "a")
        c = space.alloc(16 * MB, "c")
        d = space.alloc(64 * MB, "d")
        a_rid = space.ranges_of(a)[0].rid
        c_rid = space.ranges_of(c)[0].rid
        d_rid = space.ranges_of(d)[0].rid
        ops = [("touch", a_rid, 8, 0)]
        ops += [("touch", c_rid, 8, 0), ("touch", d_rid, 8, 0)] * 30
        ops += [("touch", a_rid, 8, 0)]
        return space, a, c, d, ops

    space, a, c, d, ops = build()
    ct = compile_trace(iter(ops))
    for zc in ((a.alloc_id, c.alloc_id), (a.alloc_id, d.alloc_id)):
        space_s, a_, c_, d_, ops_s = build()
        ms = SVMManager(space_s, profile=False)
        mb = SVMManager(space, profile=False)
        for aid in zc:
            ms.set_zero_copy(aid)
            mb.set_zero_copy(aid)
        apply_trace(ms, iter(ops_s))
        execute_compiled(ct, mb)
        assert ms.summary() == mb.summary(), f"zc config {zc} diverged"


def test_uvm_unpin_preserves_lru_position_of_refaulted_block():
    """A VABlock shared by two ranges can fault back into residency while
    pinned; unpinning it must keep its scalar LRU position (OrderedDict
    value update, no move-to-end)."""
    def build():
        space = AddressSpace(12 * MB, base=0, alignment=2 * MB)
        space.alloc(3 * MB, "a")     # ranges [0,2) and [2,3)
        space.alloc(3 * MB, "b")     # ranges [3,4) and [4,6): [3,4)
        space.alloc(6 * MB, "c")     # shares VABlock 1 with [2,3)
        shared_a = 1                 # rid of [2,3)MB — block 1
        shared_b = 2                 # rid of [3,4)MB — also block 1
        ops = [("touch", r.rid, 8, 0) for r in space.ranges]
        ops += [("pin", shared_a),
                ("touch", shared_b, 8, 0),   # block 1 refaults while pinned
                ("unpin", shared_a)]
        ops += [("touch", r.rid, 8, 0) for r in space.ranges]
        return space, ops

    space_s, ops = build()
    ms = UVMManager(space_s, profile=False)
    apply_trace(ms, iter(ops))
    ms.flush()
    space_b, ops = build()
    mb = UVMManager(space_b, profile=False)
    execute_compiled(compile_trace(iter(ops)), mb)
    mb.flush()
    assert ms.summary() == mb.summary()
    assert ms.resident == mb.resident


def test_engine_dispatch_unknown_manager_replays():
    class TracingSVM(SVMManager):
        pass

    space_a = AddressSpace(CAP, base=175 * MB)
    space_b = AddressSpace(CAP, base=175 * MB)
    wa = make_workload("stream", int(CAP * 1.25))
    wb = make_workload("stream", int(CAP * 1.25))
    wa.build(space_a)
    wb.build(space_b)
    ma = TracingSVM(space_a, profile=False)
    apply_trace(ma, wa.trace(space_a))
    mb = TracingSVM(space_b, profile=False)
    execute_compiled(compile_trace(wb.trace(space_b)), mb)
    assert ma.summary() == mb.summary()
