"""SVM UM-management cost model (paper §2.4, Fig. 3 & 5).

Five host-visible cost terms per host→device range migration:

  cpu_unmap   — collect + unmap host pages (HMM page-table walk)
  SDMA_setup  — create SDMA mappings, issue copy/map/update commands;
                absorbs most of the async SDMA copy (overlapped issue)
  alloc       — allocate device VRAM; **absorbs eviction cost** when the
                device is full (the paper's dominant term under
                oversubscription)
  cpu_update  — update host page table with new mappings
  misc        — page metadata migration, non-overlapped SDMA copy tail,
                free copy mappings

Calibration targets (paper §2.4, DOS < 100, large ranges):
  * cpu_update is the largest single term,
  * cpu_update + SDMA_setup + alloc ≈ 76 % of total,
  * pure data movement (inside SDMA_setup/misc) < 50 % of total
    (≈ 36 % here for a 1 GB range on the 36 GB/s MI250X host link).

Eviction "comprises all other items in the opposite direction" — modelled as
a full migration-shaped cost for the victim range, charged to the triggering
migration's `alloc` term (paper §2.4: alloc "includes the cost of eviction").

Terms are (fixed + per-page) affine so small ranges are latency-bound and
large ranges bandwidth-bound, reproducing Fig. 5's linear segments.
"""

from __future__ import annotations

import dataclasses

from repro.core.ranges import PAGE

TERMS = ("cpu_unmap", "sdma_setup", "alloc", "cpu_update", "misc")


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Affine per-term costs: seconds = fixed + per_page * npages (+copy)."""

    # fixed per-migration latencies (seconds)
    fix_cpu_unmap: float = 8e-6
    fix_sdma_setup: float = 12e-6
    fix_alloc: float = 6e-6
    fix_cpu_update: float = 10e-6
    fix_misc: float = 6e-6
    # per-4KB-page management costs (seconds/page)
    pp_cpu_unmap: float = 0.0408e-6
    pp_sdma_setup: float = 0.0015e-6
    pp_alloc: float = 0.0686e-6
    pp_cpu_update: float = 0.0877e-6
    pp_misc: float = 0.0004e-6
    # host<->device link bandwidth (bytes/s, one direction)
    link_bw: float = 36e9
    # split of raw copy time between SDMA_setup (issue-overlapped) and misc
    copy_in_sdma: float = 0.70
    # zero-copy remote access latency per cacheline-batch (s) and batch bytes
    zerocopy_lat: float = 1.5e-6
    zerocopy_batch: int = 4096
    # achievable serving compute rate (flops/s) for the streaming runtime:
    # peak bf16 derated to a realistic decode utilisation
    serve_flops: float = 197e12 * 0.4

    def copy_time(self, nbytes: int) -> float:
        return nbytes / self.link_bw


# The paper's experimental node: MI250X GCD, 36 GB/s bidir Infinity Fabric.
MI250X = CostParams()

# TPU-v5e-class host: PCIe Gen4 x16-ish effective host link.
TPU_V5E_HOST = CostParams(link_bw=32e9)


@dataclasses.dataclass
class CostVector:
    """Accumulated per-term costs (seconds)."""

    cpu_unmap: float = 0.0
    sdma_setup: float = 0.0
    alloc: float = 0.0
    cpu_update: float = 0.0
    misc: float = 0.0

    def total(self) -> float:
        return (self.cpu_unmap + self.sdma_setup + self.alloc
                + self.cpu_update + self.misc)

    def add(self, other: "CostVector") -> None:
        self.cpu_unmap += other.cpu_unmap
        self.sdma_setup += other.sdma_setup
        self.alloc += other.alloc
        self.cpu_update += other.cpu_update
        self.misc += other.misc

    def as_dict(self) -> dict[str, float]:
        return {t: getattr(self, t) for t in TERMS}


def migration_cost(nbytes: int, p: CostParams) -> CostVector:
    """Host→device migration of one range (no eviction)."""
    npages = -(-nbytes // PAGE)
    copy = p.copy_time(nbytes)
    return CostVector(
        cpu_unmap=p.fix_cpu_unmap + p.pp_cpu_unmap * npages,
        sdma_setup=(p.fix_sdma_setup + p.pp_sdma_setup * npages
                    + copy * p.copy_in_sdma),
        alloc=p.fix_alloc + p.pp_alloc * npages,
        cpu_update=p.fix_cpu_update + p.pp_cpu_update * npages,
        misc=p.fix_misc + p.pp_misc * npages + copy * (1.0 - p.copy_in_sdma),
    )


def eviction_cost(nbytes: int, p: CostParams) -> float:
    """Device→host eviction of one range = migration-shaped, opposite
    direction (paper §2.2). Returned as a scalar: the caller charges it to
    the triggering migration's `alloc` term (paper §2.4)."""
    return migration_cost(nbytes, p).total()


def zerocopy_cost(nbytes: int, p: CostParams) -> float:
    """Remote (host-pinned) access cost for `nbytes` at cacheline-batch
    granularity (paper §4.2 zero-copy)."""
    batches = -(-nbytes // p.zerocopy_batch)
    return batches * p.zerocopy_lat + p.copy_time(nbytes) * 0.5
