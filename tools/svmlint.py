#!/usr/bin/env python
"""svmlint CLI — contract-checking static analysis over src/repro.

Usage::

    python tools/svmlint.py                 # lint src/repro, exit 1 on findings
    python tools/svmlint.py --list-rules    # show registered rules
    python tools/svmlint.py --rules determinism,counter-pairing src/repro/svm

Wired as ``make lint`` and a CI step; any finding is a failure.
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis import RULES, lint_paths  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="svmlint",
        description="check the engine's equivalence contracts at the "
                    "source level")
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(_REPO, "src", "repro")],
                    help="files or directories to lint "
                         "(default: src/repro)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--rules", metavar="NAME[,NAME...]",
                    help="run only the named rules")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(name) for name in RULES)
        for name in sorted(RULES):
            rule = RULES[name]
            scope = ", ".join(rule.scope) if rule.scope else "src/repro"
            print(f"{name:<{width}}  [{scope}]  {rule.doc}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        findings = lint_paths(args.paths, rules=rules)
    except KeyError as exc:
        print(f"svmlint: {exc.args[0]}", file=sys.stderr)
        return 2
    for f in findings:
        print(f.format())
    n = len(findings)
    print(f"svmlint: {n} finding{'s' if n != 1 else ''} "
          f"({len(RULES)} rules)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
