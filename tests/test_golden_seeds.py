"""Golden digests of every seeded generation surface.

Determinism ("same seed ⇒ same run") is load-bearing for the engine's
byte-identity contract, the disk sweep cache, the chaos layer, and every
committed bench gate — but a silent drift in any seeded generator (a
refactor reordering RNG draws, a changed default) would pass all the
*relative* equivalence tests while quietly invalidating the committed
BENCH_engine.json numbers.  These tests pin the absolute content:
sha256 digests of the canonical serialisation of

  * `make_requests` under all three arrival processes,
  * `FaultPlan.default` (the chaos gate's hazard schedule),
  * the `HotSet` adversary's drawn touch sequence in all three modes.

Regeneration (after an *intentional* generator change): run

    PYTHONPATH=src python tests/test_golden_seeds.py

and paste the printed ``GOLDEN`` block over the one below.  A failure
here without an intentional change means committed bench results no
longer describe what the code generates."""

import hashlib

import numpy as np

import pytest

from repro.core import GB, MB
from repro.core.ranges import AddressSpace
from repro.core.traces import HotSet
from repro.svm import FaultPlan, ModelSpec, make_requests

SPECS = [ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB),
         ModelSpec.synthetic("archB", 4, 3 * MB, embed_bytes=2 * MB)]


def _digest(parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, np.ndarray):
            h.update(np.ascontiguousarray(p).tobytes())
        else:
            h.update(repr(p).encode())
        h.update(b"|")
    return h.hexdigest()[:16]


def digest_requests(arrival: str, seed: int = 0) -> str:
    reqs = make_requests(SPECS, 32, seed=seed, arrival=arrival,
                         mean_interarrival_s=0.25, tokens=24,
                         token_jitter=8, spec_choice="random")
    return _digest((r.req_id, r.spec.arch, r.arrival_s, r.n_tokens)
                   for r in reqs)


def digest_faultplan(seed: int) -> str:
    plan = FaultPlan.default(seed, n_requests=64, tokens=32)
    return _digest((e.at_tokens, e.kind, e.frac, e.fail_attempts)
                   for e in plan.events)


def digest_hotset(mode: str, seed: int = 0) -> str:
    space = AddressSpace(1 * GB, base=175 * MB)
    wl = HotSet(int(1.25 * GB), mode=mode, ops=1024, seed=seed)
    wl.build(space)
    seq, bounds, comp = wl._sequence(space)
    return _digest([seq, bounds, comp])


SURFACES = {
    "requests_burst": lambda: digest_requests("burst"),
    "requests_poisson": lambda: digest_requests("poisson"),
    "requests_uniform": lambda: digest_requests("uniform"),
    "faultplan_seed0": lambda: digest_faultplan(0),
    "faultplan_seed3": lambda: digest_faultplan(3),
    "hotset_static": lambda: digest_hotset("static"),
    "hotset_dynamic": lambda: digest_hotset("dynamic"),
    "hotset_oscillating": lambda: digest_hotset("oscillating"),
}

# regenerate with:  PYTHONPATH=src python tests/test_golden_seeds.py
GOLDEN = {
    "requests_burst": "81c1e5dc3f96be39",
    "requests_poisson": "036292edb7a51ed9",
    "requests_uniform": "3afb0768fe92aad4",
    "faultplan_seed0": "750d6fffbc94df49",
    "faultplan_seed3": "6b5b1f9fcdb45daa",
    "hotset_static": "f2ca059040e027e9",
    "hotset_dynamic": "3b9bae72742853ec",
    "hotset_oscillating": "67cc6430870ec90b",
}


@pytest.mark.parametrize("name", sorted(SURFACES))
def test_golden_digest(name):
    assert SURFACES[name]() == GOLDEN[name], (
        f"seeded surface {name!r} drifted from its committed digest — "
        "if the generator change was intentional, regenerate GOLDEN "
        "(see module docstring) and re-run the bench smoke so "
        "BENCH_engine.json matches what the code now generates")


@pytest.mark.parametrize("name", sorted(SURFACES))
def test_digest_stable_across_calls(name):
    assert SURFACES[name]() == SURFACES[name]()


def test_digest_sensitive_to_seed():
    assert digest_requests("poisson", seed=1) != \
        digest_requests("poisson", seed=0)
    assert digest_faultplan(1) != digest_faultplan(0)
    assert digest_hotset("dynamic", seed=1) != digest_hotset("dynamic")


def test_arrival_processes_distinct():
    seen = {digest_requests(a) for a in ("burst", "poisson", "uniform")}
    assert len(seen) == 3


if __name__ == "__main__":
    print("GOLDEN = {")
    for name in SURFACES:
        print(f'    "{name}": "{SURFACES[name]()}",')
    print("}")
