"""llama-3.2-vision-11b: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attention image layers every 5th layer (global
indices 3, 8, 13, ...). The vision frontend is a STUB: input_specs provides
precomputed patch embeddings (4 tiles x 1601 patches)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""

import dataclasses

from repro.models.config import ATTN, CROSS, MLP, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    vocab=128256,
    d_model=4096,
    n_layers=40,
    d_ff=14336,
    n_heads=32,
    n_kv_heads=8,
    layer_pattern=(ATTN, ATTN, ATTN, CROSS, ATTN),
    ffn_pattern=(MLP,),
    image_tokens=6404,
    rope_theta=500_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=5, d_ff=128,
        n_heads=4, n_kv_heads=2, image_tokens=8)
