"""Streaming executor: serve a model whose weights exceed the HBM budget.

Weights live host-side (numpy); a fixed-size device pool holds the resident
ranges. Each layer's weight fetch drives the SVMManager (faults -> range
migrations -> LRF/Clock/LRU evictions, with the paper's cost model supplying
the simulated clock), while the math itself runs for real, so correctness
and policy behaviour are validated together.

Streaming modes map the paper's findings onto serving:
  * naive        — demand-fetch in layer order; under oversubscription LRF
                   evicts the *earliest-fetched* layers, which are exactly
                   the ones the next token needs first: the decode loop is
                   Jacobi2d's cyclic-traversal pathology (Category II/III).
  * svm_aware    — pin the hottest leaves (embeddings + head: touched twice
                   per token) and prefetch the next layer overlapped with
                   compute (paper §4.1 pinning + §4.2 parallel eviction).
  * zero_copy    — leave designated cold leaves host-resident at remote-
                   access cost (paper §4.2).

Device-pool invalidation is push-based: the executor registers an eviction
listener on the `SVMManager`, and evicted rids map back to their leaf via
the plan's rid→leaf reverse index.  Each fetch therefore does O(ranges of
the fetched leaf + leaves actually evicted since the last drain) work —
the old implementation rescanned every leaf's full range list after every
fetch, which is O(leaves × ranges) per decode step.  Hidden prefetch
overlap is tracked in a separate ``overlap_hidden_s`` ledger (subtracted
in `metrics()`), never by rewinding the manager's wall clock, so recorded
`Event.t` timestamps stay monotonic.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.svm.planner import ParamRanges, plan_param_ranges

PyTree = Any

PEAK_FLOPS = 197e12 * 0.4     # assumed achievable serving compute rate


class StreamingExecutor:
    def __init__(self, params: PyTree, hbm_budget: int, *,
                 policy: str = "lrf",
                 cost_params: CostParams = TPU_V5E_HOST,
                 parallel_evict: bool = False,
                 prefetch: bool = False,
                 pin: tuple[str, ...] = (),
                 zero_copy: tuple[str, ...] = (),
                 concurrency: int = 64):
        self.host_params = jax.tree.map(np.asarray, params)
        self.plan: ParamRanges = plan_param_ranges(params, hbm_budget)
        self.mgr = self.plan.manager(policy=policy, params=cost_params,
                                     parallel_evict=parallel_evict)
        self.prefetch = prefetch
        self.concurrency = concurrency
        self._device: dict[str, jnp.ndarray] = {}
        self._flat = dict(self._leaves(self.host_params))
        for pat in zero_copy:
            for path, rids in self.plan.leaf_ranges.items():
                if pat in path:
                    aid = self.plan.space.ranges[rids[0]].alloc_id
                    self.mgr.set_zero_copy(aid)
        for pat in pin:
            for path, rids in self.plan.leaf_ranges.items():
                if pat in path:
                    for rid in rids:
                        self.mgr.pin(rid)
        # compute-time ledger (simulated clock shares the SVM manager wall)
        self.compute_flops = 0.0
        # prefetch hidden behind compute: separate ledger, never a wall
        # rewind (keeps Event.t monotonic)
        self.overlap_hidden_s = 0.0
        # push-based pool invalidation (O(1) per eviction, not per fetch)
        self._pending_evictions: deque[int] = deque()
        self.mgr.add_evict_listener(self._pending_evictions.append)
        # double-buffered next-layer prefetch queue
        self._prefetch_q: deque[tuple[str, float]] = deque()
        # instrumentation: units of invalidation work done by fetches
        # (range touches + evicted-leaf drops); regression-tested to be
        # O(ranges of fetched leaf + actual evictions), not O(all leaves)
        self.fetch_scan_work = 0

    @staticmethod
    def _leaves(tree: PyTree):
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
            path = "/".join(
                getattr(k, "key", getattr(k, "name", str(k))) for k in kp)
            yield path, leaf

    # ----------------------------------------------------------- fetching

    def fetch(self, path: str) -> jnp.ndarray:
        """Touch a leaf's ranges (demand paging) and return the tensor.

        Any leaves staged in the prefetch buffer are issued first (their
        migration cost was overlappable with the *previous* layer's
        compute window), so this fetch usually hits."""
        if self._prefetch_q:
            self.drain_prefetch()
        resident_before = True
        for rid in self.plan.leaf_ranges[path]:
            hit = self.mgr.touch(rid, concurrency=self.concurrency)
            resident_before &= hit
        self.fetch_scan_work += len(self.plan.leaf_ranges[path])
        if not resident_before or path not in self._device:
            tensor = self._device[path] = jnp.asarray(self._flat[path])
        else:
            tensor = self._device[path]
        # drain after caching: a leaf larger than the pool evicts its own
        # earlier ranges mid-fetch and must fall straight back out of the
        # pool (the tensor itself is still returned for this use)
        self._drain_evictions()
        return tensor

    def prefetch_leaf(self, path: str, overlap_s: float) -> None:
        """Issue next-layer migrations overlapped with current compute
        (paper §4.2 'parallel implementation'): up to `overlap_s` of the
        migration cost is hidden (ledgered, not rewound)."""
        w0 = self.mgr.wall
        for rid in self.plan.leaf_ranges[path]:
            self.mgr.touch(rid, concurrency=self.concurrency)
        self.overlap_hidden_s += min(self.mgr.wall - w0, overlap_s)
        self._drain_evictions()

    def queue_prefetch(self, paths: list[str], overlap_s: float) -> None:
        """Stage the next layer's leaves in the prefetch buffer (double
        buffering: at most one upcoming layer is staged at a time; the
        buffer is consumed by the next `fetch`, or an explicit
        `drain_prefetch`)."""
        self._prefetch_q.clear()
        self._prefetch_q.extend((p, overlap_s) for p in paths)

    def drain_prefetch(self) -> None:
        while self._prefetch_q:
            path, overlap_s = self._prefetch_q.popleft()
            self.prefetch_leaf(path, overlap_s)

    def _drain_evictions(self) -> None:
        """Drop device tensors for leaves whose ranges were evicted since
        the last drain — pushed by the manager, O(#evictions)."""
        rid_to_leaf = self.plan.rid_to_leaf
        while self._pending_evictions:
            rid = self._pending_evictions.popleft()
            leaf = rid_to_leaf.get(rid)
            if leaf is not None and self._device.pop(leaf, None) is not None:
                self.fetch_scan_work += 1

    def charge_compute(self, flops: float) -> None:
        self.compute_flops += flops
        self.mgr.advance(flops / PEAK_FLOPS)

    # ------------------------------------------------------------ metrics

    def metrics(self) -> dict:
        s = self.mgr.summary()
        s["wall_s"] = self.mgr.wall - self.overlap_hidden_s
        s["overlap_hidden_s"] = self.overlap_hidden_s
        s["dos"] = self.plan.dos()
        s["compute_flops"] = self.compute_flops
        return s


def run_layer_stream(
    executor: StreamingExecutor,
    layer_paths: list[list[str]],
    apply_layer: Callable[[int, dict[str, jnp.ndarray]], float],
    *,
    steps: int = 1,
) -> dict:
    """Drive a layer-ordered streaming pass `steps` times (decode loop).

    `layer_paths[i]` lists the param-leaf paths layer i needs;
    `apply_layer(i, tensors)` runs the math and returns its FLOPs.
    """
    n = len(layer_paths)
    for _ in range(steps):
        for i in range(n):
            tensors = {p: executor.fetch(p) for p in layer_paths[i]}
            flops = apply_layer(i, tensors)
            if executor.prefetch and i + 1 < n:
                # stage layer i+1 in the double buffer; its migrations are
                # issued (with layer i's compute window as the overlap
                # budget) when layer i+1's first fetch drains the buffer
                executor.queue_prefetch(layer_paths[i + 1],
                                        flops / PEAK_FLOPS)
            executor.charge_compute(flops)
    return executor.metrics()
