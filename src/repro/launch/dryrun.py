import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count at first backend initialisation). Everything else follows.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch import sharding as shd        # noqa: E402
from repro.launch.mesh import data_axes, dp_size, make_production_mesh  # noqa: E402
from repro.launch.settings import SHAPES, cell_skipped, settings_for  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402
from repro.models import init_cache, init_params  # noqa: E402
from repro.models import moe as moe_lib          # noqa: E402
from repro.models import transformer as transformer_lib  # noqa: E402
from repro.optim import OptConfig, make_optimizer  # noqa: E402

COLLECTIVE_RE = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# effective data moved per device, relative to the (per-device) result shape
COLLECTIVE_FACTOR = {
    "all-gather": 1.0,       # receives (n-1)/n of the gathered result
    "all-reduce": 2.0,       # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective bytes from post-SPMD optimised HLO."""
    out = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVE_FACTOR}
    total = 0.0
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = DTYPE_BYTES.get(dt, 4)
        if dims:
            for d in dims.split(","):
                nbytes *= int(d)
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
        total += nbytes * COLLECTIVE_FACTOR[op]
    out["effective_bytes_per_device"] = total
    return out


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    specs = {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["cache"] = _abstract(
            jax.eval_shape(lambda: init_cache(cfg, B, S)))
    if cfg.is_vlm:
        specs["ctx"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), jnp.bfloat16)
    elif cfg.is_encdec:
        if kind == "decode":  # decoder consumes the encoded frames
            specs["ctx"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        else:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    return specs


def build_cell(arch: str, shape_name: str, mesh, *,
               n_periods_override: int | None = None,
               microbatch_override: int | None = None,
               fsdp_override: bool | None = None,
               remat_override: str | None = None):
    """Assemble (jitted_fn, abstract_args) for one (arch x shape x mesh)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    st = settings_for(arch)
    if n_periods_override is not None:
        pl = len(cfg.layer_pattern)
        cfg = _dc.replace(
            cfg, n_layers=n_periods_override * pl + cfg.n_remainder)
    if remat_override is not None:
        cfg = _dc.replace(cfg, remat=remat_override)
    if microbatch_override is not None:
        st = _dc.replace(st, microbatches=microbatch_override)
    if fsdp_override is not None:
        st = _dc.replace(st, fsdp_train=fsdp_override,
                         fsdp_serve=fsdp_override)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    dp = data_axes(mesh)
    dpn = dp_size(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    lead = dp if len(dp) > 1 else dp[0]
    batch_ok = B % dpn == 0 and B >= dpn
    # MoE dispatch: shard-local EP (default, §Perf H1) vs global scatter
    # (paper-faithful GSPMD baseline; REPRO_MOE_EP=0). EP pays one weight
    # all-gather per layer under FSDP, which only amortises at large token
    # counts — decode cells (T = B tokens) keep the global path, where
    # GSPMD contracts the sharded weight dim with a tiny activation
    # all-reduce instead (H2: observed 0.01s vs 1.99s on jamba decode).
    moe_ep = os.environ.get("REPRO_MOE_EP", "1") == "1"
    tokens_total = B * (S if kind != "decode" else 1)
    if cfg.n_experts and moe_ep and batch_ok and tokens_total >= 65536:
        moe_lib.SHARD_MAP_SPEC = (mesh, dp, "model")
        moe_lib.BUFFER_SPEC = None
    else:
        moe_lib.SHARD_MAP_SPEC = None
        moe_lib.BUFFER_SPEC = (
            shd.moe_buffer_spec(dp, dpn, sizes["model"])
            if cfg.n_experts else None)
    transformer_lib.LOGITS_SPEC = P(
        lead if batch_ok else None, None, "model")
    # Sequence-parallel residual sharding (§Perf H5): the residual stream
    # between blocks lives (batch x seq/model x d); GSPMD then decomposes
    # the per-layer output all-reduces into reduce-scatter + all-gather —
    # half the collective bytes (Korthikanti et al.; measured 27.9s->15.0s
    # on jamba-398B train). REPRO_SEQ_PARALLEL=0 restores the baseline.
    seq_par = os.environ.get("REPRO_SEQ_PARALLEL", "1") == "1"
    # H7: when EVERY layer carries an EP-dispatched MoE, the shard_map
    # boundary re-gathers the S-sharded residual each layer and the SP win
    # inverts (mixtral: 4.55s EP-only vs 7.08s EP+SP) — keep SP off there.
    from repro.models.config import MOE as _MOE
    all_moe = (cfg.n_experts > 0
               and all(f == _MOE for _, f in cfg.layer_kinds()))
    if (seq_par and batch_ok and kind in ("train", "prefill")
            and S % sizes["model"] == 0
            and not (all_moe and moe_lib.SHARD_MAP_SPEC is not None)):
        transformer_lib.ACT_SPEC = P(lead, "model", None)
    else:
        transformer_lib.ACT_SPEC = P(lead if batch_ok else None, None, None)

    params_abs = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    fsdp = st.fsdp_train if kind == "train" else st.fsdp_serve
    pspecs = shd.param_specs(params_abs, fsdp=fsdp, dp_axes=dp, dp_total=dpn,
                             axis_sizes=sizes)
    pshard = shd.named(mesh, pspecs)
    bspec = shd.batch_spec(B, dp, dpn)
    bshard = jax.NamedSharding(mesh, bspec)
    ctx_shard = jax.NamedSharding(
        mesh, shd.batch_spec(B, dp, dpn, extra_dims=2))

    specs = input_specs(arch, shape_name)

    if kind == "train":
        opt_cfg = OptConfig(kind=st.optimizer)
        opt_init, _ = make_optimizer(opt_cfg)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        ospecs = shd.zero_specs(opt_abs, pspecs, dp_axes=dp, dp_total=dpn,
                                axis_sizes=sizes)
        oshard = shd.named(mesh, ospecs)
        step = make_train_step(cfg, opt_cfg, st.microbatches)
        batch = {"tokens": specs["tokens"], "labels": specs["labels"]}
        batch_shard = {"tokens": bshard, "labels": bshard}
        if "ctx" in specs or "frames" in specs:
            batch["ctx"] = specs.get("ctx", specs.get("frames"))
            batch_shard["ctx"] = ctx_shard

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        rep = jax.NamedSharding(mesh, P())
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, batch_shard),
            out_shardings=(pshard, oshard,
                           {"loss": rep, "grad_norm": rep}),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, batch)

    elif kind == "prefill":
        step = make_prefill_step(cfg)
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, B, S))
        cshard = shd.named(mesh,
                           shd.cache_specs(cache_abs, B, dp, dpn, sizes["model"]))
        logits_shard = jax.NamedSharding(mesh, shd.batch_spec(B, dp, dpn,
                                                              extra_dims=2))
        args_list = [params_abs, specs["tokens"]]
        in_sh = [pshard, bshard]
        if "ctx" in specs or "frames" in specs:
            args_list.append(specs.get("ctx", specs.get("frames")))
            in_sh.append(ctx_shard)
        jitted = jax.jit(
            step, in_shardings=tuple(in_sh),
            out_shardings=(logits_shard, cshard))
        args = tuple(args_list)

    else:  # decode
        step = make_serve_step(cfg)
        cache_abs = specs["cache"]
        cspecs = shd.cache_specs(cache_abs, B, dp, dpn, sizes["model"])
        cshard = shd.named(mesh, cspecs)
        args_list = [params_abs, specs["token"], cache_abs]
        in_sh = [pshard, bshard, cshard]
        if "ctx" in specs:
            args_list.append(specs["ctx"])
            in_sh.append(ctx_shard)
        jitted = jax.jit(
            step, in_shardings=tuple(in_sh),
            out_shardings=(bshard, cshard),
            donate_argnums=(2,),
        )
        args = tuple(args_list)

    return jitted, args


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs: 6·N_active·D (train) / 2·N_active·D (fwd)."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    tokens = sh["global_batch"] * (sh["seq_len"]
                                   if sh["kind"] != "decode" else 1)
    n = cfg.active_param_count()
    return (6.0 if sh["kind"] == "train" else 2.0) * n * tokens


def _measure(arch, shape_name, mesh, **overrides) -> dict:
    """Lower+compile one variant, return raw metrics."""
    jitted, args = build_cell(arch, shape_name, mesh, **overrides)
    with mesh:
        compiled = jitted.lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": parse_collectives(hlo),
    }


def run_roofline_cell(arch: str, shape_name: str, multi_pod: bool,
                      **overrides) -> dict:
    """Exact per-device FLOPs/bytes/collectives via depth differencing.

    XLA's cost_analysis counts while-loop bodies ONCE, so any lax.scan is
    under-counted. For this tier every inner scan is disabled (CE un-chunked,
    mamba associative scan over the full sequence, attention direct) and the
    program is lowered at 1 and 2 layer-periods; metrics are then linear in
    period count and extrapolate exactly:  f(P) = f(1) + (f(2)-f(1))(P-1).
    """
    from repro.models import attention as attn_lib
    from repro.models import mamba as mamba_lib
    from repro.launch import steps as steps_lib

    mesh_name = "2x16x16" if multi_pod else "16x16"
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tier": "roofline"}
    skip = cell_skipped(arch, shape_name)
    if skip:
        row["status"] = "skipped"
        row["reason"] = skip
        return row
    t0 = time.time()
    cfg = get_config(arch)
    saved = (attn_lib.FLASH_THRESHOLD, mamba_lib.CHUNK, steps_lib.CE_CHUNK,
             transformer_lib.UNROLL_PERIODS)
    try:
        attn_lib.FLASH_THRESHOLD = 1 << 62
        mamba_lib.CHUNK = 1 << 30
        steps_lib.CE_CHUNK = 1 << 30
        transformer_lib.UNROLL_PERIODS = True
        mesh = make_production_mesh(multi_pod=multi_pod)
        ov = dict(microbatch_override=1)
        ov.update(overrides)
        f1 = _measure(arch, shape_name, mesh, n_periods_override=1, **ov)
        f2 = _measure(arch, shape_name, mesh, n_periods_override=2, **ov)
        P = cfg.n_periods

        def extra(a, b):
            return a + (b - a) * (P - 1)

        row["status"] = "ok"
        row["hlo_flops_per_device"] = extra(f1["flops"], f2["flops"])
        row["hlo_bytes_per_device"] = extra(f1["bytes"], f2["bytes"])
        coll = {}
        for op in COLLECTIVE_FACTOR:
            coll[op] = {
                "count": round(extra(f1["collectives"][op]["count"],
                                     f2["collectives"][op]["count"]), 1),
                "bytes": extra(f1["collectives"][op]["bytes"],
                               f2["collectives"][op]["bytes"]),
            }
        coll["effective_bytes_per_device"] = extra(
            f1["collectives"]["effective_bytes_per_device"],
            f2["collectives"]["effective_bytes_per_device"])
        row["collectives"] = coll
        row["model_flops_global"] = model_flops(arch, shape_name)
        row["periods"] = P
    except Exception as e:  # noqa: BLE001
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"[:2000]
        row["traceback"] = traceback.format_exc()[-4000:]
    finally:
        (attn_lib.FLASH_THRESHOLD, mamba_lib.CHUNK, steps_lib.CE_CHUNK,
         transformer_lib.UNROLL_PERIODS) = saved
    row["wall_s"] = round(time.time() - t0, 1)
    return row


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    skip = cell_skipped(arch, shape_name)
    if skip:
        row["status"] = "skipped"
        row["reason"] = skip
        return row
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        jitted, args = build_cell(arch, shape_name, mesh)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        row["status"] = "ok"
        row["lower_s"] = round(t_lower, 1)
        row["compile_s"] = round(t_compile, 1)
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "peak_memory_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    row[k] = int(v)
        if cost:
            row["hlo_flops_per_device"] = float(cost.get("flops", 0.0))
            row["hlo_bytes_per_device"] = float(
                cost.get("bytes accessed", 0.0))
        row["collectives"] = parse_collectives(hlo)
        row["model_flops_global"] = model_flops(arch, shape_name)
        row["hlo_chars"] = len(hlo)
    except Exception as e:  # record the failure, keep sweeping
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"[:2000]
        row["traceback"] = traceback.format_exc()[-4000:]
    row["wall_s"] = round(time.time() - t0, 1)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape)")
    ap.add_argument("--tier", default="fit", choices=["fit", "roofline"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.tier == "roofline":
                    row = run_roofline_cell(arch, shape, mp)
                else:
                    row = run_cell(arch, shape, mp)
                line = json.dumps(row)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")


if __name__ == "__main__":
    main()
