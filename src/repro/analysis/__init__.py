"""svmlint — source-level contract checking for the engine invariants.

Public surface::

    from repro.analysis import lint_paths, lint_source, RULES
    findings = lint_paths(["src/repro"])      # [] on a clean tree

plus the runtime frozen-column audit (`assert_frozen`,
`frozen_violations`).  Importing the package registers the five
contract rules from `repro.analysis.rules`.
"""

from repro.analysis.core import (
    Finding,
    LintModule,
    Rule,
    RULES,
    SUPPRESSION_RULE,
    iter_py_files,
    lint_paths,
    lint_source,
    register_rule,
)
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.rules import (
    ATTRIBUTION_COUNTERS,
    COLUMN_FIELDS,
    MANAGER_DRIVE,
    opcode_universe,
)
from repro.analysis.runtime import assert_frozen, frozen_violations

__all__ = [
    "Finding",
    "LintModule",
    "Rule",
    "RULES",
    "SUPPRESSION_RULE",
    "iter_py_files",
    "lint_paths",
    "lint_source",
    "register_rule",
    "ATTRIBUTION_COUNTERS",
    "COLUMN_FIELDS",
    "MANAGER_DRIVE",
    "opcode_universe",
    "assert_frozen",
    "frozen_violations",
]
