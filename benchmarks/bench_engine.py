"""Engine microbenchmark: compiled-trace engine vs the scalar op loop.

Measures, on the paper's workload traces:

  * scalar `apply_trace` throughput (ops/s) — the pre-engine hot path,
  * compiled-engine execution throughput on the same trace (the trace is
    lowered once; sweeps re-execute it across the policy/variant axes),
  * one-off trace compile time,
  * **compile-tier rows**: generator lowering (`compile_trace`) vs
    columnar emission (`Workload.emit_columns`) per Table-2 workload,
    with column-for-column identity asserted,
  * **variant rows**: the §4.2 driver variants (deferred granularity,
    pre-eviction watermark, zero-copy) and the UVM baseline manager —
    configurations that fell back to the scalar path before the full
    fast tier landed,
  * a small DOS sweep wall time, serial vs parallel workers, plus a
    cold-vs-warm **trace-cache** row: the same (workload × policy) grid
    with per-point recompiles vs the shared cross-point `TRACE_CACHE`,
  * a **serving-decode row**: one oversubscribed decode step through the
    `StreamingExecutor`'s TraceSession — scalar op-for-op replay vs the
    compiled per-token segment (recorded once, replayed every token).

Byte-identical `summary()` output is asserted for every measured pair.
Results land in ``BENCH_engine.json`` at the repo root (and a copy under
results/bench/) so the perf trajectory is tracked PR over PR.

Usage:  PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import GB, MB, SweepPoint, run_point, run_sweep  # noqa: E402
from repro.core.engine import (  # noqa: E402
    TRACE_CACHE,
    compile_trace,
    execute_compiled,
)
from repro.core.ranges import AddressSpace  # noqa: E402
from repro.core.simulator import apply_trace  # noqa: E402
from repro.core.svm import SVMManager  # noqa: E402
from repro.core.uvm import UVMManager  # noqa: E402
from repro.core.traces import make_workload  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
CAP = 8 * GB


def bench_trace(name: str, dos: float, alignment: int, reps: int,
                policy: str = "lrf", *, label: str | None = None,
                manager: str = "svm", zero_copy: tuple = (),
                wl_kwargs: dict | None = None,
                mgr_kwargs: dict | None = None) -> dict:
    """Time scalar vs engine on one workload trace; assert equivalence."""
    space = AddressSpace(CAP, base=175 * MB, alignment=alignment)
    wl = make_workload(name, int(CAP * dos / 100.0), **(wl_kwargs or {}))
    wl.build(space)
    ops = list(wl.trace(space))
    cls = SVMManager if manager == "svm" else UVMManager

    def mk():
        m = cls(space, policy=policy, profile=False, **(mgr_kwargs or {}))
        for a in space.allocations:
            if a.name in zero_copy:
                m.set_zero_copy(a.alloc_id)
        return m

    def drive(m, fn, *args):
        fn(*args)
        flush = getattr(m, "flush", None)
        if flush is not None:
            flush()

    mgr = mk()
    drive(mgr, apply_trace, mgr, iter(ops))   # warm (allocator, caches)
    ref = mgr.summary()

    t0 = time.perf_counter()
    ct = compile_trace(iter(ops))
    compile_s = time.perf_counter() - t0

    mgr2 = mk()
    drive(mgr2, execute_compiled, ct, mgr2)  # warm span caches + tables
    assert mgr2.summary() == ref, f"{label or name}: engine summary diverged"

    # interleaved best-of-reps: CPU-frequency/noisy-neighbour drift hits
    # both paths alike, keeping the ratio honest
    scalar_s = engine_s = float("inf")
    for _ in range(reps):
        mgr = mk()
        t0 = time.perf_counter()
        drive(mgr, apply_trace, mgr, iter(ops))
        scalar_s = min(scalar_s, time.perf_counter() - t0)
        mgr2 = mk()
        t0 = time.perf_counter()
        drive(mgr2, execute_compiled, ct, mgr2)
        engine_s = min(engine_s, time.perf_counter() - t0)
    assert mgr2.summary() == ref, f"{label or name}: engine summary diverged"

    n = len(ops)
    return {
        "workload": name,
        "label": label or name,
        "dos": dos,
        "policy": policy,
        "manager": manager,
        "ops": n,
        "migrations": ref["migrations"],
        "scalar_ms": scalar_s * 1e3,
        "engine_ms": engine_s * 1e3,
        "compile_ms": compile_s * 1e3,
        "scalar_ops_per_s": n / scalar_s,
        "engine_ops_per_s": n / engine_s,
        "speedup": scalar_s / engine_s,
        "summary_identical": True,
    }


def bench_sweep(jobs: int, dos_grid: list[int]) -> dict:
    """Wall time of a DOS sweep grid, serial vs parallel (cache off)."""
    def grid():
        return [SweepPoint(workload=n, total_bytes=int(CAP * d / 100.0),
                           capacity=CAP)
                for n in ("stream", "jacobi2d", "sgemm", "gesummv")
                for d in dos_grid]

    t0 = time.perf_counter()
    serial = run_sweep(grid(), jobs=0)
    serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    parallel = run_sweep(grid(), jobs=jobs)
    parallel_s = time.perf_counter() - t0
    assert serial == parallel, "parallel sweep rows diverged from serial"
    return {
        "points": len(serial),
        "jobs": jobs,
        "serial_s": serial_s,
        "parallel_s": parallel_s,
        "parallel_speedup": serial_s / parallel_s,
    }


# Table-2 compile-tier specs: generator lowering vs columnar emission.
# Alignment picks realistic range counts; the wave workloads use coarser
# ranges so the retry-amplified traces stay benchmark-sized.
COMPILE_TRACES = [
    dict(label="stream", name="stream", dos=147, alignment=8 * MB),
    dict(label="conv2d", name="conv2d", dos=147, alignment=8 * MB),
    dict(label="jacobi2d", name="jacobi2d", dos=147, alignment=8 * MB),
    dict(label="jacobi2d_aware", name="jacobi2d", dos=147,
         alignment=8 * MB, wl_kwargs={"svm_aware": True}),
    dict(label="bfs", name="bfs", dos=147, alignment=8 * MB),
    dict(label="sgemm", name="sgemm", dos=147, alignment=8 * MB),
    dict(label="sgemm_aware", name="sgemm", dos=147, alignment=8 * MB,
         wl_kwargs={"svm_aware": True}),
    dict(label="syr2k", name="syr2k", dos=147, alignment=8 * MB),
    dict(label="mvt", name="mvt", dos=147, alignment=32 * MB),
    dict(label="gesummv", name="gesummv", dos=147, alignment=32 * MB),
]


def bench_compile(name: str, dos: float, alignment: int, reps: int, *,
                  label: str | None = None,
                  wl_kwargs: dict | None = None) -> dict:
    """Generator-lowered vs columnar compile time on one workload trace;
    asserts the emitted columns are op-for-op identical."""
    import numpy as np

    space = AddressSpace(CAP, base=175 * MB, alignment=alignment)
    wl = make_workload(name, int(CAP * dos / 100.0), **(wl_kwargs or {}))
    wl.build(space)
    ct_gen = compile_trace(wl.trace(space))
    ct_col = wl.emit_columns(space)
    for f in ("codes", "rids", "concs", "hints", "fargs", "boundaries"):
        assert np.array_equal(getattr(ct_gen, f), getattr(ct_col, f)), \
            f"{label or name}: columnar {f} diverged"
    assert ct_gen.n_ops == ct_col.n_ops

    gen_s = col_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        compile_trace(wl.trace(space))
        gen_s = min(gen_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        wl.emit_columns(space)
        col_s = min(col_s, time.perf_counter() - t0)
    return {
        "workload": name,
        "label": label or name,
        "dos": dos,
        "ops": len(ct_gen),
        "generator_compile_ms": gen_s * 1e3,
        "columnar_compile_ms": col_s * 1e3,
        "compile_speedup": gen_s / col_s,
        "columns_identical": True,
    }


def bench_trace_cache(dos: float = 125) -> dict:
    """Cold-vs-warm cross-point trace sharing: one (workload × policy)
    grid where each workload's trace is shared by four policy points."""
    names = ("stream", "jacobi2d", "sgemm", "gesummv")
    policies = ("lrf", "lru", "clock", "random")

    def grid():
        return [SweepPoint(workload=n, total_bytes=int(CAP * dos / 100.0),
                           capacity=CAP, policy=p)
                for n in names for p in policies]

    t0 = time.perf_counter()
    uncached = [run_point(p, trace_cache=False) for p in grid()]
    uncached_s = time.perf_counter() - t0
    TRACE_CACHE.clear()
    t0 = time.perf_counter()
    cold = run_sweep(grid(), jobs=0)       # one compile per workload
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = run_sweep(grid(), jobs=0)       # all compiles cache hits
    warm_s = time.perf_counter() - t0
    assert uncached == cold == warm, "trace-cache rows diverged"
    return {
        "points": len(uncached),
        "distinct_traces": len(names),
        "uncached_s": uncached_s,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "cold_speedup": uncached_s / cold_s,
        "warm_speedup": uncached_s / warm_s,
    }


def bench_serving_decode(reps: int, *, steps: int = 30) -> dict:
    """Serving decode hot path (PR 4): one oversubscribed decode step —
    every layer's weight fetch plus its compute — driven through the
    `StreamingExecutor`'s TraceSession, scalar op-for-op replay vs
    compiled-segment replay of the cached per-token trace.  Tensor
    materialisation is off (``materialize=False``): the row measures the
    SVM-accounting path, which is what the session tier accelerates.
    Byte-identical `metrics()` asserted for every measured pair."""
    import numpy as np

    from repro.svm import StreamingExecutor

    n_layers, d, frac = 256, 1448, 0.6      # multi-MB leaves, DOS ~180 %
    rng = np.random.default_rng(0)
    params = {f"l{i:03d}": rng.standard_normal((d, d), dtype=np.float32)
              for i in range(n_layers)}
    total = n_layers * d * d * 4
    layer_paths = [[f"l{i:03d}"] for i in range(n_layers)]
    flops = [2.0 * d * d] * n_layers

    def mk(scalar):
        ex = StreamingExecutor(params, int(total * frac), scalar=scalar,
                               profile=False)
        # warm step: records + compiles the per-token trace (session) /
        # seeds the pool state (both)
        ex.decode_step(layer_paths, flops, materialize=False)
        return ex

    # equivalence: same number of decode steps on both paths
    ex_s, ex_b = mk(True), mk(False)
    for _ in range(3):
        ex_s.decode_step(layer_paths, flops, materialize=False)
        ex_b.decode_step(layer_paths, flops, materialize=False)
    assert ex_s.metrics() == ex_b.metrics(), \
        "serving decode: session metrics diverged from scalar"

    scalar_s = session_s = float("inf")
    for _ in range(reps):
        ex = mk(True)
        t0 = time.perf_counter()
        for _ in range(steps):
            ex.decode_step(layer_paths, flops, materialize=False)
        scalar_s = min(scalar_s, (time.perf_counter() - t0) / steps)
        ex = mk(False)
        t0 = time.perf_counter()
        for _ in range(steps):
            ex.decode_step(layer_paths, flops, materialize=False)
        session_s = min(session_s, (time.perf_counter() - t0) / steps)
        hits = ex.session.cache_hits
    n_touches = sum(len(ex.plan.leaf_ranges[p])
                    for paths in layer_paths for p in paths)
    return {
        "label": "decode_thrash",
        "layers": n_layers,
        "ops_per_step": n_touches + n_layers,   # touches + computes
        "dos": round(total / (total * frac) * 100.0),
        "steps": steps,
        "scalar_step_ms": scalar_s * 1e3,
        "session_step_ms": session_s * 1e3,
        "speedup": scalar_s / session_s,
        "segment_cache_hits": hits,
        "metrics_identical": True,
    }


def bench_scheduler(*, tokens: int = 12) -> dict:
    """Multi-tenant scheduler row: an oversubscribed 8-request mix (two
    architectures, offered DOS ~520 %) over one shared pool, fifo vs
    admission vs svm_aware.  The simulation is fully deterministic under
    its fixed seed, so the gated ratio — svm_aware must strictly reduce
    evictions per decoded token vs the fifo thrashing baseline — is
    exact, not a noisy wall-clock measurement.  A determinism check
    (same seed ⇒ identical result dict) rides along."""
    from repro.core import MB
    from repro.svm import ModelSpec, run_schedule

    # archA fits the pool (56 %); archB is individually oversubscribed
    # (120 %) — admission control helps both, and svm_aware's pinning
    # additionally bites on archB's internal thrash
    specs = [ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
             ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB)]
    cap = 100 * MB

    def one(policy):
        t0 = time.perf_counter()
        r = run_schedule(specs, 8, cap, policy=policy, seed=7,
                         tokens=tokens, spec_choice="roundrobin",
                         pin_frac=0.4)
        host_s = time.perf_counter() - t0
        return r, host_s

    rows = {}
    for policy in ("fifo", "admission", "svm_aware"):
        r, host_s = one(policy)
        rows[policy] = {
            "policy": policy,
            "sim_wall_s": r["makespan_s"],
            "agg_tok_s": r["agg_tok_s"],
            "latency_p99_s": r["latency_p99_s"],
            "evictions": r["evictions"],
            "evictions_per_token": r["evictions_per_token"],
            "segment_hit_rate": r["segment_hit_rate"],
            "segment_shared_hits": r["segment_shared_hits"],
            "dos_offered": r["dos_offered"],
            "dos_peak": r["dos_peak"],
            "host_wall_s": host_s,
        }
    redo, _ = one("svm_aware")
    assert redo["evictions"] == rows["svm_aware"]["evictions"] and \
        redo["makespan_s"] == rows["svm_aware"]["sim_wall_s"], \
        "scheduler: same seed produced a different run"

    fifo, aware = rows["fifo"], rows["svm_aware"]
    return {
        "label": "serve_sched_8req_mix",
        "requests": 8,
        "tokens": tokens,
        "policies": rows,
        "sim_wall_ratio": fifo["sim_wall_s"] / aware["sim_wall_s"],
        "evict_reduction": (fifo["evictions_per_token"]
                            / aware["evictions_per_token"]),
        "deterministic": True,
    }


def bench_scheduler_measured(*, tokens: int = 12) -> dict:
    """Measured-admission row (docs/prefetching.md): an oversubscribed
    8-request mix of a dense architecture and a sparse MoE-style
    architecture whose *plan* is pool-oversized (208 %) but whose
    *measured* working set (the routed experts plus dense trunk — what
    the touch columns prove is resident) is small.  Plan-bytes admission
    serialises the pool behind the MoE tenant's allocation; measured
    admission (`PoolScheduler(admit_by="measured")`) charges each tenant
    its estimated resident bytes, so the same watermark co-admits
    strictly more tenants.  The gated ratio — peak concurrently active
    tenants, measured / bytes — is conditioned on evictions per decoded
    token staying no worse than the plan-bytes run: admitting more
    tenants by thrashing harder would be cheating.  Fully deterministic
    under the fixed seed; a determinism recheck rides along."""
    from repro.core import MB
    from repro.svm import ModelSpec, run_schedule

    # dense archA fits the pool outright; moeB plans 208 % of the pool
    # (8 experts per layer) but routes to one expert, touching ~40 %
    specs = [ModelSpec.synthetic("archA", 8, 3 * MB, embed_bytes=6 * MB),
             ModelSpec.synthetic_moe("moeB", 12, 1 * MB, n_experts=8,
                                     expert_bytes=2 * MB,
                                     active_experts=1,
                                     embed_bytes=4 * MB)]
    cap = 100 * MB

    def one(admit_by):
        t0 = time.perf_counter()
        r = run_schedule(specs, 8, cap, policy="svm_aware", seed=7,
                         tokens=tokens, spec_choice="roundrobin",
                         pin_frac=0.4, admit_by=admit_by)
        host_s = time.perf_counter() - t0
        return r, host_s

    rows = {}
    for admit_by in ("bytes", "measured"):
        r, host_s = one(admit_by)
        rows[admit_by] = {
            "admit_by": admit_by,
            "peak_active_requests": r["peak_active_requests"],
            "sim_wall_s": r["makespan_s"],
            "agg_tok_s": r["agg_tok_s"],
            "latency_p99_s": r["latency_p99_s"],
            "evictions": r["evictions"],
            "evictions_per_token": r["evictions_per_token"],
            "dos_offered": r["dos_offered"],
            "dos_peak": r["dos_peak"],
            "profile_cache": r["profile_cache"],
            "host_wall_s": host_s,
        }
    redo, _ = one("measured")
    assert redo["evictions"] == rows["measured"]["evictions"] and \
        redo["makespan_s"] == rows["measured"]["sim_wall_s"], \
        "measured admission: same seed produced a different run"

    by, me = rows["bytes"], rows["measured"]
    admit_ratio = (me["peak_active_requests"]
                   / max(by["peak_active_requests"], 1))
    # the ratio only counts if the extra tenants do not thrash: measured
    # ev/token must stay within 5 % of the plan-bytes run's
    ev_ok = (me["evictions_per_token"]
             <= by["evictions_per_token"] * 1.05 + 1e-9)
    return {
        "label": "serve_sched_measured_admission",
        "requests": 8,
        "tokens": tokens,
        "admit_modes": rows,
        "admit_ratio": admit_ratio,
        "ev_tok_ok": ev_ok,
        "deterministic": True,
    }


def bench_scheduler_fused(*, requests: int = 512,
                          tokens: int = 35) -> dict:
    """Scheduler-scale fused-round row: ≥512 burst-arrival requests (two
    synthetic architectures, ~500k replayed ops) through one shared pool
    under svm_aware, whole rounds concatenated into single batched
    `execute_fused` passes vs the per-token reference replay
    (``fused=False``).  The pool is large enough that dozens of tenants
    decode concurrently — the regime the fused tier targets.  The two
    runs' result dicts must match exactly (only the ``fused`` marker and
    the concat counter may differ): the simulated side is deterministic,
    so the gated ratio is host wall time on identical work."""
    import dataclasses

    from repro.core import MB
    from repro.svm import ModelSpec, PoolScheduler, make_requests

    specs = [ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
             ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB)]
    cap = 6000 * MB
    reqs = make_requests(specs, requests, seed=7, tokens=tokens,
                         arrival="burst", spec_choice="roundrobin")

    def strip(r: dict) -> dict:
        r = dict(r)
        r.pop("fused")
        sc = dict(r["shared_cache"])
        for k in ("shared_concats", "concat_memo_entries",
                  "concat_memo_evictions"):
            sc.pop(k)
        r["shared_cache"] = sc
        return r

    def one(fused: bool):
        sched = PoolScheduler(cap, policy="svm_aware", pin_frac=0.4,
                              fused=fused)
        t0 = time.perf_counter()
        r = sched.run([dataclasses.replace(q) for q in reqs])
        host_s = time.perf_counter() - t0
        ops = sum(s.ops_replayed for s in sched._sessions)
        return r, host_s, ops

    r_f, fused_s, ops = one(True)
    r_p, ptok_s, ops_p = one(False)
    assert strip(r_f) == strip(r_p), \
        "scheduler fused: result diverged from per-token replay"
    assert ops == ops_p
    return {
        "label": f"serve_sched_fused_{requests}req",
        "requests": requests,
        "tokens": tokens,
        "ops_replayed": ops,
        "tokens_decoded": sum(q["tokens"] for q in r_f["requests"]),
        "round_concats": r_f["shared_cache"]["shared_concats"],
        "fused_host_s": fused_s,
        "per_token_host_s": ptok_s,
        "fused_ops_per_s": ops / fused_s,
        "per_token_ops_per_s": ops / ptok_s,
        "speedup": ptok_s / fused_s,
        "result_identical": True,
    }


def bench_scheduler_scale(*, requests: int = 1024,
                          tokens: int = 110) -> dict:
    """Vectorized-window scheduler row at serving scale: a 1024-request
    / ~2.3M-op burst schedule where steady-state rounds fuse into
    multi-round window passes (`CompiledTrace.tile` + one `execute_fused`
    + NumPy column attribution over the round × request cut table).
    Measures the vectorized tier's sustained ops/s and its speedup over
    the per-request/per-token reference loop (``fused=False``), and
    asserts byte-identity on both the clean schedule and the default
    seeded chaos schedule (windows must degrade fused → per-token →
    scalar without changing a single counter)."""
    import dataclasses

    from repro.core import MB
    from repro.svm import ModelSpec, PoolScheduler, make_requests
    from repro.svm.faults import FaultPlan

    specs = [ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB),
             ModelSpec.synthetic("archB", 10, 2 * MB, embed_bytes=6 * MB)]
    cap = 6000 * MB
    reqs = make_requests(specs, requests, seed=5, tokens=tokens,
                         arrival="burst", spec_choice="roundrobin")

    def strip(r: dict) -> dict:
        r = dict(r)
        r.pop("fused")
        sc = dict(r["shared_cache"])
        for k in ("shared_concats", "concat_memo_entries",
                  "concat_memo_evictions"):
            sc.pop(k)
        r["shared_cache"] = sc
        if "chaos" in r:
            ch = dict(r["chaos"])
            ch.pop("degraded_rounds")   # fused-tier-only marker
            r["chaos"] = ch
        return r

    def one(fused: bool, plan=None):
        sched = PoolScheduler(cap, policy="svm_aware", pin_frac=0.4,
                              fused=fused, fault_plan=plan)
        t0 = time.perf_counter()
        r = sched.run([dataclasses.replace(q) for q in reqs])
        host_s = time.perf_counter() - t0
        ops = sum(s.ops_replayed for s in sched._sessions)
        return r, host_s, ops

    r_v, vec_s, ops = one(True)
    r_p, ptok_s, ops_p = one(False)
    assert strip(r_v) == strip(r_p), \
        "scheduler scale: vectorized result diverged from per-token"
    assert ops == ops_p
    plan = FaultPlan.default(9, n_requests=requests, tokens=tokens)
    r_vc, _, _ = one(True, plan)
    r_pc, _, _ = one(False, plan)
    assert strip(r_vc) == strip(r_pc), \
        "scheduler scale: chaos-schedule result diverged from per-token"
    return {
        "label": f"serve_sched_scale_{requests}req",
        "requests": requests,
        "tokens": tokens,
        "ops_replayed": ops,
        "tokens_decoded": sum(q["tokens"] for q in r_v["requests"]),
        "vectorized_host_s": vec_s,
        "per_token_host_s": ptok_s,
        "vectorized_ops_per_s": ops / vec_s,
        "per_token_ops_per_s": ops / ptok_s,
        "speedup": ptok_s / vec_s,
        "result_identical": True,
        "chaos_result_identical": True,
    }


def bench_scheduler_chaos(*, requests: int = 64, tokens: int = 8) -> dict:
    """Chaos-retention row: the seeded 64-request mix (same architectures
    and pool as the scheduler row) run clean vs under the default
    `FaultPlan` with the thrash guard armed.  Both runs are deterministic
    simulations, so the gated ratio — aggregate decode throughput
    retained under injected faults — is exact.  The chaos run must also
    finish everything: zero failed requests, zero unapplied events, and
    exact conservation including every injected surcharge."""
    from repro.core import MB
    from repro.svm import FaultPlan, ModelSpec, PoolScheduler, make_requests

    specs = [ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
             ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB)]
    cap = 100 * MB

    def one(plan):
        reqs = make_requests(specs, requests, seed=0, tokens=tokens,
                             mean_interarrival_s=2e-3)
        sched = PoolScheduler(cap, policy="svm_aware", fault_plan=plan,
                              thrash_watermark=3.0, thrash_window=32)
        t0 = time.perf_counter()
        r = sched.run(reqs)
        return r, time.perf_counter() - t0

    clean, clean_host_s = one(None)
    plan = FaultPlan.default(0, n_requests=requests, tokens=tokens)
    chaos, chaos_host_s = one(plan)
    ch = chaos["chaos"]
    assert chaos["n_failed"] == 0 and ch["retry_exhausted"] == 0 and \
        ch["injector"]["events_remaining"] == 0, \
        "scheduler chaos: unhandled faults in the gate schedule"
    c, m = chaos["conservation"], chaos["mgr"]
    assert abs(c["svm_wall_s"] - m["wall_s"]) < 1e-9 and \
        c["evictions"] == m["evictions"], \
        "scheduler chaos: conservation broke under injection"
    return {
        "label": f"serve_sched_chaos_{requests}req",
        "requests": requests,
        "tokens": tokens,
        "plan_seed": 0,
        "fault_events": ch["injector"]["events_total"],
        "migration_faults": ch["migration_faults"],
        "retries": ch["retries"],
        "crashes": ch["crashes"],
        "preemptions": ch["preemptions"],
        "incidents": len(chaos["incidents"]),
        "clean_tok_s": clean["agg_tok_s"],
        "chaos_tok_s": chaos["agg_tok_s"],
        "clean_makespan_s": clean["makespan_s"],
        "chaos_makespan_s": chaos["makespan_s"],
        "clean_host_s": clean_host_s,
        "chaos_host_s": chaos_host_s,
        "retention": chaos["agg_tok_s"] / clean["agg_tok_s"],
        "all_completed": True,
    }


# the §4.2 / UVM configurations that used to drop to the scalar path —
# each is a named row in BENCH_engine.json and part of the variant gate
VARIANT_TRACES = [
    dict(label="stream147_defer", name="stream", dos=147, alignment=8 * MB,
         mgr_kwargs={"defer_granule": 2 * MB, "defer_k": 3}),
    dict(label="stream147_previct", name="stream", dos=147,
         alignment=8 * MB, mgr_kwargs={"previct_watermark": 0.1}),
    dict(label="stream147_zero_copy", name="stream", dos=147,
         alignment=8 * MB, zero_copy=("b",)),
    dict(label="gesummv125_previct", name="gesummv", dos=125,
         alignment=32 * MB, mgr_kwargs={"previct_watermark": 0.1}),
    dict(label="uvm_jacobi109", name="jacobi2d", dos=109,
         alignment=256 * MB, manager="uvm"),
    dict(label="uvm_gesummv109", name="gesummv", dos=109,
         alignment=256 * MB, manager="uvm",
         wl_kwargs={"retry_override": 1}),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI variant: fewer reps, smaller grid")
    ap.add_argument("--jobs", type=int,
                    default=min(os.cpu_count() or 1, 8))
    args = ap.parse_args()

    reps = 8 if args.smoke else 15
    traces = [
        # the acceptance-gate case: all-miss linear streaming at DOS 147
        ("stream", 147, 4 * MB),
        ("stream", 147, 8 * MB),
        # hit-dominated (below oversubscription) and thrash-dominated
        ("mvt", 78, 8 * MB),
        ("gesummv", 147, 32 * MB),
    ]
    variant_traces = list(VARIANT_TRACES)
    compile_traces = list(COMPILE_TRACES)
    if args.smoke:
        traces = traces[:2] + traces[2:3]
        variant_traces = [v for v in variant_traces
                          if v["label"] in ("stream147_defer",
                                            "stream147_previct",
                                            "uvm_jacobi109")]
        compile_traces = [c for c in compile_traces
                          if c["label"] in ("stream", "jacobi2d", "sgemm",
                                            "mvt", "gesummv")]

    out = {"traces": [], "compile": [], "variants": [], "sweep": None,
           "trace_cache": None, "serving": None, "scheduler": None,
           "scheduler_measured": None, "scheduler_fused": None,
           "scheduler_chaos": None, "scheduler_scale": None}
    for name, dos, align in traces:
        row = bench_trace(name, dos, align, reps)
        out["traces"].append(row)
        print(f"{name}@{dos}: {row['ops']} ops, "
              f"scalar {row['scalar_ms']:.2f}ms "
              f"({row['scalar_ops_per_s']/1e3:.0f}k ops/s), "
              f"engine {row['engine_ms']:.2f}ms "
              f"({row['engine_ops_per_s']/1e3:.0f}k ops/s), "
              f"speedup {row['speedup']:.1f}x", flush=True)

    for spec in compile_traces:
        spec = dict(spec)
        row = bench_compile(spec.pop("name"), spec.pop("dos"),
                            spec.pop("alignment"), reps, **spec)
        out["compile"].append(row)
        print(f"compile {row['label']}: {row['ops']} ops, "
              f"generator {row['generator_compile_ms']:.2f}ms, "
              f"columnar {row['columnar_compile_ms']:.3f}ms, "
              f"speedup {row['compile_speedup']:.1f}x", flush=True)

    for spec in variant_traces:
        spec = dict(spec)
        row = bench_trace(spec.pop("name"), spec.pop("dos"),
                          spec.pop("alignment"), reps, **spec)
        out["variants"].append(row)
        print(f"{row['label']}: scalar {row['scalar_ms']:.2f}ms, "
              f"engine {row['engine_ms']:.2f}ms, "
              f"speedup {row['speedup']:.1f}x", flush=True)

    dos_grid = [78, 109] if args.smoke else [78, 109, 147]
    out["sweep"] = bench_sweep(args.jobs, dos_grid)
    s = out["sweep"]
    print(f"sweep {s['points']}pts: serial {s['serial_s']:.2f}s, "
          f"{s['jobs']} jobs {s['parallel_s']:.2f}s "
          f"({s['parallel_speedup']:.1f}x)", flush=True)

    out["trace_cache"] = bench_trace_cache()
    tc = out["trace_cache"]
    print(f"trace-cache {tc['points']}pts/{tc['distinct_traces']}traces: "
          f"uncached {tc['uncached_s']:.2f}s, cold {tc['cold_s']:.2f}s "
          f"({tc['cold_speedup']:.2f}x), warm {tc['warm_s']:.2f}s "
          f"({tc['warm_speedup']:.2f}x)", flush=True)

    out["serving"] = bench_serving_decode(
        max(3, reps // 3), steps=10 if args.smoke else 30)
    sv = out["serving"]
    print(f"serving {sv['label']}: {sv['ops_per_step']} ops/step @ "
          f"DOS {sv['dos']}%, scalar {sv['scalar_step_ms']:.3f}ms/step, "
          f"session {sv['session_step_ms']:.3f}ms/step, "
          f"speedup {sv['speedup']:.1f}x", flush=True)

    out["scheduler"] = bench_scheduler(tokens=8 if args.smoke else 12)
    sc = out["scheduler"]
    print(f"scheduler {sc['label']}: "
          f"fifo {sc['policies']['fifo']['evictions_per_token']:.2f} "
          f"ev/tok, admission "
          f"{sc['policies']['admission']['evictions_per_token']:.2f}, "
          f"svm_aware "
          f"{sc['policies']['svm_aware']['evictions_per_token']:.2f} "
          f"(reduction {sc['evict_reduction']:.2f}x, "
          f"sim wall {sc['sim_wall_ratio']:.2f}x)", flush=True)

    out["scheduler_measured"] = bench_scheduler_measured(
        tokens=8 if args.smoke else 12)
    sm = out["scheduler_measured"]
    print(f"scheduler {sm['label']}: bytes admits "
          f"{sm['admit_modes']['bytes']['peak_active_requests']} peak / "
          f"{sm['admit_modes']['bytes']['evictions_per_token']:.2f} "
          f"ev/tok, measured "
          f"{sm['admit_modes']['measured']['peak_active_requests']} peak "
          f"/ {sm['admit_modes']['measured']['evictions_per_token']:.2f} "
          f"ev/tok (ratio {sm['admit_ratio']:.2f}x, "
          f"ev_ok={sm['ev_tok_ok']})", flush=True)

    # the fused-round config is the gate config even under --smoke: the
    # fused tier only engages at scale, so a scaled-down smoke row would
    # measure (and gate) the wrong regime
    out["scheduler_fused"] = bench_scheduler_fused()
    sf = out["scheduler_fused"]
    print(f"scheduler {sf['label']}: {sf['ops_replayed']} ops / "
          f"{sf['tokens_decoded']} tokens, "
          f"fused {sf['fused_host_s']:.2f}s "
          f"({sf['fused_ops_per_s'] / 1e3:.0f}k ops/s) vs per-token "
          f"{sf['per_token_host_s']:.2f}s "
          f"({sf['per_token_ops_per_s'] / 1e3:.0f}k ops/s), "
          f"speedup {sf['speedup']:.2f}x", flush=True)

    # the chaos config is fixed even under --smoke: the FaultPlan seed,
    # request count, and token budget define the gate schedule
    out["scheduler_chaos"] = bench_scheduler_chaos()
    sx = out["scheduler_chaos"]
    print(f"scheduler {sx['label']}: {sx['fault_events']} fault events "
          f"({sx['migration_faults']} faults / {sx['retries']} retries / "
          f"{sx['crashes']} crash), clean {sx['clean_tok_s']:.1f} tok/s, "
          f"chaos {sx['chaos_tok_s']:.1f} tok/s "
          f"(retention {sx['retention']:.2f}x)", flush=True)

    # the scale config is the gate config even under --smoke: the
    # vectorized window tier and the sorted-array eviction sweep only
    # engage on the thousand-request / million-op regime
    out["scheduler_scale"] = bench_scheduler_scale()
    ss = out["scheduler_scale"]
    print(f"scheduler {ss['label']}: {ss['ops_replayed']} ops / "
          f"{ss['tokens_decoded']} tokens, "
          f"vectorized {ss['vectorized_host_s']:.2f}s "
          f"({ss['vectorized_ops_per_s'] / 1e6:.2f}M ops/s) vs per-token "
          f"{ss['per_token_host_s']:.2f}s "
          f"({ss['per_token_ops_per_s'] / 1e6:.2f}M ops/s), "
          f"speedup {ss['speedup']:.2f}x", flush=True)

    gate = max((r["speedup"] for r in out["traces"]
                if r["workload"] == "stream" and r["dos"] == 147))
    if gate < 10.0:
        # noisy-neighbour window: one patient retry on the gate trace
        retry = bench_trace("stream", 147, 8 * MB, reps * 3)
        out["traces"].append(retry)
        gate = max(gate, retry["speedup"])
    out["gate_stream147_speedup"] = gate
    out["gate_met"] = gate >= 10.0

    # variant gate: every previously-scalar-fallback configuration must
    # hold >= 5x on the fast tier (one patient retry per noisy row)
    best = {r["label"]: r["speedup"] for r in out["variants"]}
    for label, speedup in list(best.items()):
        if speedup >= 5.0:
            continue
        spec = dict(next(v for v in VARIANT_TRACES if v["label"] == label))
        retry = bench_trace(spec.pop("name"), spec.pop("dos"),
                            spec.pop("alignment"), reps * 3, **spec)
        out["variants"].append(retry)
        best[label] = max(speedup, retry["speedup"])
        print(f"{label}: retry speedup {retry['speedup']:.1f}x", flush=True)
    vgate = min(best.values())
    out["gate_variant_min_speedup"] = vgate
    out["gate_variant_met"] = vgate >= 5.0

    # compile gate: columnar emission >= 5x generator lowering on every
    # Table-2 trace (one patient retry per noisy row)
    cbest = {r["label"]: r["compile_speedup"] for r in out["compile"]}
    for label, speedup in list(cbest.items()):
        if speedup >= 5.0:
            continue
        spec = dict(next(c for c in COMPILE_TRACES if c["label"] == label))
        retry = bench_compile(spec.pop("name"), spec.pop("dos"),
                              spec.pop("alignment"), reps * 3, **spec)
        out["compile"].append(retry)
        cbest[label] = max(speedup, retry["compile_speedup"])
        print(f"compile {label}: retry speedup "
              f"{retry['compile_speedup']:.1f}x", flush=True)
    cgate = min(cbest.values())
    out["gate_compile_min_speedup"] = cgate
    out["gate_compile_met"] = cgate >= 5.0

    # serving gate: compiled-session decode replay >= 5x the scalar
    # imperative walk (one patient retry on a noisy box)
    sgate = out["serving"]["speedup"]
    if sgate < 5.0:
        retry = bench_serving_decode(max(3, reps // 3) * 3,
                                     steps=10 if args.smoke else 30)
        out["serving_retry"] = retry
        sgate = max(sgate, retry["speedup"])
        print(f"serving retry speedup {retry['speedup']:.1f}x", flush=True)
    out["gate_serving_decode_speedup"] = sgate
    out["gate_serving_met"] = sgate >= 5.0

    # scheduler gate: svm_aware must strictly reduce evictions/token vs
    # the fifo thrashing baseline on the 8-request mix.  The simulation
    # is deterministic (fixed seed), so no retry logic is needed.
    scgate = out["scheduler"]["evict_reduction"]
    out["gate_sched_evict_reduction"] = scgate
    out["gate_sched_met"] = scgate >= 1.5

    # measured-admission gate: capping admitted *measured* bytes instead
    # of plan bytes must co-admit >= 1.2x the tenants of the plan-bytes
    # run at evictions/token no worse than it (within 5 %) — the ratio
    # is zeroed if the thrash condition fails, so a regression in either
    # half trips the gate.  Deterministic simulation, no retry.
    mgate = (out["scheduler_measured"]["admit_ratio"]
             if out["scheduler_measured"]["ev_tok_ok"] else 0.0)
    out["gate_measured_admission"] = mgate
    out["gate_measured_met"] = mgate >= 1.2

    # fused-round gate: one fused pass per scheduler round must run the
    # 512-request trace >= 3x faster than per-token replay (one patient
    # retry — the sim side is deterministic but host wall is not)
    fgate = out["scheduler_fused"]["speedup"]
    if fgate < 3.0:
        retry = bench_scheduler_fused()
        out["scheduler_fused_retry"] = retry
        fgate = max(fgate, retry["speedup"])
        print(f"scheduler fused retry speedup {retry['speedup']:.2f}x",
              flush=True)
    out["gate_sched_fused_speedup"] = fgate
    out["gate_sched_fused_met"] = fgate >= 3.0

    # scale gate: the vectorized tier must sustain >= 2.5M replayed
    # ops/s on the 1024-request burst schedule AND beat the per-token
    # reference loop >= 3x (one patient retry — the schedule is
    # deterministic but host wall is not)
    ssgate = out["scheduler_scale"]["vectorized_ops_per_s"]
    ssfast = out["scheduler_scale"]["speedup"]
    if ssgate < 2.5e6 or ssfast < 3.0:
        retry = bench_scheduler_scale()
        out["scheduler_scale_retry"] = retry
        ssgate = max(ssgate, retry["vectorized_ops_per_s"])
        ssfast = max(ssfast, retry["speedup"])
        print(f"scheduler scale retry "
              f"{retry['vectorized_ops_per_s'] / 1e6:.2f}M ops/s "
              f"({retry['speedup']:.2f}x)", flush=True)
    out["gate_sched_scale_ops_per_s"] = ssgate
    out["gate_sched_scale_speedup"] = ssfast
    out["gate_sched_scale_met"] = ssgate >= 2.5e6 and ssfast >= 3.0

    # chaos gate: the serving stack must retain >= 0.5x of its clean
    # aggregate decode throughput under the default seeded fault
    # schedule (deterministic simulation, no retry logic needed)
    xgate = out["scheduler_chaos"]["retention"]
    out["gate_sched_chaos_retention"] = xgate
    out["gate_sched_chaos_met"] = xgate >= 0.5

    print(f"gate: stream DOS-147 speedup {gate:.1f}x "
          f"(target >= 10x) -> {'PASS' if out['gate_met'] else 'FAIL'}")
    print(f"gate: variant min speedup {vgate:.1f}x "
          f"(target >= 5x) -> "
          f"{'PASS' if out['gate_variant_met'] else 'FAIL'}")
    print(f"gate: columnar compile min speedup {cgate:.1f}x "
          f"(target >= 5x) -> "
          f"{'PASS' if out['gate_compile_met'] else 'FAIL'}")
    print(f"gate: serving decode-step speedup {sgate:.1f}x "
          f"(target >= 5x) -> "
          f"{'PASS' if out['gate_serving_met'] else 'FAIL'}")
    print(f"gate: scheduler svm_aware evict/token reduction "
          f"{scgate:.2f}x (target >= 1.5x) -> "
          f"{'PASS' if out['gate_sched_met'] else 'FAIL'}")
    print(f"gate: measured-admission tenant ratio {mgate:.2f}x "
          f"(target >= 1.2x, ev/token no worse) -> "
          f"{'PASS' if out['gate_measured_met'] else 'FAIL'}")
    print(f"gate: fused-round scheduler speedup {fgate:.2f}x "
          f"(target >= 3x) -> "
          f"{'PASS' if out['gate_sched_fused_met'] else 'FAIL'}")
    print(f"gate: vectorized scheduler scale {ssgate / 1e6:.2f}M ops/s "
          f"(target >= 2.5M, speedup {ssfast:.2f}x >= 3x) -> "
          f"{'PASS' if out['gate_sched_scale_met'] else 'FAIL'}")
    print(f"gate: chaos throughput retention {xgate:.2f}x "
          f"(target >= 0.5x) -> "
          f"{'PASS' if out['gate_sched_chaos_met'] else 'FAIL'}")

    for path in (os.path.join(ROOT, "BENCH_engine.json"),
                 os.path.join(ROOT, "results", "bench",
                              "BENCH_engine.json")):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    print("wrote BENCH_engine.json")


if __name__ == "__main__":
    main()
