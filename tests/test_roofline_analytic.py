"""Roofline term computation and the analytic FLOP/traffic models."""

import pytest

from repro.configs import ARCH_IDS
from repro.launch.analytic import analytic_bytes_per_device, analytic_flops_global
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms
from repro.launch.settings import SHAPES, cell_skipped


def _row(arch="granite-3-2b", shape="train_4k", flops=1e12, nbytes=1e11,
         coll=1e9, model=1e15):
    return {
        "arch": arch, "shape": shape,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": nbytes,
        "collectives": {"effective_bytes_per_device": coll},
        "model_flops_global": model,
    }


def test_terms_and_dominance():
    t = roofline_terms(_row(), 256)
    assert t["t_compute_s"] == pytest.approx(1e12 / PEAK_FLOPS)
    assert t["t_collective_s"] == pytest.approx(1e9 / ICI_BW)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert 0.0 <= t["roofline_fraction"] <= 1.0 + 1e-9
    assert t["fraction_resource"] >= t["roofline_fraction"]


def test_negative_collective_clamped_and_flagged():
    t = roofline_terms(_row(coll=-5e9), 256)
    assert t["t_collective_s"] == 0.0
    assert t["collective_nonlinear_flag"] is True


def test_analytic_models_cover_every_cell():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if cell_skipped(arch, shape):
                continue
            b = analytic_bytes_per_device(arch, shape)
            f = analytic_flops_global(arch, shape)
            assert b > 0 and f > 0, (arch, shape)


def test_analytic_flops_scaling_relations():
    # attention-free arch: train/prefill process the same 1M tokens, so the
    # ratio is exactly the bwd(2x)+remat(1x) factor = 4x
    f_train = analytic_flops_global("falcon-mamba-7b", "train_4k")
    f_prefill = analytic_flops_global("falcon-mamba-7b", "prefill_32k")
    assert f_train == pytest.approx(4.0 * f_prefill, rel=1e-6)
    # attention arch: prefill's 8x-longer sequences add quadratic work,
    # shrinking the ratio below 4 but keeping it above 1
    f_train_a = analytic_flops_global("granite-3-2b", "train_4k")
    f_prefill_a = analytic_flops_global("granite-3-2b", "prefill_32k")
    assert 1.0 < f_train_a / f_prefill_a < 4.0
    # decode processes B tokens, not B*S
    f_decode = analytic_flops_global("granite-3-2b", "decode_32k")
    assert f_decode < f_prefill_a / 1000


def test_analytic_memory_decode_dominated_by_weights_and_cache():
    b = analytic_bytes_per_device("granite-20b", "decode_32k")
    # must at least stream the TP-sharded active weights once
    from repro.configs import get_config
    cfg = get_config("granite-20b")
    assert b >= cfg.active_param_count() * 2 / 16


def test_memory_term_prefers_analytic_model():
    t = roofline_terms(_row(nbytes=1e14), 256)   # inflated HLO bytes
    assert t["t_memory_hlo_upper_s"] == pytest.approx(1e14 / HBM_BW)
    assert t["t_memory_s"] < t["t_memory_hlo_upper_s"]
