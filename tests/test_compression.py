"""Gradient compression: quantisation error, error feedback, compressed
all-reduce, and convergence preservation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import OptConfig, make_optimizer
from repro.optim.compression import (
    compress,
    compressed_psum,
    decompress,
    init_error_feedback,
    quantize_with_error_feedback,
)


def test_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = compress(g)
    back = decompress(q, s, g.shape, g.dtype)
    # int8 symmetric: error <= scale/2 per element
    per_block_scale = np.repeat(np.asarray(s), 256)[:1000]
    assert np.all(np.abs(np.asarray(back - g)) <= per_block_scale / 2 + 1e-7)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=2000),
       scale=st.floats(min_value=1e-6, max_value=1e4))
def test_property_roundtrip_relative_error(n, scale):
    g = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    q, s = compress(g)
    back = decompress(q, s, g.shape, g.dtype)
    err = float(jnp.max(jnp.abs(back - g)))
    assert err <= scale * 0.05 + 1e-6  # ~1/254 of block max, with headroom


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.full((512,), 0.001)}
    err = init_error_feedback(grads)
    g1, err = quantize_with_error_feedback(grads, err)
    # tiny uniform gradients quantise exactly (scale = g/127) — residual ~0;
    # mix scales so residual is non-trivial:
    grads2 = {"w": jnp.concatenate([jnp.full((256,), 1.0),
                                    jnp.full((256,), 1e-4)])}
    err2 = init_error_feedback(grads2)
    total_in, total_out = jnp.zeros(()), jnp.zeros(())
    g = grads2
    for _ in range(50):
        gq, err2 = quantize_with_error_feedback(g, err2)
        total_in += jnp.sum(g["w"])
        total_out += jnp.sum(gq["w"])
    # error feedback keeps the long-run transmitted mass unbiased
    assert float(jnp.abs(total_out - total_in) / total_in) < 1e-3


def test_compressed_psum_matches_fp32_within_tolerance():
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("dp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

    def f(xl):
        return compressed_psum(xl, "dp")

    out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                    check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=2e-2, atol=2e-2)


def test_convergence_with_compression():
    """AdamW on a quadratic with int8+EF grads still converges."""
    cfg = OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                    total_steps=200)
    init, update = make_optimizer(cfg)
    params = {"w": jnp.full((512,), 2.0)}
    state = init(params)
    err = init_error_feedback(params)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)
        grads, err = quantize_with_error_feedback(grads, err)
        params, state = update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).mean()) < 0.2
