"""Multi-tenant serving scheduler over one shared SVM pool.

Covers: deterministic seeded runs, the conservation contract (per-request
accounting sums exactly to the shared manager's aggregates), the policy
gate contract (svm_aware strictly reduces evictions/token vs fifo on the
benchmarked oversubscribed 8-request mix), the cross-request shared
compiled-segment contract (same-architecture requests replay one
relocated segment), scalar ≡ batched equivalence, admission watermark
behaviour, and the engine/planner primitives the scheduler stands on
(`CompiledTrace.relocate`, `SegmentCache`, aligned shared-pool plans)."""

import numpy as np
import pytest

from repro.core import MB, AddressSpace, SegmentCache, SVMManager, TraceSession
from repro.core.ranges import DEFAULT_BASE
from repro.svm import (
    ModelSpec,
    PoolScheduler,
    StreamingExecutor,
    make_requests,
    plan_leaf_ranges,
    run_schedule,
)

SPEC_A = ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB)
SPEC_B = ModelSpec.synthetic("archB", 10, 2 * MB, embed_bytes=6 * MB)

# the bench_engine.py gate mix: archA fits the pool, archB is
# individually oversubscribed
GATE_SPECS = [
    ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
    ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB),
]
GATE_CAP = 100 * MB


# ------------------------------------------------------------- primitives

def test_compiled_trace_relocate():
    space = AddressSpace(64 * MB, alignment=2 * MB)
    for i in range(8):
        space.alloc(2 * MB, f"a{i}")
    mgr = SVMManager(space, profile=False)
    sess = TraceSession(mgr)
    for rid in (0, 1, 2):
        sess.touch(rid, concurrency=8)
    sess.compute(1e-4)
    ct = sess.seal()
    moved = ct.relocate(4)
    assert moved.rids.tolist()[:3] == [4, 5, 6]
    assert moved.rids.tolist()[3] == -1          # compute rid untouched
    assert moved.touch_rid_np.tolist() == [4, 5, 6]
    assert ct.rids.tolist()[:3] == [0, 1, 2]     # source unchanged
    assert not moved.rids.flags.writeable        # still frozen
    # identity relocation is a plain copy sharing columns
    same = ct.relocate(0)
    assert same.rids is ct.rids


def test_segment_cache_relocates_between_bases():
    space = AddressSpace(64 * MB, alignment=2 * MB)
    for i in range(8):
        space.alloc(2 * MB, f"a{i}")
    mgr = SVMManager(space, profile=False)
    cache = SegmentCache()
    s0 = TraceSession(mgr, shared_cache=cache, rid_base=0)
    s4 = TraceSession(mgr, shared_cache=cache, rid_base=4)

    def rec(base):
        def f(s):
            s.touch(base + 0, concurrency=8)
            s.touch(base + 1, concurrency=8)
        return f

    s0.run("tok", rec(0))
    assert s0.cache_misses == 1 and cache.misses == 1
    s4.run("tok", rec(4))                 # never records: shared + shift
    assert s4.cache_misses == 0 and s4.shared_hits == 1
    assert cache.relocations == 1
    assert {0, 1, 4, 5} <= mgr.resident
    # second token on each session: local LRU, no new shared traffic
    s0.run("tok", rec(0))
    s4.run("tok", rec(4))
    assert s0.cache_hits == 1 and s4.cache_hits == 1
    assert cache.hits == 1 and cache.misses == 1


def test_aligned_shared_plans_are_congruent():
    space = AddressSpace(256 * MB, base=DEFAULT_BASE)
    leaves = list(SPEC_A.leaves)
    p1 = plan_leaf_ranges(leaves, 256 * MB, space=space, align_start=True)
    space.alloc(3 * MB, "intruder")       # misalign the cursor
    p2 = plan_leaf_ranges(leaves, 256 * MB, space=space, align_start=True)
    assert p1.geometry() == p2.geometry()
    assert p2.rid_base > p1.rid_base
    delta = p2.rid_base - p1.rid_base
    for path, rids in p1.leaf_ranges.items():
        assert [r + delta for r in rids] == p2.leaf_ranges[path]


# ----------------------------------------------------- arrival generation

def test_make_requests_deterministic_and_seeded():
    kw = dict(mean_interarrival_s=0.01, tokens=16, token_jitter=4)
    a = make_requests([SPEC_A, SPEC_B], 12, seed=5, **kw)
    b = make_requests([SPEC_A, SPEC_B], 12, seed=5, **kw)
    c = make_requests([SPEC_A, SPEC_B], 12, seed=6, **kw)
    assert [(r.arrival_s, r.spec.arch, r.n_tokens) for r in a] == \
           [(r.arrival_s, r.spec.arch, r.n_tokens) for r in b]
    assert [(r.arrival_s, r.spec.arch, r.n_tokens) for r in a] != \
           [(r.arrival_s, r.spec.arch, r.n_tokens) for r in c]
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[0] == 0.0


def test_burst_and_validation():
    reqs = make_requests([SPEC_A], 4, seed=0, mean_interarrival_s=0.0)
    assert all(r.arrival_s == 0.0 for r in reqs)
    with pytest.raises(ValueError, match="arrival"):
        make_requests([SPEC_A], 2, arrival="bimodal")
    with pytest.raises(ValueError, match="spec_choice"):
        make_requests([SPEC_A], 2, spec_choice="alphabetical")
    with pytest.raises(ValueError, match="policy"):
        PoolScheduler(64 * MB, policy="sjf")


# ------------------------------------------------- determinism + equivalence

def test_run_schedule_deterministic():
    kw = dict(policy="svm_aware", seed=9, tokens=6,
              mean_interarrival_s=0.005, spec_choice="roundrobin")
    cap = int(SPEC_A.total_bytes * 1.5)
    r1 = run_schedule([SPEC_A, SPEC_B], 6, cap, **kw)
    r2 = run_schedule([SPEC_A, SPEC_B], 6, cap, **kw)
    assert r1 == r2


@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
def test_scalar_session_equivalence(policy):
    """Batched segment replay ≡ scalar op-for-op replay, end to end."""
    cap = int(SPEC_A.total_bytes * 1.4)
    kw = dict(policy=policy, seed=3, tokens=5, spec_choice="roundrobin",
              pin_frac=0.4)
    fast = run_schedule([SPEC_A, SPEC_B], 4, cap, **kw)
    slow = run_schedule([SPEC_A, SPEC_B], 4, cap, scalar=True, **kw)
    # execution-mode markers intentionally differ (scalar mode has no
    # batched interpreter, hence no fused rounds or concat builds);
    # everything observable must match byte for byte
    for r in (fast, slow):
        r.pop("fused")
        for k in ("shared_concats", "concat_memo_entries",
                  "concat_memo_evictions"):
            r["shared_cache"].pop(k)
    assert fast == slow


# ------------------------------------------------------------ conservation

@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
def test_per_request_accounting_sums_to_manager(policy):
    cap = int(SPEC_A.total_bytes * 1.4)
    r = run_schedule([SPEC_A, SPEC_B], 8, cap, policy=policy, seed=7,
                     tokens=8, mean_interarrival_s=0.002,
                     spec_choice="roundrobin", pin_frac=0.4)
    c, m = r["conservation"], r["mgr"]
    assert c["migrations"] == m["migrations"]
    assert c["evictions"] == m["evictions"]
    assert c["bytes_migrated"] == m["bytes_migrated"]
    assert c["bytes_evicted"] == m["bytes_evicted"]
    assert c["svm_wall_s"] == pytest.approx(m["wall_s"], rel=1e-12)
    # every request ran to completion and the rows carry the accounting
    assert all(row["tokens"] > 0 for row in r["requests"])
    assert sum(row["migrations"] for row in r["requests"]) == \
        m["migrations"]


# ------------------------------------------------------- policy contracts

def test_svm_aware_strictly_beats_fifo_on_gate_mix():
    """The committed bench gate's contract, as a tier-1 test: on the
    oversubscribed 8-request mix, svm_aware strictly reduces evictions
    per decoded token (and p99 latency) vs the fifo baseline, with
    admission in between."""
    out = {}
    for policy in ("fifo", "admission", "svm_aware"):
        out[policy] = run_schedule(
            GATE_SPECS, 8, GATE_CAP, policy=policy, seed=7, tokens=12,
            spec_choice="roundrobin", pin_frac=0.4)
    fifo, adm, aware = out["fifo"], out["admission"], out["svm_aware"]
    assert aware["evictions_per_token"] < adm["evictions_per_token"] \
        < fifo["evictions_per_token"]
    assert fifo["evictions_per_token"] \
        >= 1.5 * aware["evictions_per_token"]
    assert aware["latency_p99_s"] < fifo["latency_p99_s"]
    assert aware["agg_tok_s"] > fifo["agg_tok_s"]
    # fifo admits everything at once; admission stays at the watermark
    assert fifo["dos_peak"] > 400.0
    assert adm["dos_peak"] <= 130.0


def test_zero_token_requests_terminate():
    """A zero-length decode request must retire, not spin the loop."""
    cap = int(SPEC_A.total_bytes * 2)
    r = run_schedule([SPEC_A], 3, cap, policy="fifo", seed=0, tokens=0)
    assert r["n_requests"] == 3 and r["total_tokens"] == 0
    assert r["agg_tok_s"] == 0.0 and r["evictions_per_token"] == 0.0
    assert all(row["ttft_s"] == 0.0 for row in r["requests"])


def test_empty_request_list_yields_empty_report():
    sched = PoolScheduler(64 * MB, policy="svm_aware")
    r = sched.run([])
    assert r["n_requests"] == 0 and r["total_tokens"] == 0
    assert r["latency_p99_s"] == 0.0 and r["queue_wait_mean_s"] == 0.0


def test_admission_respects_watermark():
    cap = int(SPEC_A.total_bytes * 2.5)      # two archA fit, three don't
    r = run_schedule([SPEC_A], 6, cap, policy="admission", seed=1,
                     tokens=4, admit_watermark=1.0)
    assert r["dos_peak"] <= 100.0
    assert r["queue_wait_mean_s"] > 0.0      # somebody had to queue


def test_oversized_request_admitted_alone():
    """A request bigger than the watermark can never fit; it must be
    admitted alone rather than deadlocking the queue."""
    cap = int(SPEC_B.total_bytes * 0.7)
    r = run_schedule([SPEC_B], 3, cap, policy="svm_aware", seed=2,
                     tokens=3)
    assert r["n_requests"] == 3 and r["total_tokens"] == 9
    assert r["dos_peak"] == pytest.approx(SPEC_B.total_bytes / cap * 100.0)


# ------------------------------------- shared-segment cache-hit contract

def test_same_arch_requests_replay_shared_segments():
    """Cache-hit contract: the first token of the first request records
    and compiles; every same-arch request's first token is a shared-cache
    relocation; all later tokens are local LRU hits."""
    n_req, tokens = 4, 6
    cap = int(SPEC_A.total_bytes * (n_req + 1))   # everything fits
    r = run_schedule([SPEC_A], n_req, cap, policy="fifo", seed=0,
                     tokens=tokens)
    assert r["segment_misses"] == 1
    assert r["segment_shared_hits"] == n_req - 1
    assert r["segment_local_hits"] == n_req * tokens - n_req
    assert r["shared_cache"]["shared_relocations"] == n_req - 1
    assert r["segment_hit_rate"] == pytest.approx(
        1.0 - 1.0 / (n_req * tokens))
    # and the shared replays did real work: every tenant migrated its own
    # ranges (no cross-tenant aliasing from relocation)
    per_req_migs = [row["migrations"] for row in r["requests"]]
    assert all(mig > 0 for mig in per_req_migs)


def test_heterogeneous_archs_do_not_share_segments():
    cap = int((SPEC_A.total_bytes + SPEC_B.total_bytes) * 2)
    r = run_schedule([SPEC_A, SPEC_B], 2, cap, policy="fifo", seed=0,
                     tokens=3, spec_choice="roundrobin")
    assert r["segment_misses"] == 2           # one compile per arch
    assert r["segment_shared_hits"] == 0


# ------------------------------------------------- executor shared pool

def test_streaming_executors_share_one_pool_and_segments():
    """Two same-shape executors co-tenant one space/manager/segment
    cache: the second replays the first's compiled decode segment
    (relocated), and both drive the same wall clock."""
    rng = np.random.default_rng(0)
    params = {f"l{i}": rng.standard_normal((64, 64), dtype=np.float32)
              for i in range(6)}
    total = 6 * 64 * 64 * 4
    cap = total * 3
    space = AddressSpace(cap, base=DEFAULT_BASE)
    mgr = SVMManager(space, profile=False)
    cache = SegmentCache()
    from repro.svm import plan_param_ranges
    exes = []
    for _ in range(2):
        plan = plan_param_ranges(params, cap, space=space,
                                 align_start=True)
        exes.append(StreamingExecutor(
            params, cap, plan=plan, manager=mgr, shared_cache=cache,
            profile=False))
    layer_paths = [[f"l{i}"] for i in range(6)]
    flops = [1e6] * 6
    exes[0].decode_step(layer_paths, flops, materialize=False)
    exes[1].decode_step(layer_paths, flops, materialize=False)
    assert exes[0].session.cache_misses == 1
    assert exes[1].session.cache_misses == 0
    assert exes[1].session.shared_hits == 1
    assert cache.relocations == 1
    assert mgr.n_migrations == 12             # both tenants' leaves

    # a DIFFERENT model with identical leaf path names must not alias
    # the cached segments (keys are namespaced by plan geometry)
    other = {f"l{i}": rng.standard_normal((32, 32), dtype=np.float32)
             for i in range(6)}
    plan3 = plan_param_ranges(other, cap, space=space, align_start=True)
    ex3 = StreamingExecutor(other, cap, plan=plan3, manager=mgr,
                            shared_cache=cache, profile=False)
    ex3.decode_step(layer_paths, flops, materialize=False)
    assert ex3.session.shared_hits == 0
    assert ex3.session.cache_misses == 1
