"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests and benches see the single real CPU device.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    # older jax: no AxisType enum / axis_types kwarg
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples (same axis names)."""
    return _make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod composes with data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
