"""STREAM triad Pallas kernel: a = b + alpha * c.

The paper's Category-I reference workload. Bandwidth-bound: each grid step
streams one (BLOCK_R, BLOCK_C) tile HBM→VMEM, does one FMA per element on
the VPU, and streams the result back. Tiles are (8,128)-aligned for the
v5e vector unit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 512


def _triad_kernel(alpha_ref, b_ref, c_ref, a_ref):
    alpha = alpha_ref[0].astype(b_ref.dtype)
    a_ref[...] = b_ref[...] + alpha * c_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def triad_pallas(b: jax.Array, c: jax.Array, alpha,
                 interpret: bool = False) -> jax.Array:
    """b, c: (R, C); best with R % 8 == 0 and C % 128 == 0."""
    R, C = b.shape
    br, bc = min(BLOCK_R, R), min(BLOCK_C, C)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    alpha_arr = jnp.asarray([alpha], dtype=jnp.float32)
    return pl.pallas_call(
        _triad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), b.dtype),
        interpret=interpret,
    )(alpha_arr, b, c)
