"""SVM manager state machine: migration, eviction, policies, cost model."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    GB,
    MB,
    AddressSpace,
    MI250X,
    SVMManager,
    eviction_cost,
    migration_cost,
)
from repro.core.costmodel import TERMS


def _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB):
    s = AddressSpace(cap, base=175 * MB)
    for i in range(nallocs):
        s.alloc(alloc_bytes, f"m{i}")
    return s


# --------------------------------------------------------------- cost model

def test_cost_term_ordering_matches_paper():
    """§2.4: cpu_update largest; cpu_update+SDMA_setup+alloc ≈ 76 %;
    data movement < 50 % of total (large range, no eviction)."""
    mc = migration_cost(1 * GB, MI250X)
    d = mc.as_dict()
    assert d["cpu_update"] == max(d.values())
    top3 = d["cpu_update"] + d["sdma_setup"] + d["alloc"]
    assert 0.70 <= top3 / mc.total() <= 0.82
    copy = MI250X.copy_time(1 * GB)
    assert copy / mc.total() < 0.5


def test_small_ranges_latency_bound():
    small = migration_cost(2 * MB, MI250X)
    big = migration_cost(1 * GB, MI250X)
    # per-byte cost strictly worse for tiny ranges (fixed latencies) and the
    # copy share of total cost smaller (management-dominated)
    assert small.total() / (2 * MB) > big.total() / (1 * GB)
    assert (MI250X.copy_time(2 * MB) / small.total()
            < MI250X.copy_time(1 * GB) / big.total())


def test_eviction_cost_is_migration_shaped():
    assert eviction_cost(1 * GB, MI250X) == pytest.approx(
        migration_cost(1 * GB, MI250X).total())


# ---------------------------------------------------------------- migration

def test_touch_migrates_then_hits():
    space = _space()
    m = SVMManager(space)
    rid = space.ranges[0].rid
    assert m.touch(rid) is False         # first touch faults + migrates
    assert m.touch(rid) is True          # now resident
    assert m.n_migrations == 1
    assert m.bytes_migrated == space.ranges[0].size
    assert m.free == space.capacity - space.ranges[0].size


def test_no_eviction_below_capacity():
    space = _space(cap=16 * GB, nallocs=3, alloc_bytes=3 * GB)  # DOS 56
    m = SVMManager(space)
    for r in space.ranges:
        m.touch(r.rid)
    assert m.n_evictions == 0
    assert m.evict_to_mig_ratio == 0.0


def test_eviction_under_oversubscription_lrf_is_fifo():
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)  # DOS 112
    m = SVMManager(space)
    order = [r.rid for r in space.ranges]
    for rid in order:
        m.touch(rid)
    assert m.n_evictions > 0
    # LRF == FIFO in migration order: the first-migrated ranges got evicted
    evicted = [e.rid for e in m.events if e.kind == "evt"]
    assert evicted == order[: len(evicted)]


def test_lrf_ignores_touches_lru_respects_them():
    """The paper's central pathology: LRF evicts hot (reused) data."""
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    # LRF: re-touching range 0 does NOT save it
    m = SVMManager(space, policy="lrf")
    m.touch(0)
    for rid in range(1, len(space.ranges)):
        m.touch(0)                        # keep "using" range 0
        m.touch(rid)
    assert 0 in [e.rid for e in m.events if e.kind == "evt"]

    # LRU: re-touching range 0 DOES save it
    m2 = SVMManager(space, policy="lru")
    m2.touch(0)
    for rid in range(1, len(space.ranges)):
        m2.touch(0)
        m2.touch(rid)
    assert 0 not in [e.rid for e in m2.events if e.kind == "evt"]


def test_clock_gives_second_chance():
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    m = SVMManager(space, policy="clock")
    m.touch(0)
    for rid in range(1, len(space.ranges)):
        m.touch(0)
        m.touch(rid)
    evicted = [e.rid for e in m.events if e.kind == "evt"]
    # range 0 is hot (ref bit set every round) — survives at least the
    # first eviction wave
    assert evicted and evicted[0] != 0


def test_eviction_charged_to_alloc_term():
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    m = SVMManager(space)
    for r in space.ranges:
        m.touch(r.rid)
    d = m.cost.as_dict()
    assert d["alloc"] == max(d.values())   # §2.4: alloc dominates under OS
    assert m.evict_cost_total > 0
    assert d["alloc"] > m.evict_cost_total  # alloc = own cost + evictions


def test_pinned_ranges_never_evicted():
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    m = SVMManager(space)
    m.pin(0)
    for r in space.ranges[1:]:
        m.touch(r.rid)
    assert 0 not in [e.rid for e in m.events if e.kind == "evt"]
    assert 0 in m.resident


def test_all_pinned_raises():
    space = AddressSpace(2 * GB, base=0)
    space.alloc(3 * GB)
    m = SVMManager(space)
    # pin ranges until capacity exhausted -> next migration must fail
    m.pin(0)   # 1 GB... capacity 2 GB, alignment for 2GB cap = 64MB
    with pytest.raises(RuntimeError):
        for r in space.ranges[1:]:
            m.pin(r.rid)


def test_zero_copy_never_migrates():
    space = _space()
    m = SVMManager(space)
    m.set_zero_copy(space.allocations[0].alloc_id)
    rid = space.ranges_of(space.allocations[0])[0].rid
    m.touch(rid)
    m.touch(rid)
    assert m.n_migrations == 0
    assert m.n_zerocopy == 2
    assert m.wall > 0


def test_parallel_evict_reduces_wall_not_work():
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    serial = SVMManager(space, parallel_evict=False)
    for r in space.ranges:
        serial.touch(r.rid)

    space2 = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    par = SVMManager(space2, parallel_evict=True)
    for r in space2.ranges:
        par.touch(r.rid)

    assert par.n_migrations == serial.n_migrations
    assert par.n_evictions == serial.n_evictions
    assert par.wall < serial.wall                    # overlap helps
    assert par.cost.total() == pytest.approx(serial.cost.total())


def test_writeback_counts_as_eviction():
    space = _space()
    m = SVMManager(space)
    m.touch(0)
    m.writeback(0)
    assert m.n_evictions == 1
    assert 0 not in m.resident
    assert m.free == space.capacity


def test_adaptive_granularity_defers_range_migration():
    """§4.2 'Granularity': the first k-1 serviceable faults migrate only a
    2 MB granule; the range becomes resident on the k-th."""
    space = _space(cap=16 * GB, nallocs=1, alloc_bytes=3 * GB)
    m = SVMManager(space, defer_granule=2 * MB, defer_k=3)
    rid = space.ranges[0].rid
    assert m.touch(rid) is False
    assert rid not in m.resident          # granule only
    assert m.bytes_migrated == 2 * MB
    assert m.touch(rid) is False
    assert rid not in m.resident
    assert m.touch(rid) is False          # k-th fault: full migration
    assert rid in m.resident
    assert m.bytes_migrated == 2 * (2 * MB) + space.ranges[0].size
    assert m.touch(rid) is True           # now hits


def test_defer_reduces_wasted_bytes_for_sparse_access():
    """Sparse single-touch access over many ranges wastes whole-range
    migrations under the default; deferral migrates granules only."""
    space = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    eager = SVMManager(space)
    for r in space.ranges:
        eager.touch(r.rid)
    space2 = _space(cap=8 * GB, nallocs=3, alloc_bytes=3 * GB)
    defer = SVMManager(space2, defer_granule=2 * MB, defer_k=4)
    for r in space2.ranges:
        defer.touch(r.rid)
    assert defer.bytes_migrated < 0.05 * eager.bytes_migrated
    assert defer.n_evictions == 0         # never fills the device


# ------------------------------------------------------- property invariants

@settings(max_examples=40, deadline=None)
@given(
    touches=st.lists(st.integers(min_value=0, max_value=11),
                     min_size=1, max_size=300),
    policy=st.sampled_from(["lrf", "lru", "clock", "random"]),
)
def test_property_residency_never_exceeds_capacity(touches, policy):
    space = AddressSpace(4 * GB, base=175 * MB)
    for _ in range(4):
        space.alloc(int(1.5 * GB))    # 12 ranges, DOS 150
    m = SVMManager(space, policy=policy, profile=False)
    for t in touches:
        m.touch(t)
        resident_bytes = sum(space.ranges[r].size for r in m.resident)
        assert resident_bytes <= space.capacity
        assert m.free == space.capacity - resident_bytes
        assert len(m.policy) == len(m.resident - m.pinned)
    # conservation: every range is either resident or not, evictions consistent
    assert m.n_evictions <= m.n_migrations
    assert m.bytes_migrated >= m.bytes_evicted


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=999))
def test_property_deterministic(seed):
    """Same trace + same seed => identical metrics (required for CI)."""
    def run():
        space = AddressSpace(4 * GB, base=175 * MB)
        for _ in range(3):
            space.alloc(2 * GB)
        m = SVMManager(space, seed=seed)
        for r in space.ranges:
            m.touch(r.rid, concurrency=100)
        return (m.wall, m.n_migrations, m.n_evictions, m.faults_duplicate)

    assert run() == run()
