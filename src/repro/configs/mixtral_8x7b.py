"""mixtral-8x7b: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window 4096 attention [arXiv:2401.04088; hf]."""

import dataclasses

from repro.models.config import ATTN_LOCAL, MOE, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    vocab=32000,
    d_model=4096,
    n_layers=32,
    d_ff=14336,
    n_heads=32,
    n_kv_heads=8,
    layer_pattern=(ATTN_LOCAL,),
    ffn_pattern=(MOE,),
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=128,
        n_heads=4, n_kv_heads=2, sliding_window=8, n_experts=4, top_k=2)
