"""Composable model definitions: unified config + functional layer library."""

from repro.models.config import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    MAMBA,
    MLP,
    MOE,
    NONE,
    ModelConfig,
)
from repro.models.transformer import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    prefill,
)

__all__ = [
    "ModelConfig", "ATTN", "ATTN_LOCAL", "CROSS", "MAMBA", "MLP", "MOE",
    "NONE", "init_params", "forward", "prefill", "decode_step", "init_cache",
    "encode",
]
