"""Architecture registry: exact assigned configs + reduced smoke variants."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "gemma3-1b": "repro.configs.gemma3_1b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "granite-20b": "repro.configs.granite_20b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).reduced()
