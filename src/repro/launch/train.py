"""Training launcher.

On a real TPU pod this runs under the production mesh with the per-arch
sharding rules (same code path the dry-run compiles); on CPU it runs the
reduced config end-to-end. Fault tolerance (checkpoint/restart + straggler
monitoring) is always on via the supervisor.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import SyntheticLM, modality_stub
from repro.ft import TrainSupervisor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.settings import settings_for
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, make_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    st = settings_for(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"microbatches={st.microbatches if not args.reduced else 1}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(kind=st.optimizer, lr=args.lr,
                        warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    opt_init, _ = make_optimizer(opt_cfg)
    state = {"params": params, "opt": opt_init(params)}
    mb = 1 if args.reduced else st.microbatches
    step_jit = jax.jit(make_train_step(cfg, opt_cfg, microbatches=mb))

    data = SyntheticLM(vocab=cfg.vocab, seed=0)
    host = jax.process_index()
    ctx = None
    if cfg.is_vlm:
        ctx = jnp.asarray(modality_stub("image", args.batch,
                                        cfg.image_tokens, cfg.d_model),
                          jnp.bfloat16)
    elif cfg.is_encdec:
        ctx = jnp.asarray(modality_stub("frames", args.batch,
                                        cfg.encoder_frames, cfg.d_model),
                          jnp.bfloat16)

    def step_fn(step, st_):
        b = data.batch(step, host, args.batch, args.seq)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if ctx is not None:
            batch["ctx"] = ctx
        with mesh:
            p, o, m = step_jit(st_["params"], st_["opt"], batch)
        if step % 10 == 0:
            print(f"  step {step:4d} loss={float(m['loss']):.4f}")
        return {"params": p, "opt": o}

    sup = TrainSupervisor(CheckpointManager(args.ckpt, keep=2,
                                            every=max(args.steps // 4, 1)))
    t0 = time.time()
    final, state = sup.run(state, step_fn, steps=args.steps)
    dt = time.time() - t0
    print(f"done: {final} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
