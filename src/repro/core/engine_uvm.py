"""Batched interpreter for `UVMManager` — the UVM side of the fast tier.

The scalar `UVMManager.touch` walks every VABlock of the touched range
through an OrderedDict LRU (a `move_to_end` + store per resident block,
hundreds of blocks per large range), which dominates UVM sweep wall time.
This interpreter keeps the block state in flat NumPy arrays instead:

  * residency / pinned / dirty / pending as boolean bitmaps over the
    block universe, so a touch is a handful of fancy-indexed vector ops
    regardless of range size;
  * LRU recency as a monotonically increasing per-block sequence number
    (one per scalar `move_to_end`); the victim is the min-seq resident
    block (a masked argmin, or one argpartition for an eviction storm),
    which is exactly the OrderedDict's front-of-queue order;
  * fault-batch servicing (sort, coalesce, evict, migrate) mirrors the
    scalar float/accounting operations **in the same order**, so every
    wall/cost accumulator is bit-for-bit identical.

Mid-touch batch flushes (MAX_BATCH or capacity pressure) are honoured by
splitting the block vector at the first fault that trips a threshold and
re-classifying the remainder against the post-service residency, exactly
as the scalar per-block loop would.

On completion the manager's OrderedDict/set state is reconstructed from
the arrays (ordering by sequence number restores the exact LRU order), so
`summary()`, counters, residency, the pending fault buffer, and profile
events all match `apply_trace` byte for byte.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.core.uvm import (
    BATCH_FIXED_S,
    MAX_BATCH,
    PER_FAULT_S,
    UVMManager,
    VABLOCK,
)
from repro.core.svm import Event

from repro.core.engine import (   # noqa: E402  (engine imports us lazily)
    OP_COMPUTE,
    OP_PIN,
    OP_TOUCH,
    OP_UNPIN,
    OP_WRITEBACK,
)

_NO_SEQ = np.iinfo(np.int64).max


class _UVMState:
    """Array mirror of a UVMManager's block state, plus local scalars for
    the hot accumulators (written back to the manager once at the end)."""

    def __init__(self, mgr: UVMManager):
        space = mgr.space
        self.mgr = mgr
        self.nblocks = -(-space.ranges[-1].end // VABLOCK)
        nb = self.nblocks
        self.res = np.zeros(nb, dtype=bool)
        self.seq = np.zeros(nb, dtype=np.int64)
        self.time = np.zeros(nb)
        self.pinned = np.zeros(nb, dtype=bool)
        self.dirty = np.zeros(nb, dtype=bool)
        self.pending_arr = np.zeros(nb, dtype=bool)
        self.counter = 0
        for b, t in mgr.resident.items():
            self.res[b] = True
            self.seq[b] = self.counter
            self.time[b] = t
            self.counter += 1
        for b in mgr.pinned:
            self.pinned[b] = True
        self.n_pinned = len(mgr.pinned)
        for b in mgr.dirty:
            self.dirty[b] = True
        self.n_dirty = len(mgr.dirty)
        self.pending_list: list[int] = list(mgr._pending)
        self.pending_count = len(self.pending_list)
        if self.pending_list:
            self.pending_arr[self.pending_list] = True

        self.blocks = [np.arange(r.start // VABLOCK, -(-r.end // VABLOCK),
                                 dtype=np.int64)
                       for r in space.ranges]
        self.wall = mgr.wall
        self.compute_time = mgr.compute_time
        self.free = mgr.free
        self.n_migrations = mgr.n_migrations
        self.n_evictions = mgr.n_evictions
        self.n_writebacks = mgr.n_writebacks
        self.n_batches = mgr.n_batches
        self.bytes_migrated = mgr.bytes_migrated
        self.bytes_evicted = mgr.bytes_evicted
        self.bytes_writeback = mgr.bytes_writeback
        self.evict_cost_total = mgr.evict_cost_total
        self.writeback_cost_total = mgr.writeback_cost_total
        self.faults_serviceable = mgr.faults_serviceable
        self.faults_duplicate = mgr.faults_duplicate
        self.trigger: set[int] = set()
        self.trig_chunks: list[np.ndarray] = []   # block-id arrays, * pages
        self.mc_cache: dict[int, tuple] = {}   # nbytes -> (CostVector, total)

    def finish(self) -> None:
        mgr = self.mgr
        resb = np.nonzero(self.res)[0]
        order = np.argsort(self.seq[resb])       # seqs are unique
        mgr.resident = OrderedDict(
            zip(resb[order].tolist(), self.time[resb[order]].tolist()))
        mgr.pinned = set(np.nonzero(self.pinned)[0].tolist())
        mgr.dirty = set(np.nonzero(self.dirty)[0].tolist())
        mgr._pending = OrderedDict.fromkeys(self.pending_list)
        mgr.wall = self.wall
        mgr.compute_time = self.compute_time
        mgr.free = self.free
        mgr.n_migrations = self.n_migrations
        mgr.n_evictions = self.n_evictions
        mgr.n_writebacks = self.n_writebacks
        mgr.n_batches = self.n_batches
        mgr.bytes_migrated = self.bytes_migrated
        mgr.bytes_evicted = self.bytes_evicted
        mgr.bytes_writeback = self.bytes_writeback
        mgr.evict_cost_total = self.evict_cost_total
        mgr.writeback_cost_total = self.writeback_cost_total
        mgr.faults_serviceable = self.faults_serviceable
        mgr.faults_duplicate = self.faults_duplicate
        mgr.trigger_pages.update(self.trigger)
        if self.trig_chunks:
            mgr.trigger_pages.update(
                (np.concatenate(self.trig_chunks)
                 * (VABLOCK // 4096)).tolist())
            self.trig_chunks = []


def execute_compiled_uvm(ct, mgr: UVMManager) -> None:
    """Apply a compiled trace to a UVMManager; equivalent to
    `apply_trace` (same flush points: compute ops, writeback, pin,
    MAX_BATCH, capacity pressure — the end-of-trace flush stays the
    caller's job, as with the scalar path)."""
    st = _UVMState(mgr)
    # list mirrors of the op columns, memoised on the (immutable) trace:
    # a cached CompiledTrace re-executed across sweep points — including
    # by the SVM interpreter for other points of the same TraceKey group —
    # converts once, not per execution
    lists = ct.span_cache.get("uvm_lists")
    if lists is None:
        lists = (ct.codes.tolist(), ct.rids.tolist(),
                 ct.concs.tolist(), ct.fargs.tolist())
        ct.span_cache["uvm_lists"] = lists
    codes, rids, concs, fargs = lists
    try:
        for k in range(len(codes)):
            c = codes[k]
            if c == OP_TOUCH:
                _touch(st, rids[k], concs[k])
            elif c == OP_COMPUTE:
                _service(st)
                st.wall += fargs[k]
                st.compute_time += fargs[k]
            elif c == OP_WRITEBACK:
                _writeback(st, rids[k])
            elif c == OP_PIN:
                _touch(st, rids[k], 1)
                _service(st)
                bl = st.blocks[rids[k]]
                st.pinned[bl] = True
                st.res[bl] = False       # memory accounting unchanged
                st.n_pinned = int(st.pinned.sum())
            elif c == OP_UNPIN:
                bl = st.blocks[rids[k]]
                sel = st.pinned[bl]
                if sel.any():
                    ub = bl[sel]
                    st.pinned[ub] = False
                    st.n_pinned = int(st.pinned.sum())
                    # scalar resident[b] = wall: appends NEW keys in block
                    # order but leaves already-resident blocks (faulted
                    # back in while pinned) at their old LRU position
                    newly = ub[~st.res[ub]]
                    st.res[ub] = True
                    st.seq[newly] = np.arange(st.counter,
                                              st.counter + len(newly))
                    st.counter += len(newly)
                    st.time[ub] = st.wall
            else:
                # OP_SPILL (eager pre-eviction) is an SVM policy concept;
                # the UVM baseline has no range-level spill API
                raise ValueError(
                    f"opcode {c} unsupported on the UVM interpreter")
    finally:
        # flush array state back even on a mid-trace device-full error so
        # the manager is left in the same partial state as the scalar path
        st.finish()


def _touch(st: _UVMState, rid: int, conc: int) -> None:
    blocks = st.blocks[rid]
    dup_base = conc // 8 if conc >= 8 else 0
    res = st.res[blocks]
    if res.all():
        # pure-hit fast path: the paper's dominant re-touch case
        st.seq[blocks] = np.arange(st.counter, st.counter + len(blocks))
        st.counter += len(blocks)
        st.time[blocks] = st.wall
        return
    if (st.pending_count == 0 and st.free < VABLOCK and not res.any()
            and not st.pending_arr[blocks].any()):
        # fault storm: every block pends and trips the capacity flush
        # immediately — one single-fault service (evict one, migrate one)
        # per block, fully vectorisable
        _touch_storm(st, blocks, dup_base)
        return
    start = 0
    n = len(blocks)
    while start < n:
        c_star = min(MAX_BATCH, -(-st.free // VABLOCK))
        if c_star - st.pending_count < 16:
            # near a flush threshold (capacity pressure): vector segments
            # would degenerate to per-block slices — mirror the scalar
            # per-block loop directly on the array state instead
            _touch_scalar(st, blocks, start, dup_base)
            return
        bl = blocks[start:] if start else blocks
        res = st.res[bl]
        pend = st.pending_arr[bl]
        new_mask = ~res & ~pend
        new_idx = np.nonzero(new_mask)[0]
        # first new fault that trips a flush: batch full, or the pending
        # blocks no longer fit in free memory (thresholds are constant
        # between services — free only changes inside _service)
        cut = len(bl)
        flush_after = False
        if len(new_idx):
            jstar = c_star - st.pending_count
            if jstar < 1:
                jstar = 1
            if jstar <= len(new_idx):
                cut = int(new_idx[jstar - 1]) + 1
                flush_after = True
        res_s = res[:cut]
        hits = np.nonzero(res_s)[0]
        if len(hits):
            hb = bl[hits]
            st.seq[hb] = np.arange(st.counter, st.counter + len(hb))
            st.counter += len(hb)
            st.time[hb] = st.wall
        st.faults_duplicate += int((~res_s & pend[:cut]).sum())
        newb = bl[:cut][new_mask[:cut]]
        if len(newb):
            st.pending_arr[newb] = True
            st.pending_list.extend(newb.tolist())
            st.pending_count += len(newb)
            st.faults_serviceable += len(newb)
            st.trig_chunks.append(newb)
            st.faults_duplicate += dup_base * len(newb)
        if flush_after:
            _service(st)
        start += cut


def _touch_scalar(st: _UVMState, blocks: np.ndarray, start: int,
                  dup_base: int) -> None:
    """Per-block mirror of the scalar touch loop, used when every few
    faults trip a flush (capacity pressure) and vector segments would
    shrink to single blocks."""
    res = st.res
    pend = st.pending_arr
    seq = st.seq
    time = st.time
    trig_scale = VABLOCK // 4096
    for b in blocks[start:].tolist():
        if res[b]:
            seq[b] = st.counter
            st.counter += 1
            time[b] = st.wall
        elif pend[b]:
            st.faults_duplicate += 1
        else:
            pend[b] = True
            st.pending_list.append(b)
            st.pending_count += 1
            st.faults_serviceable += 1
            st.trigger.add(b * trig_scale)
            st.faults_duplicate += dup_base
            if (st.pending_count >= MAX_BATCH
                    or st.pending_count * VABLOCK >= st.free):
                _service(st)


def _touch_storm(st: _UVMState, blocks: np.ndarray, dup_base: int) -> None:
    """Vectorised single-fault-service storm: with ``free < VABLOCK`` and
    an empty buffer, each non-resident block pends, immediately trips the
    capacity flush, evicts exactly one LRU victim, and migrates one block
    — so the whole touch is a fixed wall/cost pattern per block, folded
    with one ``cumsum`` (bit-identical to the scalar `+=` chain)."""
    n = len(blocks)
    # victim stream: the n resident blocks with the smallest seqs, in seq
    # order — exactly n successive LRU pops (the n new blocks get higher
    # seqs than every existing resident block, so they are never victims
    # within this touch), selected with one argpartition.
    cand = np.nonzero(_evictable(st))[0]
    if len(cand) < n:
        # fewer pre-existing residents than faults: the scalar loop would
        # start evicting this touch's own earlier blocks (or raise on a
        # truly empty pool) — mirror it block by block instead
        _touch_scalar(st, blocks, 0, dup_base)
        return
    sq = st.seq[cand]
    if len(cand) > n:
        part = np.argpartition(sq, n - 1)[:n]
        victims = cand[part[np.argsort(sq[part])]]
    else:
        victims = cand[np.argsort(sq)]
    st.res[victims] = False
    _storm_apply(st, blocks, victims, dup_base)


def _storm_apply(st: _UVMState, blocks: np.ndarray, victims: np.ndarray,
                 dup_base: int) -> None:
    if not len(blocks):
        return
    mgr = st.mgr
    n = len(blocks)
    mc, mc_total = _mc_for(st, VABLOCK)
    all_clean = st.n_dirty == 0          # the trace case: touches never write
    if all_clean:
        ev_w = mgr._mc_block.cpu_unmap
        nd = 0
    else:
        dirty_v = st.dirty[victims]
        ev_w = np.where(dirty_v, mgr._mc_block_total,
                        mgr._mc_block.cpu_unmap)
        nd = int(dirty_v.sum())
    # wall: per fault [batch fixed+decode, evict, migrate] — exact fold
    deltas = np.empty(3 * n)
    deltas[0::3] = BATCH_FIXED_S + PER_FAULT_S
    deltas[1::3] = ev_w
    deltas[2::3] = mc_total
    traj = np.cumsum(np.concatenate(([st.wall], deltas)))
    st.wall = float(traj[-1])
    ev_wall = traj[2::3]       # wall after each eviction
    mig_wall = traj[3::3]      # wall after each migration
    cost = mgr.cost
    # cost folds, scalar order per fault: the eviction charge (alloc if
    # dirty, cpu_unmap if clean) then the migration's five terms.  Terms
    # with no eviction contribution skip the zero interleave (+0.0 is
    # add-identity for the non-negative accumulators)
    ledger2 = np.empty((2 * n + 1, 2))
    ledger2[0] = (cost.cpu_unmap, cost.alloc)
    if all_clean:
        ledger2[1::2, 0] = mgr._mc_block.cpu_unmap
        ledger2[1::2, 1] = 0.0
    else:
        ledger2[1::2, 0] = np.where(dirty_v, 0.0, mgr._mc_block.cpu_unmap)
        ledger2[1::2, 1] = np.where(dirty_v, mgr._mc_block_total, 0.0)
    ledger2[2::2, 0] = mc.cpu_unmap
    ledger2[2::2, 1] = mc.alloc
    cost.cpu_unmap, cost.alloc = np.cumsum(ledger2, axis=0)[-1].tolist()
    ledger3 = np.empty((n + 1, 3))
    ledger3[0] = (cost.sdma_setup, cost.cpu_update, cost.misc)
    ledger3[1:] = (mc.sdma_setup, mc.cpu_update, mc.misc)
    (cost.sdma_setup, cost.cpu_update,
     cost.misc) = np.cumsum(ledger3, axis=0)[-1].tolist()
    if nd:
        dirty_ws = np.full(nd, mgr._mc_block_total)
        st.evict_cost_total = float(np.cumsum(
            np.concatenate(([st.evict_cost_total], dirty_ws)))[-1])
        st.bytes_evicted += nd * VABLOCK
        st.dirty[victims] = False
        st.n_dirty -= nd
    st.res[victims] = False
    st.res[blocks] = True
    seqs = np.arange(st.counter, st.counter + n)
    st.counter += n
    st.seq[blocks] = seqs
    st.time[blocks] = mig_wall
    st.n_batches += n
    st.n_evictions += n
    st.n_migrations += n
    st.bytes_migrated += n * VABLOCK
    st.faults_serviceable += n
    st.faults_duplicate += dup_base * n
    st.trig_chunks.append(blocks)
    if mgr.profile:
        events = mgr.events
        ranges = mgr.space.ranges
        ew = ev_wall.tolist()
        mw = mig_wall.tolist()
        for i, (v, b) in enumerate(zip(victims.tolist(), blocks.tolist())):
            rv = mgr._rid_of_block(v)
            events.append(Event(ew[i], "evt", rv, ranges[rv].alloc_id,
                                VABLOCK))
            rb = mgr._rid_of_block(b)
            events.append(Event(mw[i], "mig", rb, ranges[rb].alloc_id,
                                VABLOCK))


def _mc_for(st: _UVMState, nbytes: int):
    cached = st.mc_cache.get(nbytes)
    if cached is None:
        from repro.core.costmodel import migration_cost
        mc = migration_cost(nbytes, st.mgr.params)
        st.mc_cache[nbytes] = cached = (mc, mc.total())
    return cached


def _service(st: _UVMState) -> None:
    if not st.pending_count:
        return
    mgr = st.mgr
    if st.pending_count == 1:
        _service_one(st)
        return
    barr = np.sort(np.asarray(st.pending_list, dtype=np.int64))
    st.pending_arr[barr] = False
    st.pending_list = []
    st.pending_count = 0
    st.n_batches += 1
    st.wall += BATCH_FIXED_S + PER_FAULT_S * len(barr)
    # tree/density prefetcher: coalesce contiguous faulting blocks
    if mgr.prefetch:
        splits = np.nonzero(np.diff(barr) != 1)[0] + 1
        gstarts = np.concatenate(([0], splits))
        gends = np.concatenate((splits, [len(barr)]))
    else:
        gstarts = np.arange(len(barr))
        gends = gstarts + 1
    gsizes = gends - gstarts
    total_bytes = len(barr) * VABLOCK
    if st.free >= total_bytes:
        # no group can evict (free only shrinks across groups): fold the
        # whole batch's migrations vectorised
        _service_noevict(st, barr, gsizes)
        return
    for gs, ge in zip(gstarts.tolist(), gends.tolist()):
        g = barr[gs:ge]
        nbytes = (ge - gs) * VABLOCK
        while st.free < nbytes:
            _evict(st, _pop_victim(st))
        mc, mc_total = _mc_for(st, nbytes)
        mgr.cost.add(mc)
        st.wall += mc_total
        st.n_migrations += 1
        st.bytes_migrated += nbytes
        newly = g[~st.res[g]]
        st.res[g] = True
        st.seq[newly] = np.arange(st.counter, st.counter + len(newly))
        st.counter += len(newly)
        st.time[g] = st.wall
        st.free -= nbytes
        if mgr.profile:
            rid = mgr._rid_of_block(int(g[0]))
            mgr.events.append(Event(st.wall, "mig", rid,
                                    mgr.space.ranges[rid].alloc_id, nbytes))


def _service_noevict(st: _UVMState, barr: np.ndarray,
                     gsizes: np.ndarray) -> None:
    mgr = st.mgr
    k = len(gsizes)
    nbytes_g = gsizes * VABLOCK
    usz = np.unique(nbytes_g)
    terms = np.empty((len(usz), 5))
    totals = np.empty(len(usz))
    for j, sz in enumerate(usz.tolist()):
        mc, tot = _mc_for(st, sz)
        terms[j] = (mc.cpu_unmap, mc.sdma_setup, mc.alloc,
                    mc.cpu_update, mc.misc)
        totals[j] = tot
    idx = np.searchsorted(usz, nbytes_g)
    cost = mgr.cost
    ledger = np.empty((k + 1, 5))
    ledger[0] = (cost.cpu_unmap, cost.sdma_setup, cost.alloc,
                 cost.cpu_update, cost.misc)
    ledger[1:] = terms[idx]
    (cost.cpu_unmap, cost.sdma_setup, cost.alloc, cost.cpu_update,
     cost.misc) = np.cumsum(ledger, axis=0)[-1].tolist()
    traj = np.cumsum(np.concatenate(([st.wall], totals[idx])))
    st.wall = float(traj[-1])
    gwall = traj[1:]
    st.n_migrations += k
    st.bytes_migrated += len(barr) * VABLOCK
    newly = barr[~st.res[barr]]
    st.res[barr] = True
    st.seq[newly] = np.arange(st.counter, st.counter + len(newly))
    st.counter += len(newly)
    st.time[barr] = np.repeat(gwall, gsizes)
    st.free -= len(barr) * VABLOCK
    if mgr.profile:
        gw = gwall.tolist()
        gstart_blocks = barr[np.cumsum(gsizes) - gsizes].tolist()
        for j in range(k):
            rid = mgr._rid_of_block(gstart_blocks[j])
            mgr.events.append(Event(gw[j], "mig", rid,
                                    mgr.space.ranges[rid].alloc_id,
                                    int(nbytes_g[j])))


def _service_one(st: _UVMState) -> None:
    """Single-fault batch: the common shape under capacity pressure (every
    pend trips the capacity flush).  Same operations as the general path,
    without the sort/group/array scaffolding."""
    mgr = st.mgr
    b = st.pending_list[0]
    st.pending_arr[b] = False
    st.pending_list = []
    st.pending_count = 0
    st.n_batches += 1
    st.wall += BATCH_FIXED_S + PER_FAULT_S
    while st.free < VABLOCK:
        _evict(st, _pop_victim(st))
    mc, mc_total = _mc_for(st, VABLOCK)
    mgr.cost.add(mc)
    st.wall += mc_total
    st.n_migrations += 1
    st.bytes_migrated += VABLOCK
    if not st.res[b]:
        st.res[b] = True
        st.seq[b] = st.counter
        st.counter += 1
    st.time[b] = st.wall
    st.free -= VABLOCK
    if mgr.profile:
        rid = mgr._rid_of_block(b)
        mgr.events.append(Event(st.wall, "mig", rid,
                                mgr.space.ranges[rid].alloc_id, VABLOCK))


def _evictable(st: _UVMState) -> np.ndarray:
    """Residency mask minus pinned blocks: a block shared with a pinned
    range can fault back into residency while still pinned, and the
    scalar `_lru_victim` skips exactly those."""
    return st.res & ~st.pinned if st.n_pinned else st.res


def _pop_victim(st: _UVMState) -> int:
    """Oldest (min-seq) evictable block — the OrderedDict front in scalar
    terms.  One O(nblocks) masked argmin; evictions are far rarer than
    touches, and this has no per-touch bookkeeping to keep fresh."""
    ev = _evictable(st)
    masked = np.where(ev, st.seq, _NO_SEQ)
    v = int(masked.argmin())
    if not ev[v]:
        raise RuntimeError("UVM: all resident blocks pinned")
    return v


def _evict(st: _UVMState, b: int) -> None:
    mgr = st.mgr
    if st.n_dirty and st.dirty[b]:
        w = mgr._mc_block_total
        mgr.cost.alloc += w
        st.evict_cost_total += w
        st.bytes_evicted += VABLOCK
        st.dirty[b] = False
        st.n_dirty -= 1
    else:
        w = mgr._mc_block.cpu_unmap
        mgr.cost.cpu_unmap += w
    st.wall += w
    st.res[b] = False
    st.free += VABLOCK
    st.n_evictions += 1
    if mgr.profile:
        rid = mgr._rid_of_block(b)
        mgr.events.append(Event(st.wall, "evt", rid,
                                mgr.space.ranges[rid].alloc_id, VABLOCK))


def _writeback(st: _UVMState, rid: int) -> None:
    mgr = st.mgr
    _service(st)
    for b in st.blocks[rid].tolist():
        if st.res[b]:
            w = mgr._mc_block_total
            mgr.cost.add(mgr._mc_block)
            st.writeback_cost_total += w
            st.wall += w
            st.res[b] = False
            if st.n_dirty and st.dirty[b]:
                st.dirty[b] = False
                st.n_dirty -= 1
            st.free += VABLOCK
            st.n_writebacks += 1
            st.bytes_writeback += VABLOCK
            if mgr.profile:
                r = mgr._rid_of_block(b)
                mgr.events.append(Event(st.wall, "wb", r,
                                        mgr.space.ranges[r].alloc_id,
                                        VABLOCK))
