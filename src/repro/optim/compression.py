"""Gradient compression for cross-pod data parallelism.

At 1000+ node scale the gradient all-reduce over the `pod` axis crosses
DCN/optical links an order of magnitude slower than ICI; int8 block-quantised
gradients with error feedback cut that traffic 4x (vs fp32 accumulations)
while keeping convergence (the feedback buffer re-injects quantisation
residuals next step, bounding bias — Seide et al. / Karimireddy et al.).

Two entry points:
  * `compress`/`decompress` + `ef_update` — numerics used inside the train
    step (works under jit/GSPMD; the wire saving needs manual collectives);
  * `compressed_psum` — a shard_map-compatible all-reduce that actually
    moves int8 over the mesh axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-256-block symmetric int8 quantisation. Returns (q, scales)."""
    flat, _ = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress(q: jax.Array, scale: jax.Array, shape: tuple[int, ...],
               dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_with_error_feedback(grads: PyTree, err: PyTree
                                 ) -> tuple[PyTree, PyTree]:
    """g' = Q(g + err);  err' = (g + err) - g'. Applied leaf-wise."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, err)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce with int8 payload (inside shard_map): each participant
    quantises its contribution; the sum runs in int32; one shared scale per
    block is taken as the max over participants."""
    q, scale = compress(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantise against the shared scale so the integer sum is coherent
    requant = jnp.clip(
        jnp.round(q.astype(jnp.float32) * (scale / scale_max)[:, None]),
        -127, 127).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    flat = (total.astype(jnp.float32) * scale_max[:, None]).reshape(-1)
    return flat[: x.size].reshape(x.shape).astype(x.dtype)
