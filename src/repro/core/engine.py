"""Compiled-trace engine — the fast execution tier for the SVM simulator.

`apply_trace` walks a workload trace one op at a time through
`SVMManager.touch`, paying full Python dispatch (dataclass construction,
method calls, attribute chasing) on every op.  Reproducing one paper figure
sweeps the Table-2 suite across DOS points × policies × §4.2 variants, so
that per-op loop dominates `benchmarks/run.py` wall time.

This module lowers a trace **once** into flat NumPy op arrays
(opcode / rid / concurrency / page-hint / float-arg columns) and executes
them with a batched interpreter:

  * **Phase A** (structure): a lean, integer-only loop over the touch ops
    of a span determines hits, misses, and the exact victim sequence,
    mutating the live policy/residency state.  Resident hits — the paper's
    97–99 % duplicate/hit common case — cost one set lookup.
  * **Phase B** (accounting): all per-migration float work (five-term cost
    model, wall trajectory, duplicate-fault synthesis, trigger pages,
    profile events) is done vectorised with NumPy.  Sequential float
    accumulation order is preserved bit-for-bit via ``np.cumsum`` (an exact
    left-to-right fold) seeded with the manager's current accumulator
    values, so `summary()` is **byte-identical** to the scalar path.
  * Boundary ops (writeback / pin / unpin / zero-copy touches) and
    unsupported driver variants (deferred granularity, pre-eviction
    watermark, non-SVM managers) drop to the scalar `SVMManager` path,
    op for op.

Equivalence guarantee: for any trace and any manager configuration,
executing the compiled trace leaves the manager with the same `summary()`,
counters, residency set, free bytes, eviction order, and (under `profile`)
the same `events`/`density` lists as `apply_trace`.  Two tolerated
deviations: (1) the *stored* (never read) float timestamps inside LRF/LRU
policy queues are patched to the correct wall values at span flush for all
surviving entries; (2) eviction listeners / `eviction_epoch` fire at span
flush rather than at each eviction's wall time — end-of-run totals are
identical, but a listener sampling `mgr.wall` mid-run sees the span-end
clock (drive the manager via `touch()` for per-eviction timing, as the
streaming executor does).
"""

from __future__ import annotations

import dataclasses
import itertools
import weakref
from typing import Iterable

import numpy as np

from repro.core.costmodel import CostParams, eviction_cost, migration_cost
from repro.core.policies import LRF, LRU
from repro.core.ranges import PAGE, AddressSpace
from repro.core.svm import DensitySample, Event, SVMManager

ENGINE_VERSION = "1"

OP_TOUCH = 0
OP_COMPUTE = 1
OP_WRITEBACK = 2
OP_PIN = 3
OP_UNPIN = 4

# spans shorter than this run through the scalar manager path: the NumPy
# batch setup would cost more than it saves
FAST_SPAN_MIN = 48


@dataclasses.dataclass
class CompiledTrace:
    """A workload trace lowered to flat op columns (lowered once, executed
    many times — e.g. across the policies × variants axes of a sweep)."""

    codes: np.ndarray      # int8   — OP_* opcode per op
    rids: np.ndarray       # int64  — range id (-1 where n/a)
    concs: np.ndarray      # int64  — touch concurrency
    hints: np.ndarray      # int64  — touch page hint
    fargs: np.ndarray      # float64 — compute seconds
    boundaries: np.ndarray  # int64 — indices of writeback/pin/unpin ops
    # python-list mirrors of the touch stream (fast to iterate in Phase A)
    touch_pos: list        # op index per touch
    touch_rid: list        # rid per touch
    touch_pos_np: np.ndarray
    touch_rid_np: np.ndarray
    n_ops: int             # source ops consumed (incl. kernel markers)
    # per-span slices + uniqueness flags, memoised across executions
    span_cache: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.codes)

    def span(self, s: int, e: int):
        """Touch-stream slice for ops [s, e): (pos_list, rid_list, pos_np,
        rid_np, rids_unique). Cached — compiled traces are executed many
        times (policy/variant axes of a sweep)."""
        cached = self.span_cache.get((s, e))
        if cached is None:
            lo, hi = np.searchsorted(self.touch_pos_np, (s, e))
            pos_np = self.touch_pos_np[lo:hi]
            rid_np = self.touch_rid_np[lo:hi]
            rid_l = self.touch_rid[lo:hi]
            uniq = len(np.unique(rid_np)) == len(rid_np)
            cached = (self.touch_pos[lo:hi], rid_l, pos_np, rid_np, uniq)
            self.span_cache[(s, e)] = cached
        return cached


def compile_trace(trace: Iterable, max_ops: int | None = None) -> CompiledTrace:
    """Lower a lazy op trace into flat columns.

    Kernel markers are consumed (they count toward ``max_ops``, matching
    `apply_trace`) but not materialised.
    """
    if max_ops is not None:
        trace = itertools.islice(trace, max_ops)
    codes: list[int] = []
    rids: list[int] = []
    concs: list[int] = []
    hints: list[int] = []
    fargs: list[float] = []
    n_src = 0
    for op in trace:
        n_src += 1
        tag = op[0]
        if tag == "touch":
            codes.append(OP_TOUCH)
            rids.append(op[1])
            concs.append(op[2])
            hints.append(op[3] or 0)
            fargs.append(0.0)
        elif tag == "compute":
            codes.append(OP_COMPUTE)
            rids.append(-1)
            concs.append(0)
            hints.append(0)
            fargs.append(op[1])
        elif tag == "kernel":
            continue
        elif tag == "writeback":
            codes.append(OP_WRITEBACK)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        elif tag == "pin":
            codes.append(OP_PIN)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        elif tag == "unpin":
            codes.append(OP_UNPIN)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        else:
            raise ValueError(f"unknown trace op {tag!r}")
    code_arr = np.array(codes, dtype=np.int8)
    rid_arr = np.array(rids, dtype=np.int64)
    touch_mask = code_arr == OP_TOUCH
    touch_pos_np = np.nonzero(touch_mask)[0]
    touch_rid_np = rid_arr[touch_mask]
    return CompiledTrace(
        codes=code_arr,
        rids=rid_arr,
        concs=np.array(concs, dtype=np.int64),
        hints=np.array(hints, dtype=np.int64),
        fargs=np.array(fargs, dtype=np.float64),
        boundaries=np.nonzero(code_arr >= OP_WRITEBACK)[0],
        touch_pos=touch_pos_np.tolist(),
        touch_rid=touch_rid_np.tolist(),
        touch_pos_np=touch_pos_np,
        touch_rid_np=touch_rid_np,
        n_ops=n_src,
    )


def compile_workload(workload, space: AddressSpace,
                     max_ops: int | None = None) -> CompiledTrace:
    return compile_trace(workload.trace(space), max_ops=max_ops)


# --------------------------------------------------------------- cost tables

# per-AddressSpace static tables, shared by every execution over that space
_SPACE_TABLES: "weakref.WeakKeyDictionary[AddressSpace, dict]" = \
    weakref.WeakKeyDictionary()


def _tables(space: AddressSpace, params: CostParams) -> dict:
    tab = _SPACE_TABLES.get(space)
    if tab is None or tab["n_ranges"] != len(space.ranges):
        size_arr = np.array([r.end - r.start for r in space.ranges],
                            dtype=np.int64)
        tab = {
            "n_ranges": len(space.ranges),
            "sizes": size_arr.tolist(),
            "size_arr": size_arr,
            "alloc_ids": [r.alloc_id for r in space.ranges],
            "pages": np.array([r.start // PAGE for r in space.ranges],
                              dtype=np.int64),
            "params": {},
        }
        _SPACE_TABLES[space] = tab
    per_params = tab["params"].get(params)
    if per_params is None:
        usz = np.unique(tab["size_arr"])
        # migration_cost is a pure function of (size, params): memoised
        # values are bit-identical to what the scalar path computes fresh
        mcs = [migration_cost(int(s), params) for s in usz.tolist()]
        per_params = {
            "usz": usz,
            "terms": np.array([[m.cpu_unmap, m.sdma_setup, m.alloc,
                                m.cpu_update, m.misc] for m in mcs]),
            "ecs": np.array([eviction_cost(int(s), params)
                             for s in usz.tolist()]),
            "sizeidx": np.searchsorted(usz, tab["size_arr"]),
        }
        tab["params"][params] = per_params
    return {**tab, **per_params}


# ----------------------------------------------------------------- execution

def _fast_supported(mgr) -> bool:
    if type(mgr) is not SVMManager:
        return False
    if mgr.defer_granule and mgr.defer_k > 0:
        return False
    if mgr.previct_watermark > 0.0:
        return False
    return True


def execute_compiled(ct: CompiledTrace, mgr) -> None:
    """Apply a compiled trace to a manager; equivalent to `apply_trace`."""
    if not _fast_supported(mgr):
        _replay(ct, mgr, 0, len(ct))
        return

    # dynamic boundaries: touches on zero-copy allocations take the scalar
    # path (they charge remote-access cost instead of migrating)
    bounds = ct.boundaries
    if mgr.zero_copy_allocs:
        zc_rids = {r.rid for r in mgr.space.ranges
                   if r.alloc_id in mgr.zero_copy_allocs}
        if zc_rids:
            zc_mask = np.zeros(len(mgr.space.ranges), dtype=bool)
            zc_mask[list(zc_rids)] = True
            touch_zc = (ct.codes == OP_TOUCH) & zc_mask[np.clip(ct.rids, 0,
                                                                None)]
            bounds = np.union1d(bounds, np.nonzero(touch_zc)[0])

    pos = 0
    for b in bounds.tolist():
        _run_span(ct, mgr, pos, b)
        _exec_boundary(ct, mgr, b)
        pos = b + 1
    _run_span(ct, mgr, pos, len(ct))


def _exec_boundary(ct: CompiledTrace, mgr, k: int) -> None:
    code = ct.codes[k]
    rid = int(ct.rids[k])
    if code == OP_TOUCH:          # zero-copy touch
        mgr.touch(rid, concurrency=int(ct.concs[k]),
                  page_hint=int(ct.hints[k]))
    elif code == OP_WRITEBACK:
        mgr.writeback(rid)
    elif code == OP_PIN:
        mgr.pin(rid)
    elif code == OP_UNPIN:
        mgr.unpin(rid)


def _replay(ct: CompiledTrace, mgr, s: int, e: int) -> None:
    """Scalar fallback: dispatch ops one by one through the manager."""
    codes = ct.codes
    rids = ct.rids
    for k in range(s, e):
        code = codes[k]
        if code == OP_TOUCH:
            mgr.touch(int(rids[k]), concurrency=int(ct.concs[k]),
                      page_hint=int(ct.hints[k]))
        elif code == OP_COMPUTE:
            mgr.advance(float(ct.fargs[k]))
        else:
            _exec_boundary(ct, mgr, k)


def _run_span(ct: CompiledTrace, mgr, s: int, e: int) -> None:
    if e <= s:
        return
    if e - s < FAST_SPAN_MIN:
        _replay(ct, mgr, s, e)
        return
    tpos, trid, tpos_np, trid_np, uniq = ct.span(s, e)
    tab = _tables(mgr.space, mgr.params)
    struct = None
    if type(mgr.policy) is LRF and not mgr.pinned and len(trid):
        # vectorised LRF fast paths, gated on a residency bitmap
        mask = np.zeros(tab["n_ranges"], dtype=bool)
        resident = mgr.resident
        if resident:
            mask[np.fromiter(resident, dtype=np.int64,
                             count=len(resident))] = True
        u, first_idx = np.unique(trid_np, return_index=True)
        miss_u = ~mask[u]
        need = int(tab["size_arr"][u[miss_u]].sum())
        if need <= mgr.free:
            # no eviction possible: misses are exactly the first touches
            # of the non-resident ranges, hits are LRF no-ops
            struct = _phase_a_lrf_noevict(
                mgr, tpos_np, trid_np, first_idx[miss_u], need)
        else:
            # eviction-pressure span: solve the FIFO dynamics in closed
            # form under the every-touch-misses hypothesis and validate it
            # vectorised (holds for linear streaming AND full thrash);
            # falls back to the sequential loop on mixed hit/miss spans
            prev = None
            if not uniq:
                prev = ct.span_cache.get(("prev", s, e))
                if prev is None:
                    order = np.argsort(trid_np, kind="stable")
                    srid = trid_np[order]
                    prev = np.full(len(trid_np), -1, dtype=np.int64)
                    same = srid[1:] == srid[:-1]
                    prev[order[1:][same]] = order[:-1][same]
                    ct.span_cache[("prev", s, e)] = prev
            struct = _phase_a_lrf_streaming(mgr, tpos_np, trid, trid_np,
                                            tab, mask, prev)
    if struct is None:
        # the sequential passes mutate live state as they go; snapshot so
        # a mid-span device-full error can be replayed through the scalar
        # path, which raises with fully consistent partial manager state
        snap = _snapshot(mgr)
        try:
            if type(mgr.policy) is LRF:
                struct = _phase_a_lrf(mgr, tpos, trid, tab)
            else:
                struct = _phase_a_generic(mgr, tpos, trid, tab)
        except RuntimeError:
            _restore(mgr, snap)
            _replay(ct, mgr, s, e)    # re-raises at the same op, scalar
            raise                     # unreachable: replay must raise too
    _phase_b(ct, mgr, s, e, tab, *struct)


# ------------------------------------------------------ phase A — structure

def _snapshot(mgr):
    policy = mgr.policy
    q = getattr(policy, "_q", None)
    if q is not None:
        pstate = ("q", list(q.items()))
    elif getattr(policy, "_order", None) is not None:
        pstate = ("order", list(policy._order.items()))
    elif getattr(policy, "_set", None) is not None:
        pstate = ("set", list(policy._set), policy._rng.getstate())
    else:
        import copy
        pstate = ("deep", copy.deepcopy(policy))
    return set(mgr.resident), mgr.free, pstate


def _restore(mgr, snap):
    resident, free, pstate = snap
    mgr.resident.clear()
    mgr.resident.update(resident)
    mgr.free = free
    policy = mgr.policy
    if pstate[0] == "q":
        policy._q.clear()
        policy._q.update(pstate[1])
    elif pstate[0] == "order":
        policy._order.clear()
        policy._order.update(pstate[1])
    elif pstate[0] == "set":
        policy._set.clear()
        policy._set.update((r, None) for r in pstate[1])
        policy._rng.setstate(pstate[2])
    else:
        mgr.policy = pstate[1]


def _phase_a_lrf_noevict(mgr, tpos_np, trid_np, miss_first_idx, need):
    """Vectorised Phase A for LRF spans that cannot evict (the touched
    working set fits in free bytes): misses are the first occurrences of
    non-resident rids, in touch order; every other touch is a hit, which
    LRF ignores by construction."""
    idx = np.sort(miss_first_idx)
    m_rid = trid_np[idx]
    m_pos = tpos_np[idx]
    rid_list = m_rid.tolist()
    mgr.free -= need
    mgr.resident.update(rid_list)
    q = mgr.policy._q
    for rid in rid_list:
        q[rid] = 0.0
    return m_pos, m_rid, np.zeros(len(idx), dtype=np.int64), [], None


def _phase_a_lrf_streaming(mgr, tpos_np, trid, trid_np, tab, mask, prev):
    """Closed-form Phase A for all-miss spans under LRF.

    Hypothesis: every touch in the span is a miss.  LRF then degenerates
    to FIFO, the victim stream is exactly [current queue] + [migrated
    ranges, in touch order], and each migration's eviction count falls out
    of one ``searchsorted`` over the two byte cumsums.  The hypothesis is
    then validated vectorised — every re-touch (``prev``) and every
    initially-resident touch must have been evicted before its hit check —
    covering both linear streaming (Category I) and full cyclic thrash
    (Categories II/III at high DOS).  Returns None (no state mutated) when
    the span actually contains hits or would exhaust evictable ranges.
    """
    q = mgr.policy._q
    sizes_arr = tab["size_arr"]
    n = len(trid_np)
    n_q0 = len(q)
    if n_q0:
        cand = np.concatenate([np.fromiter(q, dtype=np.int64, count=n_q0),
                               trid_np])
    else:
        cand = trid_np
    cv = np.concatenate(([0], np.cumsum(sizes_arr[cand])))
    cs = np.cumsum(sizes_arr[trid_np])
    e_arr = np.searchsorted(cv, cs - mgr.free, side="left")
    if (e_arr > n_q0 + np.arange(n)).any():
        return None        # would need to evict not-yet-migrated ranges
    # eviction frontier *before* each touch's hit check
    e_prev = np.empty(n, dtype=np.int64)
    e_prev[0] = 0
    e_prev[1:] = e_arr[:-1]
    if prev is not None:
        nf = prev >= 0
        if nf.any() and (n_q0 + prev[nf] >= e_prev[nf]).any():
            return None    # a re-touched range would still be resident
    if n_q0:
        r0 = mask[trid_np]
        if prev is not None:
            r0 &= prev < 0
        ks = np.nonzero(r0)[0]
        if len(ks):
            q0pos = {rid: i for i, rid in enumerate(q)}
            for k, e in zip(ks.tolist(), e_prev[ks].tolist()):
                p = q0pos.get(trid[k])
                if p is None or p >= e:
                    return None   # an initially-resident touch would hit

    n_evt = int(e_arr[-1])
    victims = cand[:n_evt].tolist()
    nev = e_arr.copy()
    nev[1:] -= e_arr[:-1]

    # state update: the survivors are exactly cand[n_evt:], in order;
    # surviving pre-existing queue entries keep their timestamps
    mgr.free = int(mgr.free + int(cv[n_evt]) - int(cs[-1]))
    old_items = list(q.items())[n_evt:] if n_evt < n_q0 else []
    q.clear()
    for rid, t in old_items:
        q[rid] = t
    for rid in trid[max(n_evt - n_q0, 0):]:
        q[rid] = 0.0
    resident = mgr.resident
    resident.clear()
    resident.update(q)
    return tpos_np, trid_np, nev, victims, None


def _phase_a_lrf(mgr, tpos, trid, tab):
    """Integer-only hit/miss/victim resolution for the default LRF policy.

    Operates directly on the live policy queue (an OrderedDict whose key
    order IS the FIFO victim order); float timestamps are patched in
    phase B.  A miss rid is never queued (queue ⊆ resident), so insertion
    is a plain assignment.
    """
    q = mgr.policy._q
    popitem = q.popitem
    resident = mgr.resident
    res_add = resident.add
    res_disc = resident.discard
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    mp = miss_pos.append
    ma = miss_rid.append
    na = vends.append
    va = victims.append
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            continue
        nbytes = sizes[rid]
        while free < nbytes:
            if not q:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim, _ = popitem(False)
            res_disc(victim)
            free += sizes[victim]
            va(victim)
            n_victims += 1
        free -= nbytes
        res_add(rid)
        if rid not in pinned:
            q[rid] = 0.0
        mp(tpos[i])
        ma(rid)
        na(n_victims)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return miss_pos, miss_rid, nev, victims, None


def _phase_a_generic(mgr, tpos, trid, tab):
    """Policy-agnostic structure pass: same call sequence as the scalar path
    (victim → remove → insert), so stateful policies (CLOCK second-chance
    sweeps, RANDOM rng draws) stay in lockstep."""
    policy = mgr.policy
    on_touch = policy.on_touch
    track = isinstance(policy, LRU)
    lastpos: dict[int, int] = {}
    resident = mgr.resident
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            on_touch(rid, 0.0)
            if track:
                lastpos[rid] = tpos[i]
            continue
        nbytes = sizes[rid]
        while free < nbytes:
            if len(policy) == 0:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim = policy.victim()
            policy.remove(victim)
            resident.discard(victim)
            free += sizes[victim]
            victims.append(victim)
            n_victims += 1
        free -= nbytes
        resident.add(rid)
        if rid not in pinned:
            policy.insert(rid, 0.0)
            if track:
                lastpos[rid] = tpos[i]
        miss_pos.append(tpos[i])
        miss_rid.append(rid)
        vends.append(n_victims)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return miss_pos, miss_rid, nev, victims, (lastpos if track else None)


# ----------------------------------------------------- phase B — accounting

def _phase_b(ct, mgr, s, e, tab, miss_pos, miss_rid, nev, victims, lastpos):
    """Vectorised, bit-exact float accounting for one span.

    Every accumulator fold is seeded with the manager's current value and
    realised with ``np.cumsum`` (an exact sequential fold), so the result
    equals the scalar path's `+=` chain bit for bit.
    """
    fargs = ct.fargs[s:e]
    M = len(miss_pos)
    cost = mgr.cost
    if M == 0:
        traj = np.cumsum(np.concatenate(([mgr.wall], fargs)))
        mgr.wall = float(traj[-1])
        mgr.compute_time = float(
            np.cumsum(np.concatenate(([mgr.compute_time], fargs)))[-1])
        if lastpos:
            q = getattr(mgr.policy, "_q", None)
            if q is not None:
                for rid, k in lastpos.items():
                    if rid in q:
                        q[rid] = float(traj[k - s + 1])
        return

    m_pos = np.asarray(miss_pos, dtype=np.int64)
    m_rid = np.asarray(miss_rid, dtype=np.int64)
    m_nev = np.asarray(nev, dtype=np.int64)
    v_rid = np.asarray(victims, dtype=np.int64)
    miss_rid_l = miss_rid.tolist() if isinstance(miss_rid, np.ndarray) \
        else miss_rid
    sizeidx = tab["sizeidx"]
    terms = tab["terms"][sizeidx[m_rid]]            # (M, 5)
    t1, t2, t3, t4, t5 = terms.T
    ec_v = tab["ecs"][sizeidx[v_rid]] if len(v_rid) else np.zeros(0)

    # fold eviction costs into each migration's alloc term, preserving the
    # scalar path's per-eviction add order (0/1 evictions vectorised)
    alloc = t3.copy()
    ends = np.cumsum(m_nev)
    starts = ends - m_nev
    one = m_nev == 1
    if one.any():
        alloc[one] = t3[one] + ec_v[starts[one]]
    for i in np.nonzero(m_nev > 1)[0].tolist():
        a = alloc[i]
        for j in range(starts[i], ends[i]):
            a += ec_v[j]
        alloc[i] = a
    total = (((t1 + t2) + alloc) + t4) + t5

    if mgr.parallel_evict:
        # §4.2 parallel implementation: overlap evictions with the blocked
        # migration (plus lock/rollback overhead)
        base = (((t1 + t2) + t3) + t4) + t5
        evw = np.zeros(M)
        if one.any():
            evw[one] = ec_v[starts[one]]
        for i in np.nonzero(m_nev > 1)[0].tolist():
            a = 0.0
            for j in range(starts[i], ends[i]):
                a += ec_v[j]
            evw[i] = a
        total = np.where(m_nev > 0, np.maximum(base, evw) + 5e-6, base)

    # wall trajectory over the whole span (compute ops interleave misses;
    # hit ops contribute +0.0, which is add-identity for finite wall)
    deltas = fargs.copy()
    rel_pos = m_pos - s
    deltas[rel_pos] = total
    traj = np.cumsum(np.concatenate(([mgr.wall], deltas)))
    mgr.wall = float(traj[-1])
    mgr.compute_time = float(
        np.cumsum(np.concatenate(([mgr.compute_time], fargs)))[-1])

    # five-term cost ledger: one stacked exact fold, seeded with the
    # current accumulator values
    ledger = np.empty((M + 1, 5))
    ledger[0] = (cost.cpu_unmap, cost.sdma_setup, cost.alloc,
                 cost.cpu_update, cost.misc)
    ledger[1:, 0] = t1
    ledger[1:, 1] = t2
    ledger[1:, 2] = alloc
    ledger[1:, 3] = t4
    ledger[1:, 4] = t5
    (cost.cpu_unmap, cost.sdma_setup, cost.alloc, cost.cpu_update,
     cost.misc) = np.cumsum(ledger, axis=0)[-1].tolist()
    if len(ec_v):
        mgr.evict_cost_total = float(
            np.cumsum(np.concatenate(([mgr.evict_cost_total], ec_v)))[-1])

    # counters
    nmig0 = mgr.n_migrations
    mgr.n_migrations = nmig0 + M
    mgr.n_evictions += len(victims)
    msz = tab["size_arr"][m_rid]
    mgr.bytes_migrated += int(msz.sum())
    if len(v_rid):
        mgr.bytes_evicted += int(tab["size_arr"][v_rid].sum())
    mgr.faults_serviceable += M

    # duplicate faults: same deterministic jitter as SVMManager._noise
    conc_m = ct.concs[m_pos]
    kk = np.arange(nmig0 + 1, nmig0 + M + 1, dtype=np.uint64)
    h = (kk * np.uint64(2654435761)
         + np.uint64((mgr._seed * 97) & 0xFFFFFFFF)) & np.uint64(0xFFFFFFFF)
    noise = 0.8 + 0.4 * (h.astype(np.float64) / float(0xFFFFFFFF))
    dup = (conc_m * noise).astype(np.int64) - 1
    np.clip(dup, 0, None, out=dup)
    mgr.faults_duplicate += int(dup.sum())

    # trigger pages
    trig = tab["pages"][m_rid] + ct.hints[m_pos]
    high = conc_m >= 32
    if high.any():
        mgr.trigger_pages.update(
            np.concatenate([trig, trig[high] + 1]).tolist())
    else:
        mgr.trigger_pages.update(trig.tolist())

    # eviction notification (push-based listeners + epoch, fired at flush)
    if victims:
        mgr.eviction_epoch += len(victims)
        if mgr._evict_listeners:
            for v in victims:
                for cb in mgr._evict_listeners:
                    cb(v)

    # patch the (write-only) policy timestamps of surviving queue entries
    q = getattr(mgr.policy, "_q", None)
    if q is not None:
        if lastpos is None:           # LRF: inserts happen only on misses
            wall_at = traj[rel_pos + 1].tolist()
            for rid, w in zip(miss_rid_l, wall_at):
                if rid in q:
                    q[rid] = w
        else:
            for rid, k in lastpos.items():
                if rid in q:
                    q[rid] = float(traj[k - s + 1])

    if mgr.profile:
        _emit_profile(ct, mgr, s, tab, traj, m_pos, miss_rid_l, starts, ends,
                      victims, dup, trig)


def _emit_profile(ct, mgr, s, tab, traj, m_pos, miss_rid, starts, ends,
                  victims, dup, trig):
    events = mgr.events
    density = mgr.density
    alloc_ids = tab["alloc_ids"]
    sizes = tab["sizes"]
    traj_l = traj.tolist()
    pos_l = (m_pos - s).tolist()
    starts_l = starts.tolist()
    ends_l = ends.tolist()
    dup_l = dup.tolist()
    trig_l = trig.tolist()
    for i, rid in enumerate(miss_rid):
        j = pos_l[i]
        w_before = traj_l[j]
        w_after = traj_l[j + 1]
        for vi in range(starts_l[i], ends_l[i]):
            v = victims[vi]
            events.append(Event(w_before, "evt", v, alloc_ids[v], sizes[v]))
        events.append(Event(w_after, "mig", rid, alloc_ids[rid], sizes[rid]))
        density.append(DensitySample(w_after, rid, alloc_ids[rid],
                                     1 + dup_l[i], trig_l[i]))
