"""Compiled-session runtime (TraceSession + the rebuilt svm/launch layer).

Pins the PR-4 contract:

  * session replay is *resumable*: ops recorded incrementally and replayed
    in arbitrary segment splits leave the manager byte-identical to the
    scalar `apply_trace` walk of the same op stream (residency / clock /
    ledgers carry across segment replays);
  * the streaming executor and activation-offload scheduler drive the
    manager exclusively through recorded ops — session-batched vs
    session-scalar metrics are byte-identical over mode × policy × DOS;
  * a decode loop's per-token trace compiles once and replays as a cached
    segment every later token (cache hits counted);
  * the `OP_SPILL` boundary op (eager-spill-until-free) matches the old
    imperative spill loop on both engines, and is rejected by the UVM
    interpreter;
  * statically: no module under `repro.svm` / `repro.launch` calls the
    manager's touch/evict methods directly anymore.
"""

import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    MB,
    AddressSpace,
    SVMManager,
    TraceSession,
    UVMManager,
    make_workload,
)
from repro.core.engine import compile_trace, execute_compiled
from repro.core.simulator import apply_trace
from repro.svm import StreamingExecutor, plan_offload, simulate_offload
from repro.svm.executor import run_layer_stream

CAP = 2 * GB


# ------------------------------------------------- resumable session replay

def _workload_ops(name="stream", dos=1.25):
    space = AddressSpace(CAP, base=175 * MB, alignment=8 * MB)
    wl = make_workload(name, int(CAP * dos))
    wl.build(space)
    return space, list(wl.trace(space))


@pytest.mark.parametrize("name", ("stream", "jacobi2d", "gesummv"))
@pytest.mark.parametrize("seg", (7, 64, 10_000_000))
def test_segmented_replay_resumes_byte_identical(name, seg):
    """Recording a trace into arbitrary segment splits and replaying them
    back-to-back equals the scalar walk of the whole op stream: manager
    state carries across segment replays."""
    space, ops = _workload_ops(name)
    ms = SVMManager(space, policy="lrf")
    apply_trace(ms, iter(ops))

    space_b, ops_b = _workload_ops(name)
    mb = SVMManager(space_b, policy="lrf")
    sess = TraceSession(mb)
    for k in range(0, len(ops_b), seg):
        sess.record(ops_b[k:k + seg])
        sess.flush()
    assert ms.summary() == mb.summary()
    assert ms.events == mb.events
    assert ms.resident == mb.resident
    assert ms.free == mb.free
    assert sess.segments_replayed == -(-len(ops_b) // seg)


def test_session_scalar_mode_replays_op_for_op():
    space, ops = _workload_ops("gesummv")
    ms = SVMManager(space, policy="clock")
    apply_trace(ms, iter(ops))
    space_b, ops_b = _workload_ops("gesummv")
    mb = SVMManager(space_b, policy="clock")
    sess = TraceSession(mb, scalar=True)
    sess.record(ops_b)
    sess.flush()
    assert ms.summary() == mb.summary()
    assert ms.events == mb.events


def test_session_run_caches_and_counts():
    space = AddressSpace(16 * MB, base=0, alignment=2 * MB)
    for i in range(8):
        space.alloc(2 * MB, f"a{i}")
    mgr = SVMManager(space)
    sess = TraceSession(mgr)

    def rec(s):
        for rid in range(8):
            s.touch(rid, concurrency=1)

    ct = sess.run("tok", rec)
    assert (sess.cache_misses, sess.cache_hits) == (1, 0)
    for _ in range(3):
        assert sess.run("tok", rec) is ct     # same compiled segment
    assert (sess.cache_misses, sess.cache_hits) == (1, 3)
    assert sess.segments_replayed == 4
    # replays resumed against live state: later tokens all hit
    assert mgr.n_migrations == 8
    # run() refuses to discard pending recorded ops
    sess.touch(0, concurrency=1)
    with pytest.raises(RuntimeError, match="pending"):
        sess.run("tok", rec)
    sess.flush()


def test_session_lru_eviction_bounded():
    space = AddressSpace(16 * MB, base=0, alignment=2 * MB)
    space.alloc(2 * MB, "a")
    sess = TraceSession(SVMManager(space), cache_size=2)
    for key in ("x", "y", "z"):
        sess.run(key, lambda s: s.touch(0, concurrency=1))
    assert sess.get("x") is None          # evicted
    assert sess.get("y") is not None and sess.get("z") is not None


# --------------------------------------------------------------- OP_SPILL

def _spill_ops(n=8):
    ops = []
    for i in range(n):
        ops += [("spill", 2 * MB, 0.85), ("touch", i, 8, 0),
                ("compute", 1e-4)]
    for i in range(n - 1, -1, -1):
        ops += [("touch", i, 8, 0), ("compute", 2e-4)]
    return ops


def _spill_space(n=8):
    s = AddressSpace(3 * 2 * MB, base=0, alignment=2 * MB)
    for i in range(n):
        s.alloc(2 * MB, f"a{i}")
    return s


def test_spill_op_scalar_and_batched_match_imperative_loop():
    ops = _spill_ops()
    mgr_i = SVMManager(_spill_space())
    # the old imperative eager-spill loop, inlined as the reference
    for op in ops:
        if op[0] == "spill":
            while mgr_i.free < op[1] and \
                    mgr_i.spill_oldest(overlap=op[2]) is not None:
                pass
        elif op[0] == "touch":
            mgr_i.touch(op[1], concurrency=op[2], page_hint=op[3])
        else:
            mgr_i.advance(op[1])
    for scalar in (True, False):
        mgr = SVMManager(_spill_space())
        sess = TraceSession(mgr, scalar=scalar)
        sess.record(_spill_ops())
        sess.flush()
        assert mgr.summary() == mgr_i.summary(), f"scalar={scalar}"
        assert mgr.events == mgr_i.events


def test_spill_op_rejected_by_uvm_interpreter():
    space = AddressSpace(8 * MB, base=0)
    space.alloc(2 * MB, "a")
    ct = compile_trace(iter([("spill", 2 * MB, 0.5)]))
    with pytest.raises(ValueError, match="unsupported"):
        execute_compiled(ct, UVMManager(space))


# ------------------------------------------ executor: session ≡ imperative

def _exec_params(n_layers, d=64):
    key = jax.random.PRNGKey(0)
    return {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (d, d),
                                       jnp.float32)
            for i in range(n_layers)}


MODES = {
    "naive": {},
    "svm_aware": {"prefetch": True, "pin": ("l0",)},
    "zero_copy": {"zero_copy": ("l5", "l6", "l7")},
}


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("policy", ("lrf", "clock", "lru"))
def test_executor_session_metrics_match_scalar(mode, policy):
    """Session-batched decode == the scalar imperative op walk, byte for
    byte, across streaming mode × policy × oversubscription ratio."""
    for frac in (0.5, 0.8, 2.0):
        results = {}
        for scalar in (True, False):
            params = _exec_params(12)
            total = 12 * 64 * 64 * 4
            ex = StreamingExecutor(params, int(total * frac), policy=policy,
                                   scalar=scalar, **MODES[mode])
            paths = [[f"l{i}"] for i in range(12)]
            results[scalar] = run_layer_stream(
                ex, paths, lambda i, t: 2.0 * 64 * 64, steps=3)
        assert results[True] == results[False], (mode, policy, frac)


def test_decode_step_equals_per_fetch_walk():
    """Batching a whole token into one compiled segment emits exactly the
    imperative per-fetch op sequence: summaries match a fetch-by-fetch
    drive of the same layer schedule."""
    paths = [[f"l{i}"] for i in range(10)]
    flops = [2.0 * 64 * 64] * 10

    def mk():
        return StreamingExecutor(_exec_params(10), int(10 * 64 * 64 * 4
                                                       * 0.6))
    ex_a = mk()
    for _ in range(4):
        ex_a.decode_step(paths, flops)
    ex_b = mk()
    for _ in range(4):
        for i, ps in enumerate(paths):
            for p in ps:
                ex_b.fetch(p)
            ex_b.charge_compute(flops[i])
    assert ex_a.mgr.summary() == ex_b.mgr.summary()
    assert ex_a.mgr.events == ex_b.mgr.events


def test_multi_token_decode_reuses_compiled_trace():
    """The serving hot path: token 1 records + compiles the per-token
    trace; every later token replays the cached segment (counted)."""
    params = _exec_params(16)
    total = 16 * 64 * 64 * 4
    ex = StreamingExecutor(params, int(total * 0.6))
    paths = [[f"l{i}"] for i in range(16)]
    steps = 6
    m = run_layer_stream(ex, paths, lambda i, t: 2.0 * 64 * 64, steps=steps)
    assert m["segment_cache_misses"] == 1          # compiled once
    assert m["segment_cache_hits"] == steps - 1    # replayed every token
    assert m["segments_replayed"] == steps
    assert m["evictions"] > 0                      # genuinely thrashing


def test_multi_token_prefetch_decode_reuses_segments():
    params = _exec_params(12)
    total = 12 * 64 * 64 * 4
    ex = StreamingExecutor(params, int(total * 0.6), prefetch=True)
    paths = [[f"l{i}"] for i in range(12)]
    steps = 5
    m = run_layer_stream(ex, paths, lambda i, t: 2.0 * 64 * 64, steps=steps)
    per_token = m["segment_cache_misses"]
    assert m["segment_cache_hits"] == (steps - 1) * per_token
    assert m["overlap_hidden_s"] > 0.0


def test_executor_compute_rate_from_cost_params():
    from repro.core.costmodel import TPU_V5E_HOST
    import dataclasses
    params = _exec_params(4)
    ex = StreamingExecutor(params, 4 * 64 * 64 * 4)
    assert ex.compute_rate == TPU_V5E_HOST.serve_flops == 197e12 * 0.4
    fast = dataclasses.replace(TPU_V5E_HOST, serve_flops=1e12)
    ex2 = StreamingExecutor(params, 4 * 64 * 64 * 4, cost_params=fast)
    assert ex2.compute_rate == 1e12
    ex3 = StreamingExecutor(params, 4 * 64 * 64 * 4, cost_params=fast,
                            compute_rate=5e12)
    assert ex3.compute_rate == 5e12
    # slower compute rate => more simulated seconds per flop
    ex2.charge_compute(1e9)
    ex3.charge_compute(1e9)
    assert ex2.mgr.compute_time > ex3.mgr.compute_time


# ------------------------------------------- offload: session ≡ imperative

@pytest.mark.parametrize("svm_aware", (False, True))
@pytest.mark.parametrize("n_layers,res", ((24, 8), (16, 12), (10, 3)))
def test_offload_session_matches_scalar(svm_aware, n_layers, res):
    kw = dict(n_layers=n_layers, act_bytes=16 * MB,
              budget_bytes=res * 16 * MB)
    for cps in (0.0, 1e-3):
        a = simulate_offload(plan_offload(**kw, svm_aware=svm_aware),
                             engine="scalar", compute_per_layer_s=cps)
        b = simulate_offload(plan_offload(**kw, svm_aware=svm_aware),
                             engine="session", compute_per_layer_s=cps)
        assert a == b, (svm_aware, n_layers, res, cps)


def test_offload_session_stats_exposed():
    stats = {}
    simulate_offload(plan_offload(12, 16 * MB, 4 * 16 * MB),
                     session_stats=stats)
    assert stats["segments_sealed"] == stats["segments_replayed"] == 1
    assert stats["ops_recorded"] == 12 * 3 + 12 * 2  # spill+touch+compute,
    assert stats["ops_replayed"] == stats["ops_recorded"]


def test_offload_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_offload(plan_offload(4, MB, 4 * MB), engine="batched")


# ------------------------------------- acceptance: no direct manager pokes

def test_runtime_layer_never_drives_manager_directly():
    """Every access from the runtime layer must be a recorded op replayed
    through the engine — enforced by svmlint's manager-encapsulation rule
    (repro.analysis), which this test runs over repro.svm + repro.launch."""
    from repro.analysis import lint_paths

    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    findings = lint_paths(
        [os.path.join(root, "svm"), os.path.join(root, "launch")],
        rules=["manager-encapsulation"])
    assert not findings, "\n".join(f.format() for f in findings)


# ----------------------------------- preempt / drain / resume (chaos layer)

def _preempt_cycle(policy: str, scalar: bool) -> SVMManager:
    """One preempt/drain/resume cycle as the chaos scheduler drives it:
    pin, decode tokens, eagerly drain (unpin + writeback + flush),
    decode again from the carried session state."""
    space = AddressSpace(8 * MB, base=0, alignment=2 * MB)
    for i in range(8):                     # 16 MB of ranges on an 8 MB pool
        space.alloc(2 * MB, f"a{i}")
    mgr = SVMManager(space, policy=policy, profile=False)
    sess = TraceSession(mgr, scalar=scalar)

    def rec(s):
        for rid in range(8):
            s.touch(rid, concurrency=4)
        s.compute(1e-4)

    sess.pin(0)
    sess.flush()
    for _ in range(3):
        sess.run("tok", rec)
    # eager drain: exactly PoolScheduler._evacuate's op sequence
    sess.unpin(0)
    for rid in range(8):
        sess.writeback(rid)
    sess.flush()
    for _ in range(3):                     # resume: same compiled segment
        sess.run("tok", rec)
    return mgr


@pytest.mark.parametrize("policy", ("lrf", "clock", "lru"))
def test_preempt_drain_resume_byte_identical(policy):
    """A drained-and-resumed session replays byte-identically in scalar
    and batched mode: residency, clocks, and ledgers all carry across
    the preemption cycle regardless of eviction policy."""
    a = _preempt_cycle(policy, scalar=False)
    b = _preempt_cycle(policy, scalar=True)
    assert a.summary() == b.summary()
    assert sorted(a.resident) == sorted(b.resident)
    # the drain really evicted: writebacks count as evictions
    assert a.n_evictions > 0


def test_replay_scalar_matches_replay():
    """`TraceSession.replay_scalar` (the chaos layer's golden path for
    fault-armed tokens) is byte-identical to the batched `replay` of the
    same compiled segment."""
    def run(use_scalar: bool) -> SVMManager:
        space = AddressSpace(8 * MB, base=0, alignment=2 * MB)
        for i in range(8):
            space.alloc(2 * MB, f"a{i}")
        mgr = SVMManager(space, policy="lrf", profile=False)
        sess = TraceSession(mgr)

        def rec(s):
            for rid in range(8):
                s.touch(rid, concurrency=4)
            s.compute(1e-4)

        ct = sess.fetch("tok", rec)
        for _ in range(4):
            if use_scalar:
                sess.replay_scalar(ct)
            else:
                sess.replay(ct)
        return mgr

    assert run(True).summary() == run(False).summary()
