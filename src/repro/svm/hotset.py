"""Hot-set estimation from compiled-trace touch columns.

The paper's central finding is that SVM's aggressive whole-range
prefetch, in tandem with eviction, thrashes under oversubscription — and
the scheduler compounds it by admitting tenants by *total plan bytes*,
not by what they actually keep resident.  The engine's compiled traces
are exactly the access logs the DL-prefetch line of work learns from
(arXiv 2203.12672), so the measured alternative needs no new telemetry:
a `HotSetProfile` is derived **from the touch/rid columns of a
`CompiledTrace`** in one vectorised NumPy pass —

  * per-rid touch frequency (how often a range is accessed over the
    profiled window),
  * a reuse-interval histogram: for every re-touch of a rid, the bytes
    touched in between — log2-bucketed, the classic working-set curve,
  * per-rid mean/min reuse interval in bytes: ranges whose reuse
    interval exceeds the pool window cannot stay resident no matter what
    the eviction policy does (they *stream*); ranges under it form the
    measured hot set,
  * ``resident_bytes(window)``: the estimated resident working set at a
    given pressure — hot bytes plus one streaming buffer (the largest
    cold range, the room a cyclic scan needs in flight).

Profiles are a pure function of the trace's *relative* rid layout (rids
are stored relative to ``rid_base``), so congruent tenants — equal plan
`geometry()` — share one profile via `ProfileCache`, exactly like the
relocating `SegmentCache` shares compiled segments.

Consumers:

  * `StreamingExecutor(prefetch_mode="measured")` — pins only leaves
    above a touch-frequency threshold instead of prefetching every next
    layer (docs/prefetching.md),
  * `PoolScheduler(admit_by="measured")` — admission caps *estimated
    resident* bytes instead of total plan bytes,
  * `simulate(measured_pin=...)` — the sweep axis comparing measured
    against the paper's aggressive default on the hot-set adversaries.

This module never drives a manager: it only reads frozen op columns.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import ColumnEmitter, CompiledTrace

#: log2 byte buckets of the reuse-interval histogram (2^0 .. 2^47)
REUSE_BUCKETS = 48


@dataclasses.dataclass(frozen=True)
class HotSetProfile:
    """Per-rid touch statistics over one profiled trace window.

    All rids are **relative** to the ``rid_base`` the profile was built
    with, so a profile computed for one tenant applies verbatim to every
    congruent tenant (same relative layout at a different pool offset).
    Arrays are aligned: entry ``i`` describes relative rid ``rids[i]``.
    """

    rids: np.ndarray          # int64, ascending — relative rids touched
    freq: np.ndarray          # int64 — touches per rid in the window
    sizes: np.ndarray         # int64 — bytes per rid
    reuse_min: np.ndarray     # float64 — min bytes between re-touches
    reuse_mean: np.ndarray    # float64 — mean bytes between re-touches
    reuse_hist: np.ndarray    # int64[REUSE_BUCKETS] — log2-bucketed
    n_touches: int            # total touches in the window
    touched_bytes: int        # sum of sizes over touched rids

    def __post_init__(self) -> None:
        for arr in (self.rids, self.freq, self.sizes, self.reuse_min,
                    self.reuse_mean, self.reuse_hist):
            arr.flags.writeable = False  # svmlint: disable=frozen-mutation -- freezing the profile's own freshly-built arrays (shared across congruent tenants), not un-freezing trace columns

    @classmethod
    def from_touches(cls, rid_seq: np.ndarray, size_arr: np.ndarray,
                     rid_base: int = 0) -> "HotSetProfile":
        """Profile a touch-ordered rid sequence in one NumPy pass.

        ``rid_seq`` is the absolute-rid touch column; ``size_arr`` maps
        absolute rid -> range bytes.  A rid touched once has infinite
        reuse interval (it never demonstrably re-uses its residency)."""
        seq = np.asarray(rid_seq, dtype=np.int64)
        n = len(seq)
        if n == 0:
            z = np.zeros(0, dtype=np.int64)
            return cls(rids=z, freq=z.copy(), sizes=z.copy(),
                       reuse_min=np.zeros(0), reuse_mean=np.zeros(0),
                       reuse_hist=np.zeros(REUSE_BUCKETS, dtype=np.int64),
                       n_touches=0, touched_bytes=0)
        sizes_t = np.asarray(size_arr, dtype=np.int64)[seq]
        u, inv, cnt = np.unique(seq, return_inverse=True,
                                return_counts=True)
        # previous-occurrence index per touch: stable sort groups equal
        # rids in touch order, so within a group each entry's predecessor
        # is that rid's previous touch
        order = np.argsort(seq, kind="stable")
        prev = np.full(n, -1, dtype=np.int64)
        same = seq[order[1:]] == seq[order[:-1]]
        prev[order[1:][same]] = order[:-1][same]
        # bytes touched strictly between a touch and its predecessor:
        # prefix sums of the per-touch sizes, exclusive on both ends
        cum = np.concatenate((np.zeros(1, dtype=np.int64),
                              np.cumsum(sizes_t)))
        idx = np.nonzero(prev >= 0)[0]
        gaps = (cum[idx] - cum[prev[idx] + 1]).astype(np.float64)
        reuse_min = np.full(len(u), np.inf)
        reuse_sum = np.zeros(len(u))
        reuse_cnt = np.zeros(len(u), dtype=np.int64)
        if len(idx):
            np.minimum.at(reuse_min, inv[idx], gaps)
            np.add.at(reuse_sum, inv[idx], gaps)
            np.add.at(reuse_cnt, inv[idx], np.ones(len(idx),
                                                   dtype=np.int64))
        reuse_mean = np.where(reuse_cnt > 0,
                              reuse_sum / np.maximum(reuse_cnt, 1),
                              np.inf)
        hist = np.zeros(REUSE_BUCKETS, dtype=np.int64)
        if len(gaps):
            buckets = np.clip(np.log2(gaps + 1.0).astype(np.int64), 0,
                              REUSE_BUCKETS - 1)
            hist = np.bincount(buckets,
                               minlength=REUSE_BUCKETS).astype(np.int64)
        usz = np.asarray(size_arr, dtype=np.int64)[u]
        return cls(rids=u - rid_base, freq=cnt.astype(np.int64),
                   sizes=usz, reuse_min=reuse_min, reuse_mean=reuse_mean,
                   reuse_hist=hist, n_touches=int(n),
                   touched_bytes=int(usz.sum()))

    @classmethod
    def from_trace(cls, ct: CompiledTrace, size_arr: np.ndarray,
                   rid_base: int = 0) -> "HotSetProfile":
        """Profile a compiled trace's touch columns (read-only)."""
        _, rid_col = ct.touch_columns()
        return cls.from_touches(rid_col, size_arr, rid_base=rid_base)

    # ----------------------------------------------------------- queries

    def hot_mask(self, window_bytes: float) -> np.ndarray:
        """Which touched rids can stay resident at the given pressure:
        mean reuse interval within the window (bytes).  Mean, not min —
        a streaming range that once re-touches back-to-back should not
        be promoted by a single lucky interval."""
        return self.reuse_mean <= float(window_bytes)

    def hot_bytes(self, window_bytes: float) -> int:
        """Bytes of the measured hot set at the given pressure."""
        return int(self.sizes[self.hot_mask(window_bytes)].sum())

    def resident_bytes(self, window_bytes: float) -> int:
        """Estimated resident working set at the given pressure: the hot
        set stays resident; everything else streams through one buffer
        sized by the largest cold range (the in-flight migration room a
        cyclic scan needs).  Untouched plan bytes cost nothing — that is
        the whole point of measuring."""
        hot = self.hot_mask(window_bytes)
        cold = self.sizes[~hot]
        buf = int(cold.max()) if len(cold) else 0
        return int(self.sizes[hot].sum()) + buf

    def select_hot_rids(self, window_bytes: float,
                        budget_bytes: float) -> np.ndarray:
        """The measured-prefetch pick: hot rids (by `hot_mask`), highest
        touch frequency first, cut off where cumulative bytes exceed
        ``budget_bytes``.  Returns *relative* rids, ascending — a
        deterministic set for any congruent tenant."""
        hot = np.nonzero(self.hot_mask(window_bytes))[0]
        if not len(hot):
            return np.zeros(0, dtype=np.int64)
        # stable order: frequency desc, then rid asc for ties
        order = hot[np.lexsort((self.rids[hot], -self.freq[hot]))]
        keep = order[np.cumsum(self.sizes[order]) <= float(budget_bytes)]
        return np.sort(self.rids[keep])


class ProfileCache:
    """Geometry-keyed profile memo: congruent tenants (equal plan
    geometry / equal `TraceKey`) share one `HotSetProfile` instead of
    re-deriving it per tenant.  Pure dict + counters — profiles are
    immutable, so sharing needs no relocation step."""

    def __init__(self) -> None:
        self._entries: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get_or_build(self, key, build) -> HotSetProfile:
        prof = self._entries.get(key)
        if prof is not None:
            self.hits += 1
            return prof
        self.misses += 1
        prof = self._entries[key] = build()
        return prof

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses}


def token_trace(leaf_ranges: dict, layer_paths, concurrency: int = 64,
                tokens: int = 1) -> CompiledTrace:
    """Lower ``tokens`` decode tokens of a spec-shaped fetch schedule
    into a compiled trace (touch columns only — no compute timing is
    needed to profile reuse).  ``tokens >= 2`` captures the cross-token
    reuse interval of every leaf, which one token cannot see."""
    em = ColumnEmitter()
    rid_cols = [np.asarray([rid for p in paths for rid in leaf_ranges[p]],
                           dtype=np.int64)
                for paths in layer_paths]
    for _ in range(max(1, int(tokens))):
        for rids in rid_cols:
            em.touches(rids, concurrency)
    return em.finish()


def spec_profile(spec, *, cache: ProfileCache | None = None,
                 concurrency: int = 64, tokens: int = 2) -> HotSetProfile:
    """Measured profile for a `ModelSpec`-shaped object (``leaves`` +
    ``layer_paths``), planned into a throwaway address space and
    profiled over ``tokens`` decode tokens.  With a ``cache``, congruent
    specs (same spec hash ⇒ same plan geometry by construction) build
    once and share."""
    def build() -> HotSetProfile:
        from repro.svm.planner import plan_leaf_ranges

        plan = plan_leaf_ranges(list(spec.leaves),
                                max(int(spec.total_bytes), 1))
        ct = token_trace(plan.leaf_ranges, spec.layer_paths,
                         concurrency=concurrency, tokens=tokens)
        size_arr = np.asarray([r.end - r.start
                               for r in plan.space.ranges],
                              dtype=np.int64)
        return HotSetProfile.from_trace(ct, size_arr,
                                        rid_base=plan.rid_base)

    if cache is None:
        return build()
    return cache.get_or_build((spec, int(tokens)), build)
