"""Chaos layer: fault injection, thrash-guard degradation, recovery.

Covers: the seeded `FaultPlan`/`FaultInjector` bookkeeping, the public
manager chaos hooks (`resize_capacity`, `arm_migration_faults`,
`inject_latency`) including the fault-before-mutation guarantee, exact
conservation of per-request accounting under every injected schedule
(policy × hazard × seed), crash/preemption drain-and-resume, bounded
retry with deterministic backoff charged to the simulated clock,
retry-budget exhaustion dropping a request (and the empty-`done` report
staying well-formed), the thrash guard's preempt-and-tighten ladder, the
fused-divergence guard's per-token fallback, cross-tier byte-identity of
whole chaos runs (fused ≡ per-token ≡ scalar), and the 64-request
acceptance schedule with bit-identical reruns."""

import numpy as np
import pytest

from repro.core import MB, AddressSpace, MigrationError, SVMManager
from repro.ft.retry import RetryPolicy
from repro.svm import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    ModelSpec,
    PoolScheduler,
    make_requests,
)

SPEC_A = ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB)
SPEC_B = ModelSpec.synthetic("archB", 10, 2 * MB, embed_bytes=6 * MB)

# the bench_engine.py gate mix: archA fits the pool, archB is
# individually oversubscribed
GATE_SPECS = [
    ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
    ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB),
]
GATE_CAP = 100 * MB


def chaos_run(policy="fifo", *, n=10, tokens=8, plan_seed=1, cap=30 * MB,
              specs=(SPEC_A, SPEC_B), plan=None, **kw):
    reqs = make_requests(list(specs), n, seed=2, tokens=tokens)
    if plan is None:
        plan = FaultPlan.default(plan_seed, n_requests=n, tokens=tokens)
    sched = PoolScheduler(cap, policy=policy, fault_plan=plan, **kw)
    return sched.run(reqs)


def assert_conserved(r):
    c, m = r["conservation"], r["mgr"]
    assert c["svm_wall_s"] == pytest.approx(m["wall_s"], abs=1e-9)
    assert c["migrations"] == m["migrations"]
    assert c["evictions"] == m["evictions"]
    assert c["bytes_migrated"] == m["bytes_migrated"]
    assert c["bytes_evicted"] == m["bytes_evicted"]


# ------------------------------------------------------- plan / injector

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown hazard"):
        FaultEvent(0, "meteor_strike")
    with pytest.raises(ValueError, match="at_tokens"):
        FaultEvent(-1, "crash")
    with pytest.raises(ValueError, match="frac"):
        FaultEvent(0, "slow_page", frac=0.0)


def test_default_plan_is_seeded_and_bounded():
    p1 = FaultPlan.default(7, n_requests=16, tokens=8)
    p2 = FaultPlan.default(7, n_requests=16, tokens=8)
    p3 = FaultPlan.default(8, n_requests=16, tokens=8)
    assert p1 == p2
    assert p1 != p3
    horizon = 16 * 8
    kinds = [e.kind for e in p1.events]
    assert kinds.count("capacity_loss") == 1
    assert kinds.count("capacity_restore") == 1
    assert kinds.count("slow_page") == 1
    assert kinds.count("slow_page_end") == 1
    assert kinds.count("crash") == 1
    assert kinds.count("migration_fault") == 3
    # everything lands inside the token horizon, so the plan fully fires
    assert all(e.at_tokens <= horizon for e in p1.events)
    # intensity scales the migration-fault count
    p4 = FaultPlan.default(7, n_requests=16, tokens=8, intensity=2.0)
    assert [e.kind for e in p4.events].count("migration_fault") == 6


def test_injector_pumps_in_order():
    plan = FaultPlan(events=(
        FaultEvent(5, "slow_page", frac=2.0),
        FaultEvent(5, "migration_fault"),
        FaultEvent(5, "crash"),
        FaultEvent(9, "slow_page_end"),
    ))
    inj = FaultInjector(plan)
    assert inj.next_at() == 5
    assert inj.due_env(4) == []
    env = inj.due_env(5)
    assert [e.kind for e in env] == ["slow_page"]
    # token events pop one per decoded token, so a burst lands on
    # consecutive tokens instead of collapsing
    assert inj.pop_token_event(5).kind == "crash"
    assert inj.pop_token_event(5).kind == "migration_fault"
    assert inj.pop_token_event(5) is None
    assert inj.remaining == 1
    assert inj.next_at() == 9
    assert inj.due_env(9)[0].kind == "slow_page_end"
    assert inj.remaining == 0
    assert inj.next_at() == float("inf")
    assert inj.stats()["events_applied"] == 4


# ------------------------------------------------------- manager hooks

def make_mgr(cap=16 * MB, n=8, size=2 * MB, policy="lrf"):
    # the space's capacity IS the device pool size; allocations may
    # oversubscribe it (that is the paper's whole premise)
    space = AddressSpace(cap, alignment=2 * MB)
    for i in range(n):
        space.alloc(size, f"a{i}")
    return SVMManager(space, policy=policy, profile=False)


def test_resize_capacity_emergency_evicts():
    m = make_mgr(cap=8 * MB, n=4)
    for rid in range(4):
        m.touch(rid)
    assert m.free == 0
    ev0, w0 = m.n_evictions, m.wall
    w = m.resize_capacity(4 * MB)
    assert m.capacity == 4 * MB
    assert m.n_evictions - ev0 == 2       # two 2MB victims out
    assert m.free == 0
    assert w > 0.0 and m.wall == pytest.approx(w0 + w)
    # growing back frees headroom without touching residency
    ev1 = m.n_evictions
    assert m.resize_capacity(8 * MB) == 0.0
    assert m.free == 4 * MB and m.n_evictions == ev1
    with pytest.raises(ValueError):
        m.resize_capacity(0)


def test_armed_migration_fault_raises_before_any_mutation():
    m = make_mgr()
    m.touch(0)
    snap = (m.wall, m.n_migrations, m.n_evictions, m.bytes_migrated,
            m.free, frozenset(m.resident), m.cost.total())
    m.arm_migration_faults(1)
    with pytest.raises(MigrationError):
        m.touch(1)
    assert (m.wall, m.n_migrations, m.n_evictions, m.bytes_migrated,
            m.free, frozenset(m.resident), m.cost.total()) == snap
    assert m.migration_faults == 1 and m.fault_armed == 0
    # disarmed: the retry succeeds and mutates normally
    assert m.touch(1) is False and 1 in m.resident


def test_inject_latency_ledgers_chaos_wall():
    m = make_mgr()
    w0 = m.wall
    m.inject_latency(0.25)
    assert m.wall == pytest.approx(w0 + 0.25)
    assert m.chaos_wall == pytest.approx(0.25)
    assert m.summary()["chaos_wall_s"] == pytest.approx(0.25)


# ------------------------------------- conservation under every schedule

@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
@pytest.mark.parametrize("seed", [0, 1, 3])
def test_conservation_policy_x_hazard_x_seed(policy, seed):
    r = chaos_run(policy, plan_seed=seed, thrash_watermark=3.0,
                  thrash_window=16)
    assert_conserved(r)
    # the whole plan fired and nothing leaked unrecovered
    assert r["chaos"]["injector"]["events_remaining"] == 0
    assert r["n_requests"] + r["n_failed"] == 10


def test_single_hazard_class_runs_conserve():
    hazards = {
        "capacity": (FaultEvent(4, "capacity_loss", frac=0.6),
                     FaultEvent(20, "capacity_restore")),
        "slow_page": (FaultEvent(4, "slow_page", frac=4.0),
                      FaultEvent(20, "slow_page_end")),
        "migration_fault": (FaultEvent(4, "migration_fault",
                                       fail_attempts=2),),
        "crash": (FaultEvent(4, "crash"),),
    }
    for name, events in hazards.items():
        r = chaos_run("fifo", plan=FaultPlan(events=events, name=name))
        assert_conserved(r)
        assert r["chaos"]["injector"]["events_remaining"] == 0
        assert r["n_failed"] == 0, name
        assert all(q["tokens"] == 8 for q in r["requests"]), name


# --------------------------------------------------- recovery behaviours

def test_migration_fault_recovers_via_bounded_retry():
    plan = FaultPlan(events=(FaultEvent(2, "migration_fault",
                                        fail_attempts=2),))
    policy = RetryPolicy(max_attempts=4, base_delay_s=1e-3)
    r = chaos_run("fifo", plan=plan, retry_policy=policy)
    ch = r["chaos"]
    assert ch["migration_faults"] == 1
    assert ch["retries"] == 2
    assert ch["retry_exhausted"] == 0
    # deterministic exponential backoff, charged to the simulated clock
    assert ch["backoff_wall_s"] == pytest.approx(
        policy.delay(1) + policy.delay(2))
    assert r["mgr"]["chaos_wall_s"] >= ch["backoff_wall_s"]
    rows = [q for q in r["requests"] if q["faults"]]
    assert len(rows) == 1
    assert rows[0]["retries"] == 2
    assert rows[0]["backoff_s"] == pytest.approx(ch["backoff_wall_s"])
    assert_conserved(r)


def test_retry_exhaustion_drops_request_and_report_stays_well_formed():
    # one request, an unrecoverable fault at its first token: `done`
    # ends up empty — the report must still be well-formed with zeroed
    # latency rows (regression: the old idle fast-forward IndexError'd
    # and percentiles assumed a non-empty set)
    plan = FaultPlan(events=(FaultEvent(0, "migration_fault",
                                        fail_attempts=99),))
    reqs = make_requests([SPEC_B], 1, seed=0, tokens=4)
    sched = PoolScheduler(8 * MB, policy="fifo", fault_plan=plan,
                          retry_policy=RetryPolicy(max_attempts=3,
                                                   base_delay_s=1e-4))
    r = sched.run(reqs)
    assert r["n_requests"] == 0 and r["n_failed"] == 1
    assert r["latency_p50_s"] == 0.0 and r["ttft_p99_s"] == 0.0
    assert r["queue_wait_mean_s"] == 0.0
    assert r["agg_tok_s"] == 0.0 or r["total_tokens"] == 0
    assert r["chaos"]["retry_exhausted"] == 1
    assert r["chaos"]["retries"] == 2          # max_attempts - 1 backoffs
    row = r["failed_requests"][0]
    assert row["failed"] is True and row["tokens"] == 0
    # the dropped request keeps its charged work — conservation spans it
    assert_conserved(r)
    assert any("retry budget exhausted" in s for s in r["incidents"])


def test_crash_drains_and_resumes_byte_identically():
    plan = FaultPlan(events=(FaultEvent(6, "crash"),))
    r = chaos_run("fifo", plan=plan)
    ch = r["chaos"]
    assert ch["crashes"] == 1 and ch["resumes"] == 1
    crashed = [q for q in r["requests"] if q["crashes"]]
    assert len(crashed) == 1
    # the crashed request still decoded every token after resuming from
    # its carried TraceSession state
    assert crashed[0]["tokens"] == 8
    assert crashed[0]["resumes"] == 1
    assert r["n_failed"] == 0
    assert_conserved(r)
    # same plan, same mix => bit-identical rerun
    r2 = chaos_run("fifo", plan=plan)
    assert r["requests"] == r2["requests"]
    assert r["makespan_s"] == r2["makespan_s"]


def test_slow_page_window_charges_multiplicative_surcharge():
    base = chaos_run("fifo", plan=FaultPlan(events=()))
    slow = chaos_run("fifo", plan=FaultPlan(events=(
        FaultEvent(4, "slow_page", frac=4.0),
        FaultEvent(30, "slow_page_end"))))
    assert slow["chaos"]["slow_page_windows"] == 1
    assert slow["mgr"]["chaos_wall_s"] > 0.0
    assert slow["makespan_s"] > base["makespan_s"]
    assert_conserved(slow)


def test_capacity_loss_forces_emergency_evictions_and_tightens_admission():
    plan = FaultPlan(events=(FaultEvent(4, "capacity_loss", frac=0.5),
                             FaultEvent(40, "capacity_restore")))
    r = chaos_run("admission", plan=plan)
    assert r["chaos"]["capacity_events"] == 2
    assert any("capacity_loss" in s for s in r["incidents"])
    # pool back at nominal by the end
    assert r["mgr"]["capacity_bytes"] == 30 * MB
    assert r["n_failed"] == 0
    assert_conserved(r)


# ------------------------------------------------------- runtime guards

def test_thrash_guard_preempts_and_tightens():
    reqs = make_requests(GATE_SPECS, 8, seed=0, tokens=12)
    sched = PoolScheduler(GATE_CAP, policy="fifo",
                          thrash_watermark=0.5, thrash_window=16)
    r = sched.run(reqs)
    ch = r["chaos"]
    assert ch["thrash_trips"] >= 1
    assert ch["preemptions"] == ch["thrash_trips"]
    assert ch["resumes"] >= 1
    assert ch["admit_watermark_final"] < 1.0
    assert any("thrash-guard trip" in s for s in r["incidents"])
    # every preempted tenant resumed and finished
    assert r["n_requests"] == 8 and r["n_failed"] == 0
    assert all(q["tokens"] == 12 for q in r["requests"])
    assert_conserved(r)
    # deterministic: the guard keys off counters, not the host clock
    reqs2 = make_requests(GATE_SPECS, 8, seed=0, tokens=12)
    sched2 = PoolScheduler(GATE_CAP, policy="fifo",
                           thrash_watermark=0.5, thrash_window=16)
    r2 = sched2.run(reqs2)
    assert r["requests"] == r2["requests"]


def test_thrash_guard_off_by_default_changes_nothing():
    reqs = make_requests(GATE_SPECS, 8, seed=0, tokens=8)
    base = PoolScheduler(GATE_CAP, policy="fifo").run(reqs)
    assert base["chaos"]["thrash_trips"] == 0
    assert base["chaos"]["preemptions"] == 0


def test_fused_divergence_guard_falls_back_per_token():
    reqs = make_requests([SPEC_A], 4, seed=0, tokens=4)
    sched = PoolScheduler(64 * MB, policy="fifo")
    # corrupt every multi-segment concat: drop the last member, so the
    # cut prefix sums cannot match the block's segment totals
    real_concat = sched._concat_round
    def bad_concat(segs):
        return real_concat(segs[:-1])
    sched._concat_round = bad_concat
    r = sched.run(reqs)
    assert r["chaos"]["fused_fallbacks"] >= 1
    assert any("fused divergence" in s for s in r["incidents"])
    # the golden fallback decoded everything and conservation held
    assert all(q["tokens"] == 4 for q in r["requests"])
    assert_conserved(r)
    # identical to an honest per-token run: the guard fired before
    # anything executed, so there is no double charge
    reqs2 = make_requests([SPEC_A], 4, seed=0, tokens=4)
    honest = PoolScheduler(64 * MB, policy="fifo", fused=False).run(reqs2)
    assert r["requests"] == honest["requests"]


def test_fused_diverged_structural_check():
    segs = [list(range(3)), list(range(5))]   # only len() matters
    mega = list(range(8))
    good = np.array([3, 8], dtype=np.int64)
    assert not PoolScheduler._fused_diverged(segs, mega, good)
    assert PoolScheduler._fused_diverged(segs, mega,
                                         np.array([3], dtype=np.int64))
    assert PoolScheduler._fused_diverged(segs, mega,
                                         np.array([4, 8], dtype=np.int64))
    assert PoolScheduler._fused_diverged(segs, mega,
                                         np.array([3, 7], dtype=np.int64))


# --------------------------------------------- cross-tier byte-identity

def test_chaos_run_identical_across_engine_tiers():
    runs = [chaos_run("fifo", fused=True),
            chaos_run("fifo", fused=False),
            chaos_run("fifo", fused=False, scalar=True)]
    rows = [r["requests"] + r["failed_requests"] for r in runs]
    assert rows[0] == rows[1] == rows[2]
    assert runs[0]["makespan_s"] == runs[1]["makespan_s"] \
        == runs[2]["makespan_s"]
    assert runs[0]["mgr"]["wall_s"] == runs[1]["mgr"]["wall_s"] \
        == runs[2]["mgr"]["wall_s"]


# ------------------------------------------------- acceptance: 64 reqs

def test_acceptance_64_request_chaos_schedule():
    def go():
        reqs = make_requests(GATE_SPECS, 64, seed=0, tokens=8,
                             mean_interarrival_s=2e-3)
        plan = FaultPlan.default(0, n_requests=64, tokens=8)
        sched = PoolScheduler(GATE_CAP, policy="svm_aware",
                              fault_plan=plan, thrash_watermark=3.0,
                              thrash_window=32)
        return sched.run(reqs)
    r = go()
    # completes with zero unhandled faults: plan fully applied, nothing
    # left armed, no retry budget blown
    assert r["chaos"]["injector"]["events_remaining"] == 0
    assert r["chaos"]["retry_exhausted"] == 0
    assert r["n_requests"] + r["n_failed"] == 64
    assert r["n_failed"] == 0
    assert all(q["tokens"] == 8 for q in r["requests"])
    assert r["chaos"]["crashes"] == 1 and r["chaos"]["resumes"] >= 1
    assert_conserved(r)
    # bit-identical rerun under the same seed
    r2 = go()
    assert r["requests"] == r2["requests"]
    assert r["incidents"] == r2["incidents"]
    assert r["makespan_s"] == r2["makespan_s"]
    assert r["chaos"] == r2["chaos"]
