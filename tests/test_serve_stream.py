"""Serving-launcher runtime pieces: `WeightStream` mode selection (was
CLI-only) and the `decode_tokens` context-threading regression (the
`(A and B) or C` operator-precedence bug)."""

import numpy as np
import pytest

from repro.launch.serve import WeightStream, decode_tokens, schedule_report


def _params(sizes: dict[str, int]):
    rng = np.random.default_rng(0)
    return {name: rng.standard_normal(n // 4).astype(np.float32)
            for name, n in sizes.items()}


# --------------------------------------------------------- WeightStream

def test_zero_copy_packing_respects_half_total_cap():
    """Cold-leaf (largest-first) zero-copy packing must stay under half
    the total weight bytes, skipping leaves that would overflow the cap
    in favour of smaller ones that still fit."""
    sizes = {"big": 400 * 1024, "mid": 300 * 1024, "small": 200 * 1024,
             "tiny": 60 * 1024}
    ws = WeightStream(_params(sizes), 2, budget_frac=0.5, policy="lrf",
                      mode="zero_copy")
    zc = ws.executor._zc_leaves
    total = sum(sizes.values())
    assert sum(sizes[p] for p in zc) <= total // 2
    # greedy largest-first: 'big' fits (400k <= 480k); 'mid' would
    # overflow (700k) and is skipped; 'tiny' still fits after 'big'
    assert zc == {"big", "tiny"}
    # zero-copy leaves never migrate: their accesses are remote
    ws.step()
    assert ws.executor.mgr.n_zerocopy > 0


def test_svm_aware_skips_pinning_when_hot_leaf_dominates():
    """The pinned-full-pool deadlock guard: a hot leaf above half the
    budget is streamed, not pinned (prefetch still engages)."""
    sizes = {"embed": 400 * 1024, "l0": 40 * 1024, "l1": 40 * 1024}
    ws = WeightStream(_params(sizes), 2, budget_frac=0.5, policy="lrf",
                      mode="svm_aware")
    assert ws.executor.prefetch
    assert not ws.executor.mgr.pinned


def test_svm_aware_pins_hot_leaf_when_it_fits():
    sizes = {"embed": 100 * 1024, "l0": 60 * 1024, "l1": 60 * 1024,
             "l2": 60 * 1024}
    ws = WeightStream(_params(sizes), 2, budget_frac=0.8, policy="lrf",
                      mode="svm_aware")
    ex = ws.executor
    assert ex.prefetch
    assert set(ex.plan.leaf_ranges["embed"]) == ex.mgr.pinned


def test_report_fields_consistent_with_executor_metrics():
    sizes = {f"l{i}": 64 * 1024 for i in range(8)}
    ws = WeightStream(_params(sizes), 2, budget_frac=0.4, policy="lrf",
                      mode="naive")
    for _ in range(5):
        ws.step()
    m = ws.executor.metrics()
    rep = ws.report(5)
    assert f"{m['migrations']} migs / {m['evictions']} evicts" in rep
    assert f"e2m {m['evict_to_mig']:.2f}" in rep
    assert f"DOS {m['dos']:.0f}%" in rep
    assert f"{m['wall_s'] * 1e3:.2f}ms" in rep
    assert (f"{m['segment_cache_misses']} compiled / "
            f"{m['segment_cache_hits']} cached replays") in rep
    assert "5 tokens" in rep


# ------------------------------------------- decode_tokens context threading

class _Cfg:
    def __init__(self, *, vlm=False, encdec=False):
        self.is_vlm = vlm
        self.is_encdec = encdec


class _Step:
    """Records the context argument of every decode call."""

    def __init__(self):
        self.calls = []

    def __call__(self, params, tok, cache, ctx=_Cfg):   # sentinel default
        self.calls.append(ctx)
        return tok + 1, cache


def test_decoder_only_takes_two_arg_path():
    step = _Step()
    outs, cache = decode_tokens(_Cfg(), step, {}, 0, "kv", None, 3)
    assert outs == [1, 2, 3] and cache == "kv"
    assert step.calls == [_Cfg, _Cfg, _Cfg]      # ctx never passed


def test_vlm_threads_image_context_without_encoding(monkeypatch):
    import repro.models

    def boom(*a):  # pragma: no cover — must not run for VLMs
        raise AssertionError("encode() must not run for VLM decode")

    monkeypatch.setattr(repro.models, "encode", boom)
    step = _Step()
    decode_tokens(_Cfg(vlm=True), step, {}, 0, "kv", "img", 2)
    assert step.calls == ["img", "img"]


def test_encdec_reencodes_context_each_step(monkeypatch):
    import repro.models

    monkeypatch.setattr(repro.models, "encode",
                        lambda params, cfg, ctx: ("enc", ctx))
    step = _Step()
    decode_tokens(_Cfg(encdec=True), step, {}, 0, "kv", "frames", 2)
    assert step.calls == [("enc", "frames"), ("enc", "frames")]


def test_vlm_without_context_takes_plain_path_regression():
    """The old `ctx is not None and cfg.is_encdec or cfg.is_vlm` parsed
    as `(A and B) or C`: a VLM config with no context entered the
    context branch and passed ctx=None explicitly.  The intended
    `A and (B or C)` must take the plain two-arg path."""
    step = _Step()
    decode_tokens(_Cfg(vlm=True), step, {}, 0, "kv", None, 2)
    assert step.calls == [_Cfg, _Cfg]


def test_schedule_report_mentions_key_fields():
    from repro.core import MB
    from repro.svm import ModelSpec, run_schedule

    spec = ModelSpec.synthetic("a", 4, MB, embed_bytes=MB)
    r = run_schedule([spec], 3, 2 * spec.total_bytes, policy="fifo",
                     seed=0, tokens=4)
    rep = schedule_report(r)
    assert "svm sched[fifo]" in rep
    assert f"{r['migrations']} migs / {r['evictions']} evicts" in rep
    assert f"{r['segment_shared_hits']} cross-request replays" in rep
