"""Token data pipeline: deterministic synthetic streams for reproducible
benchmarking, memmap-backed corpora for real runs, host-sharded batch
iteration, and precomputed modality-frontend stubs for VLM/audio archs."""

from __future__ import annotations

import dataclasses
import zlib
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic Zipf-ish token stream — every (host, step) batch is
    reproducible from the seed alone, so restarts resume bit-identically."""

    vocab: int
    seed: int = 0

    def batch(self, step: int, host: int, batch: int, seq: int
              ) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host]))
        # zipf-like skew over the vocab, clipped
        raw = rng.zipf(1.3, size=(batch, seq + 1))
        tokens = (raw % self.vocab).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class MemmapTokens:
    """Flat binary token corpus (np.memmap, int32), packed into fixed-length
    sequences with block-shuffled epochs; host-sharded by stride."""

    def __init__(self, path: str, seq: int, *, host: int = 0,
                 num_hosts: int = 1, seed: int = 0):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq
        self.host = host
        self.num_hosts = num_hosts
        self.seed = seed
        self.n_seqs = (len(self.data) - 1) // seq

    def epoch(self, epoch: int) -> Iterator[dict[str, np.ndarray]]:
        order = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch])
        ).permutation(self.n_seqs)
        for idx in order[self.host::self.num_hosts]:
            lo = idx * self.seq
            chunk = np.asarray(self.data[lo: lo + self.seq + 1])
            yield {"tokens": chunk[:-1].astype(np.int32)[None],
                   "labels": chunk[1:].astype(np.int32)[None]}


def batch_iterator(source: SyntheticLM, batch: int, seq: int, *,
                   host: int = 0, start_step: int = 0
                   ) -> Iterator[dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield source.batch(step, host, batch, seq)
        step += 1


def modality_stub(kind: str, batch: int, tokens: int, d_model: int,
                  seed: int = 0) -> np.ndarray:
    """Precomputed patch/frame embeddings standing in for the (stubbed)
    vision/speech frontend (assignment: backbone only)."""
    # crc32, not hash(): str hashes are salted per process (PYTHONHASHSEED)
    # and would give each run a different stream for the same kind
    rng = np.random.default_rng(np.random.SeedSequence(
        [seed, zlib.crc32(kind.encode()) % (2 ** 31)]))
    return rng.standard_normal((batch, tokens, d_model)).astype(np.float32)
