"""Discrete-event simulation of workloads against the SVM driver model.

A *workload* builds its managed allocations in an AddressSpace and yields a
lazy trace of ops; the simulator applies them to an SVMManager and collects
the paper's metrics (wall time, throughput, migration/eviction profiles,
fault densities, cost breakdown).

Op vocabulary (tuples, for speed):
  ("touch", rid, concurrency, page_hint)  — kernel accesses range rid
  ("compute", seconds)                    — pure device compute
  ("writeback", rid)                      — algorithmic device→host copy
  ("pin", rid) / ("unpin", rid)           — app-directed placement (§4.1)
  ("spill", need_bytes, overlap)          — eager-spill until free >= need
  ("kernel", name)                        — kernel-boundary marker
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

from repro.core.costmodel import CostParams, MI250X
from repro.core.ranges import DEFAULT_BASE, GB, AddressSpace
from repro.core.svm import SVMManager

Op = tuple


@dataclasses.dataclass
class RunResult:
    workload: str
    dos: float
    wall_s: float
    work_units: float
    throughput: float          # work_units / wall_s
    summary: dict
    manager: SVMManager

    def row(self) -> dict:
        r = {"workload": self.workload, "dos": round(self.dos, 1),
             "throughput": self.throughput}
        r.update({k: v for k, v in self.summary.items()
                  if k != "cost_breakdown"})
        return r


class Workload:
    """Base class: subclasses define allocations + access trace + work."""

    name = "workload"
    concurrency = 32          # in-flight page requests => fault density
    kernel_markers = True

    def __init__(self, total_bytes: int):
        self.total_bytes = int(total_bytes)

    def build(self, space: AddressSpace) -> None:
        raise NotImplementedError

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        raise NotImplementedError

    def work_units(self) -> float:
        """Useful work (bytes or flops) for throughput normalisation."""
        return float(self.total_bytes)


def simulate(
    workload: Workload,
    capacity_bytes: int = 64 * GB,
    *,
    base: int = DEFAULT_BASE,
    params: CostParams = MI250X,
    policy: str = "lrf",
    profile: bool = True,
    max_ops: int | None = None,
    manager_cls=SVMManager,
    zero_copy_alloc_names: tuple | str = (),
    engine: str = "batched",
    trace_cache=None,
    trace_key=None,
    measured_pin: float = 0.0,
    **mgr_kwargs,
) -> RunResult:
    """Simulate one workload run.

    ``engine="batched"`` lowers the trace through the compiled-trace engine
    (`repro.core.engine`) — bit-identical to the scalar path, typically an
    order of magnitude faster.  Table-2 workloads lower through the
    columnar tier (`Workload.emit_columns`); with ``trace_cache`` (a
    `repro.core.engine.TraceCache`) and ``trace_key`` set, the compiled
    trace is shared across runs with the same workload spec + space
    geometry (see `repro.core.sweep.trace_key`).  The engine dispatches on
    the manager type (`SVMManager` and `UVMManager` each have a batched
    interpreter; any other manager replays op-for-op); every §4.2 driver
    variant runs on the fast tier.  ``engine="scalar"`` forces the per-op
    `apply_trace` loop.

    ``zero_copy_alloc_names`` may be the sentinel ``"biggest"``: it
    resolves to the workload's largest allocation of the *same build* used
    for simulation.

    ``measured_pin`` enables measured prefetching (docs/prefetching.md):
    the workload's own compiled touch columns are profiled
    (`repro.svm.hotset.HotSetProfile`) and the measured hot set — ranges
    whose mean reuse interval fits the pool, highest touch frequency
    first, byte-bounded to ``measured_pin`` of capacity — is pinned
    up-front before the trace runs.  The profile is a pure function of
    the trace, so batched and scalar engines pin the identical set."""
    if engine not in ("batched", "scalar"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "available: 'batched', 'scalar'")
    space = AddressSpace(capacity_bytes, base=base)
    workload.build(space)
    if zero_copy_alloc_names == "biggest":
        zero_copy_alloc_names = (
            max(space.allocations, key=lambda a: a.size).name,)
    elif isinstance(zero_copy_alloc_names, str):
        # a bare name would silently substring-match via `in` below
        raise ValueError("zero_copy_alloc_names must be a tuple of "
                         "allocation names or the sentinel 'biggest'; got "
                         f"{zero_copy_alloc_names!r}")
    mgr = manager_cls(space, policy=policy, params=params, profile=profile,
                      **mgr_kwargs)
    for a in space.allocations:
        if a.name in zero_copy_alloc_names:
            mgr.set_zero_copy(a.alloc_id)
    ct = None
    if engine == "batched" or measured_pin > 0.0:
        from repro.core.engine import compile_workload
        ct = compile_workload(workload, space, max_ops=max_ops,
                              cache=trace_cache, key=trace_key)
    if measured_pin > 0.0:
        # measured prefetch: profile the workload's own compiled touch
        # columns and pin the measured hot set before the trace runs.
        # Lazy import — repro.svm.hotset only reads frozen op columns.
        # This is repro.core, where driving mgr.pin directly is the
        # sanctioned scalar-reference idiom (cf. `apply_trace`); the
        # profile is a pure function of the trace, so the scalar engine
        # pins the identical set the batched engine does.
        import numpy as np

        from repro.svm.hotset import HotSetProfile

        size_arr = np.asarray([r.end - r.start for r in space.ranges],
                              dtype=np.int64)
        prof = HotSetProfile.from_trace(ct, size_arr)
        budget = float(measured_pin) * mgr.capacity
        for rid in prof.select_hot_rids(mgr.capacity, budget):
            mgr.pin(int(rid))
    if engine == "batched":
        from repro.core.engine import execute_compiled
        execute_compiled(ct, mgr)
    else:
        apply_trace(mgr, workload.trace(space), max_ops=max_ops)
    flush = getattr(mgr, "flush", None)
    if flush is not None:            # end-of-trace driver sync (UVM)
        flush()
    wall = max(mgr.wall, 1e-12)
    return RunResult(
        workload=workload.name,
        dos=space.dos(),
        wall_s=mgr.wall,
        work_units=workload.work_units(),
        throughput=workload.work_units() / wall,
        summary=mgr.summary(),
        manager=mgr,
    )


def apply_trace(mgr: SVMManager, trace: Iterable[Op],
                max_ops: int | None = None) -> None:
    """Drive a manager through a trace one op at a time — the scalar
    reference loop every batched tier is byte-identical to."""
    n = 0
    for op in trace:
        tag = op[0]
        if tag == "touch":
            _, rid, conc, hint = op
            mgr.touch(rid, concurrency=conc, page_hint=hint)
        elif tag == "compute":
            mgr.advance(op[1])
        elif tag == "writeback":
            mgr.writeback(op[1])
        elif tag == "pin":
            mgr.pin(op[1])
        elif tag == "unpin":
            mgr.unpin(op[1])
        elif tag == "spill":
            while mgr.free < op[1] and \
                    mgr.spill_oldest(overlap=op[2]) is not None:
                pass
        elif tag == "kernel":
            pass
        else:
            raise ValueError(f"unknown trace op {tag!r}")
        n += 1
        if max_ops is not None and n >= max_ops:
            break


def dos_sweep(
    make_workload,
    dos_values: Iterable[float],
    capacity_bytes: int = 64 * GB,
    *,
    normalize_at: float = 78.0,
    policy: str = "lrf",
    params: CostParams = MI250X,
    engine: str = "batched",
    manager: str = "svm",
    jobs: int = 0,
    cache_dir: str | None = None,
    **mgr_kwargs,
) -> list[dict]:
    """Run a workload at several problem sizes (expressed as target DOS %)
    and report throughput normalised to the `normalize_at` point
    (paper Fig. 6).

    ``make_workload`` is either a callable ``bytes -> Workload`` (run
    serially in-process) or a picklable spec tuple ``(name, kwargs)``
    resolved via `repro.core.traces.make_workload`, which additionally
    allows fanning the DOS points out across ``jobs`` worker processes
    with an optional content-keyed on-disk ``cache_dir``
    (see `repro.core.sweep`).  When ``normalize_at`` is not one of
    ``dos_values``, the anchor point rides in the same `run_sweep` batch
    as the main rows — same cache, worker fan-out, and engine selection."""
    dos_values = list(dos_values)
    anchor_idx = next((i for i, d in enumerate(dos_values)
                       if abs(d - normalize_at) < 1e-9), None)
    if not callable(make_workload):
        from repro.core.sweep import SweepPoint, run_sweep
        name, wl_kwargs = make_workload

        def point(dos):
            return SweepPoint.make(name, capacity_bytes * dos / 100.0,
                                   capacity_bytes, policy=policy,
                                   wl_kwargs=dict(wl_kwargs),
                                   mgr_kwargs=mgr_kwargs, engine=engine,
                                   manager=manager)

        points = [point(dos) for dos in dos_values]
        if anchor_idx is None:
            points.append(point(normalize_at))
        all_rows = run_sweep(points, jobs=jobs, params=params,
                             cache_dir=cache_dir)
        rows = all_rows[:len(dos_values)]
        base_thr = (rows[anchor_idx] if anchor_idx is not None
                    else all_rows[-1])["throughput"]
    else:
        from repro.core.sweep import MANAGERS
        manager_cls = MANAGERS[manager]
        rows = []
        for dos in dos_values:
            wl = make_workload(int(capacity_bytes * dos / 100.0))
            res = simulate(wl, capacity_bytes, policy=policy, params=params,
                           profile=False, engine=engine,
                           manager_cls=manager_cls, **mgr_kwargs)
            rows.append(res.row())
        if anchor_idx is not None:
            base_thr = rows[anchor_idx]["throughput"]
        else:
            wl = make_workload(int(capacity_bytes * normalize_at / 100.0))
            base_thr = simulate(wl, capacity_bytes, policy=policy,
                                params=params, profile=False, engine=engine,
                                manager_cls=manager_cls,
                                **mgr_kwargs).throughput
    for row in rows:
        row["norm_perf"] = row["throughput"] / base_thr
    return rows
