"""Multi-tenant serving over one shared SVM pool: 8 concurrent decode
requests of two (reduced) architectures contend for a device pool that
holds barely more than one model, under each scheduling policy.

  * fifo       — admit everything, round-robin: the paper's thrashing
                 pathology multiplied by N tenants.
  * admission  — cap admitted working-set bytes at the pool watermark;
                 later arrivals queue.
  * svm_aware  — admission + per-request hot-leaf pinning + same-arch
                 token batching (shared compiled-segment replays).

Same-architecture requests replay one shared compiled per-token segment
(relocated to each tenant's range offsets) — the `shared` column counts
those cross-request replays.

    PYTHONPATH=src python examples/serve_multitenant.py

``--scale`` swaps the 8-request tour for the fused-round tier at serving
scale: 256 requests through one pool (whole scheduler rounds concatenate
into a single batched engine pass), timed against the per-token reference
replay, plus the oscillating hot-set adversary from `repro.core.traces`
driven through the sweep tier at the same pool capacity.

    PYTHONPATH=src python examples/serve_multitenant.py --scale
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import get_reduced
from repro.models import init_params
from repro.svm import ModelSpec, PoolScheduler, make_requests


def tiny(arch: str, n_layers: int, d_model: int, d_ff: int):
    cfg = dataclasses.replace(get_reduced(arch), n_layers=n_layers,
                              d_model=d_model, d_ff=d_ff)
    return init_params(cfg, jax.random.PRNGKey(0))


def scale() -> None:
    """256-request fused-round demo + oscillating hot-set sweep row."""
    from repro.core.sweep import hotset_grid, run_point

    specs = [
        ModelSpec.from_params("gemma3-1b", tiny("gemma3-1b", 6, 128, 512),
                              batch=4),
        ModelSpec.from_params("granite-3-2b",
                              tiny("granite-3-2b", 8, 192, 768), batch=4),
    ]
    # a pool that admits a few dozen tenants at once: fused rounds win by
    # batching many per-token segments into one engine pass, so the demo
    # needs real concurrency (the bench's ≥512-request config shows ≥3x;
    # this stays CI-smoke-fast).  Burst arrival keeps rounds maximal —
    # pending arrivals would split svm_aware rounds into unit blocks
    # (correct, but nothing left to fuse)
    cap = int(max(s.total_bytes for s in specs) * 16)
    reqs = make_requests(specs, 256, seed=11, tokens=12, token_jitter=3,
                         arrival="burst", spec_choice="roundrobin")
    print(f"fused round tier: 256 requests, pool {cap / 1e6:.1f}MB")
    rows = {}
    for fused in (True, False):
        sched = PoolScheduler(cap, policy="svm_aware", pin_frac=0.4,
                              fused=fused)
        t0 = time.perf_counter()
        r = sched.run([dataclasses.replace(q) for q in reqs])
        rows[fused] = (r, time.perf_counter() - t0)
    r, dt = rows[True]
    _, dt_ref = rows[False]
    same = all(rows[True][0][k] == rows[False][0][k]
               for k in ("latency_p99_s", "migrations", "evictions",
                         "evict_to_mig", "agg_tok_s"))
    sc = r["shared_cache"]
    print(f"  fused {dt * 1e3:7.1f}ms vs per-token {dt_ref * 1e3:7.1f}ms "
          f"({dt_ref / dt:.2f}x), byte-identical: {same}")
    print(f"  p50/p99 {r['latency_p50_s'] * 1e3:.1f}/"
          f"{r['latency_p99_s'] * 1e3:.1f}ms, agg {r['agg_tok_s']:.0f} "
          f"tok/s, {sc['shared_concats']} round concats, "
          f"{sc['shared_relocations']} relocations\n")

    # the phase-change adversary at the same capacity: each phase flips
    # the hot set between the two halves of the allocation, so residency
    # built in one phase is dead weight in the next
    pt = hotset_grid(int(cap * 2), [cap], modes=("oscillating",),
                     ops=20_000, seed=11)[0]
    row = run_point(pt)
    print(f"oscillating hot-set ({row['workload']}, DOS "
          f"{row['dos']:.0f}%): {row['migrations']} migs / "
          f"{row['evictions']} evicts, e2m {row['evict_to_mig']:.2f}, "
          f"wall {row['wall_s'] * 1e3:.1f}ms")


def main() -> None:
    specs = [
        ModelSpec.from_params("gemma3-1b", tiny("gemma3-1b", 6, 128, 512),
                              batch=4),
        ModelSpec.from_params("granite-3-2b",
                              tiny("granite-3-2b", 8, 192, 768), batch=4),
    ]
    # pool: slightly smaller than the larger model — the big arch is
    # individually oversubscribed (svm_aware's pinning regime), small-arch
    # pairs fit, and the full 8-request mix offers ~450 % DOS
    cap = int(max(s.total_bytes for s in specs) * 0.9)
    offered = sum(specs[i % 2].total_bytes for i in range(8))
    print(f"pool {cap / 1e6:.1f}MB; 8 requests "
          f"({specs[0].total_bytes / 1e6:.1f}MB gemma-ish / "
          f"{specs[1].total_bytes / 1e6:.1f}MB granite-ish), "
          f"offered DOS {offered / cap * 100:.0f}%\n")

    print(f"  {'policy':10s} {'p50':>8s} {'p99':>8s} {'tok/s':>7s} "
          f"{'ev/tok':>7s} {'e2m':>5s} {'hit%':>5s} {'shared':>6s}")
    rows = []
    for policy in ("fifo", "admission", "svm_aware"):
        sched = PoolScheduler(cap, policy=policy, pin_frac=0.4)
        reqs = make_requests(specs, 8, seed=3, mean_interarrival_s=0.01,
                             tokens=16, spec_choice="roundrobin")
        r = sched.run(reqs)
        rows.append(r)
        print(f"  {policy:10s} {r['latency_p50_s'] * 1e3:7.1f}ms "
              f"{r['latency_p99_s'] * 1e3:7.1f}ms {r['agg_tok_s']:7.0f} "
              f"{r['evictions_per_token']:7.2f} {r['evict_to_mig']:5.2f} "
              f"{r['segment_hit_rate'] * 100:5.1f} "
              f"{r['segment_shared_hits']:6d}")

    fifo, aware = rows[0], rows[-1]
    print(f"\nsvm_aware vs fifo: "
          f"{fifo['evictions_per_token'] / aware['evictions_per_token']:.2f}x "
          f"fewer evictions/token, "
          f"{fifo['latency_p99_s'] / aware['latency_p99_s']:.2f}x lower "
          f"p99 latency (admission keeps the pool below the thrashing "
          f"cliff; pinning + shared segment replays do the rest)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", action="store_true",
                    help="256-request fused-round tier + oscillating "
                         "hot-set adversary (CI-smoke-fast)")
    if ap.parse_args().scale:
        scale()
    else:
        main()
