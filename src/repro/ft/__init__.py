from repro.ft.retry import (
    DEFAULT_RETRY,
    RetryBudget,
    RetryError,
    RetryPolicy,
    retry_call,
)
from repro.ft.supervisor import (
    ElasticPlan,
    StragglerMonitor,
    TrainSupervisor,
    plan_elastic_remesh,
)

__all__ = ["TrainSupervisor", "StragglerMonitor", "plan_elastic_remesh",
           "ElasticPlan", "RetryPolicy", "RetryBudget", "RetryError",
           "retry_call", "DEFAULT_RETRY"]
