"""Range construction — the SVM management unit (paper §2.1).

SVM manages unified memory in *ranges*: contiguous virtual spans produced by
splitting each managed allocation at device-alignment boundaries.

    alignment = pow2_floor(svm_capacity / 32), clamped to >= 2 MB
    (a 48 GB-class device => 1 GB alignment)

Ranges are additionally clipped to allocation boundaries, so an allocation
that crosses alignment boundaries comprises multiple ranges (paper Fig. 2:
three 1.5 GB allocations at a 175 MB base offset => 7 ranges, smallest
175 MB, largest 1 GB).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

KB = 1024
MB = 1024 * KB
GB = 1024 * MB

MIN_ALIGNMENT = 2 * MB
PAGE = 4 * KB  # host/device page size (faults are page-granular)
# managed allocations are placed after the platform's prior runtime
# reservations (paper Fig. 2) — the single source for every default base
DEFAULT_BASE = 175 * MB


def pow2_floor(x: int) -> int:
    """Largest power of two <= x (x >= 1)."""
    if x < 1:
        raise ValueError(f"pow2_floor requires x >= 1, got {x}")
    return 1 << (x.bit_length() - 1)


def svm_alignment(capacity_bytes: int) -> int:
    """Device alignment from SVM-managed capacity (paper §2.1)."""
    return max(MIN_ALIGNMENT, pow2_floor(capacity_bytes // 32))


@dataclasses.dataclass(frozen=True)
class Range:
    """A contiguous span of virtual pages — SVM's unit of migration/eviction."""

    rid: int
    alloc_id: int
    start: int  # virtual byte address, inclusive
    end: int    # virtual byte address, exclusive

    @property
    def size(self) -> int:
        return self.end - self.start

    @property
    def num_pages(self) -> int:
        return -(-self.size // PAGE)

    def contains(self, addr: int) -> bool:
        return self.start <= addr < self.end

    def __repr__(self) -> str:  # compact for profiles
        return f"R{self.rid}[a{self.alloc_id}:{self.start:#x}+{self.size >> 20}MB]"


@dataclasses.dataclass(frozen=True)
class Allocation:
    """One managed-memory allocation (hipMallocManaged analogue)."""

    alloc_id: int
    name: str
    start: int
    size: int

    @property
    def end(self) -> int:
        return self.start + self.size


def split_allocation(
    alloc: Allocation, alignment: int, first_rid: int
) -> list[Range]:
    """Split an allocation into ranges at alignment boundaries (paper §2.1).

    Every alignment boundary strictly inside the allocation starts a new
    range; range edges are clipped to the allocation's own boundaries.
    """
    cuts = [alloc.start]
    # first alignment boundary strictly greater than alloc.start
    b = (alloc.start // alignment + 1) * alignment
    while b < alloc.end:
        cuts.append(b)
        b += alignment
    cuts.append(alloc.end)
    return [
        Range(rid=first_rid + i, alloc_id=alloc.alloc_id, start=s, end=e)
        for i, (s, e) in enumerate(zip(cuts[:-1], cuts[1:]))
    ]


class AddressSpace:
    """The unified virtual address space: allocations and their ranges.

    Allocations are placed contiguously from ``base`` (the paper's platform
    places managed allocations after prior runtime reservations, which is why
    Fig. 2 shows non-aligned range edges — a 175 MB base reproduces it).
    """

    def __init__(self, capacity_bytes: int, base: int = 0,
                 alignment: int | None = None):
        self.capacity = capacity_bytes
        self.alignment = alignment or svm_alignment(capacity_bytes)
        self.base = base
        self._cursor = base
        self.allocations: list[Allocation] = []
        self.ranges: list[Range] = []
        self._ranges_by_alloc: dict[int, list[Range]] = {}
        self._size_arr: np.ndarray | None = None

    def size_array(self) -> np.ndarray:
        """Per-rid range sizes as int64 (cached; rids index `ranges`)."""
        arr = self._size_arr
        if arr is None or len(arr) != len(self.ranges):
            arr = np.array([r.size for r in self.ranges], dtype=np.int64)
            self._size_arr = arr
        return arr

    def pad_to_alignment(self) -> int:
        """Advance the allocation cursor to the next alignment boundary.

        The padded gap is unmanaged (no allocation, no ranges) — it models
        per-tenant placement padding in a shared pool: plans started on an
        alignment boundary have identical range geometry regardless of
        what was allocated before them, which is what makes compiled
        segments relocatable between same-architecture tenants.  Returns
        the number of padding bytes skipped."""
        pad = -self._cursor % self.alignment
        self._cursor += pad
        return pad

    def alloc(self, size: int, name: str = "") -> Allocation:
        a = Allocation(
            alloc_id=len(self.allocations),
            name=name or f"alloc{len(self.allocations)}",
            start=self._cursor,
            size=size,
        )
        self._cursor += size
        self.allocations.append(a)
        rs = split_allocation(a, self.alignment, first_rid=len(self.ranges))
        self.ranges.extend(rs)
        self._ranges_by_alloc[a.alloc_id] = rs
        return a

    def ranges_of(self, alloc: Allocation | int) -> list[Range]:
        aid = alloc if isinstance(alloc, int) else alloc.alloc_id
        return self._ranges_by_alloc[aid]

    def range_at(self, addr: int) -> Range:
        """Range containing a virtual address (binary search)."""
        lo, hi = 0, len(self.ranges) - 1
        while lo <= hi:
            mid = (lo + hi) // 2
            r = self.ranges[mid]
            if addr < r.start:
                hi = mid - 1
            elif addr >= r.end:
                lo = mid + 1
            else:
                return r
        raise KeyError(f"address {addr:#x} not in any managed range")

    def ranges_overlapping(self, start: int, end: int) -> Iterator[Range]:
        """All ranges intersecting [start, end)."""
        if end <= start:
            return
        r = self.range_at(start)
        idx = r.rid
        while idx < len(self.ranges) and self.ranges[idx].start < end:
            yield self.ranges[idx]
            idx += 1

    @property
    def total_managed(self) -> int:
        return self._cursor - self.base

    def dos(self) -> float:
        """Degree of oversubscription (%): used / available * 100 (paper §3.1)."""
        return self.total_managed / self.capacity * 100.0
