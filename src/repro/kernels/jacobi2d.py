"""Jacobi 5-point stencil Pallas kernel (the paper's Category-II workload).

Grid over row-blocks; each step binds THREE views of the input (the block
above, the block itself, the block below) via separate BlockSpecs — the
Pallas TPU idiom for halo exchange without overlapping block shapes. Rows
are updated on the VPU; global boundary rows/cols pass through unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_H = 256


def _jacobi_kernel(up_ref, mid_ref, dn_ref, out_ref, *, bh: int,
                   nrows: int, ncols: int):
    i = pl.program_id(0)
    mid = mid_ref[...].astype(jnp.float32)        # (bh, C)
    up = up_ref[...].astype(jnp.float32)          # (bh, C) block above
    dn = dn_ref[...].astype(jnp.float32)          # (bh, C) block below

    # row i-1 / i+1 within this block, with halo rows from neighbours
    above = jnp.concatenate([up[-1:], mid[:-1]], axis=0)
    below = jnp.concatenate([mid[1:], dn[:1]], axis=0)
    left = jnp.concatenate([mid[:, :1], mid[:, :-1]], axis=1)
    right = jnp.concatenate([mid[:, 1:], mid[:, -1:]], axis=1)
    res = 0.2 * (mid + above + below + left + right)

    # masks: global boundary rows/cols keep their input values
    gr = i * bh + jax.lax.broadcasted_iota(jnp.int32, (bh, ncols), 0)
    gc = jax.lax.broadcasted_iota(jnp.int32, (bh, ncols), 1)
    interior = ((gr > 0) & (gr < nrows - 1) & (gc > 0) & (gc < ncols - 1))
    out_ref[...] = jnp.where(interior, res, mid).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def jacobi2d_pallas(a: jax.Array, interpret: bool = False) -> jax.Array:
    R, C = a.shape
    bh = min(BLOCK_H, R)
    while R % bh:      # blocks must tile the rows exactly (halo correctness)
        bh -= 1
    nb = R // bh
    kernel = functools.partial(_jacobi_kernel, bh=bh, nrows=R, ncols=C)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            # clamped neighbour blocks provide the halo rows
            pl.BlockSpec((bh, C), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((bh, C), lambda i: (i, 0)),
            pl.BlockSpec((bh, C), lambda i: (jnp.minimum(i + 1, nb - 1), 0)),
        ],
        out_specs=pl.BlockSpec((bh, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), a.dtype),
        interpret=interpret,
    )(a, a, a)
