"""Roofline analysis over dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

`cost_analysis()` on the SPMD-partitioned module reports *per-device*
flops/bytes, and the parsed collective bytes are per-device too, so the
terms divide by per-chip rates directly.
"""

from __future__ import annotations

import json

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (3D torus, ~per-chip usable)


def roofline_terms(row: dict, chips: int) -> dict:
    """Three roofline terms (seconds) for one dry-run row.

    compute   — exact HLO FLOPs (scan-free differenced lowering);
    memory    — fused-traffic analytic model (the HLO 'bytes accessed' is
                an unfused upper bound on the CPU stand-in backend and is
                reported separately as t_memory_hlo_upper);
    collective— per-device collective bytes parsed from the SPMD HLO.
    """
    flops_dev = row.get("hlo_flops_per_device", 0.0)
    bytes_hlo = row.get("hlo_bytes_per_device", 0.0)
    coll_dev = row.get("collectives", {}).get(
        "effective_bytes_per_device", 0.0)
    try:
        from repro.launch.analytic import (
            analytic_bytes_per_device,
            analytic_flops_global,
        )
        bytes_dev = analytic_bytes_per_device(row["arch"], row["shape"])
        flops_check = analytic_flops_global(row["arch"], row["shape"])
    except Exception:  # noqa: BLE001 — paper-workload rows have no arch
        bytes_dev = bytes_hlo
        flops_check = 0.0
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    # depth differencing can go nonmonotonic when XLA places collectives
    # differently at depth 1 vs 2 — clamp and flag instead of reporting a
    # negative term
    nonlinear = coll_dev < 0
    t_collective = max(coll_dev, 0.0) / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)),
        key=lambda kv: kv[1])[0]
    model = row.get("model_flops_global", 0.0)
    hlo_global = flops_dev * chips
    bound = max(t_compute, t_memory, t_collective)
    ideal = (model / chips) / PEAK_FLOPS if chips else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_hlo_upper_s": bytes_hlo / HBM_BW,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "collective_nonlinear_flag": nonlinear,
        "model_flops_global": model,
        "hlo_flops_global": hlo_global,
        "analytic_flops_global": flops_check,
        "useful_flops_ratio": model / hlo_global if hlo_global else 0.0,
        # fraction of the compute roofline achievable if the dominant term
        # were the only cost (upper-bounds MFU for this program)
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        # resource-aware fraction: the fundamental lower bound is the max of
        # ideal compute time and minimal memory time (weights+cache must
        # stream once) — the right score for memory-bound decode cells
        "fraction_resource": (max(ideal, t_memory) / bound) if bound else 0.0,
    }


def load_rows(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def analyze(path: str, mesh: str = "16x16") -> list[dict]:
    chips = 512 if mesh == "2x16x16" else 256
    out = []
    for row in load_rows(path):
        if row.get("mesh") != mesh:
            continue
        entry = {k: row.get(k) for k in ("arch", "shape", "mesh", "status")}
        if row.get("status") == "ok":
            entry.update(roofline_terms(row, chips))
        elif row.get("status") == "skipped":
            entry["reason"] = row.get("reason")
        else:
            entry["error"] = row.get("error")
        out.append(entry)
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    for e in analyze(args.path, args.mesh):
        print(json.dumps(e))


if __name__ == "__main__":
    main()
