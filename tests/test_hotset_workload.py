"""Synthetic hot-set workloads (`repro.core.traces.HotSet`): seeded
static / dynamic / oscillating access adversaries, generator-vs-columnar
parity, and their sweep-grid integration (`repro.core.sweep.hotset_grid`)."""

import numpy as np
import pytest

from repro.core import MB, AddressSpace
from repro.core.engine import compile_trace
from repro.core.sweep import hotset_grid, run_point
from repro.core.traces import HotSet, make_workload

TOTAL = 64 * MB


def _space():
    return AddressSpace(128 * MB, alignment=2 * MB)


COLS = ("codes", "rids", "concs", "hints", "fargs", "boundaries")


@pytest.mark.parametrize("mode", HotSet.MODES)
def test_generator_columnar_parity(mode):
    """The tier contract every Table-2 workload honours: columnar
    emission is op-for-op identical to generator lowering."""
    wl = make_workload("hotset", TOTAL, mode=mode, ops=2048, seed=5)
    space = _space()
    wl.build(space)
    ct_gen = compile_trace(wl.trace(space))
    ct_col = wl.emit_columns(space)
    for f in COLS:
        assert np.array_equal(getattr(ct_gen, f), getattr(ct_col, f)), f
    assert ct_gen.n_ops == ct_col.n_ops


def test_seeded_determinism():
    def cols(seed):
        wl = HotSet(TOTAL, mode="dynamic", ops=1024, seed=seed)
        space = _space()
        wl.build(space)
        return wl.emit_columns(space)

    assert np.array_equal(cols(3).rids, cols(3).rids)
    assert not np.array_equal(cols(3).rids, cols(4).rids)


def test_mode_validation_and_naming():
    with pytest.raises(ValueError):
        HotSet(TOTAL, mode="wobbling")
    assert HotSet(TOTAL, mode="oscillating").name == "hotset-oscillating"
    # static collapses to a single phase regardless of the phases arg
    assert HotSet(TOTAL, mode="static", phases=8).phases == 1
    assert HotSet(TOTAL, mode="dynamic", phases=8).phases == 8


def test_oscillation_thrashes_where_static_does_not():
    """Each oscillating flip moves the hot window to the other half of
    the allocation.  With all-hot traffic and a pool that holds one hot
    window but not both, the static trace warms up once and never
    evicts, while every oscillation re-fetches the flipped window over a
    full pool — the pure phase-change signal."""
    def run(mode):
        pt = hotset_grid(TOTAL, [12 * MB], modes=(mode,),
                         ops=4096, seed=0, hot_prob=1.0)[0]
        return run_point(pt)

    static, osc = run("static"), run("oscillating")
    assert static["evictions"] == 0
    assert osc["evictions"] > 20
    assert osc["migrations"] > static["migrations"]


def test_hotset_grid_shape_and_rows():
    pts = hotset_grid(TOTAL, [TOTAL // 2, TOTAL // 4],
                      policies=("lrf", "lru"), ops=512, seed=1)
    assert len(pts) == 3 * 2 * 2            # modes × caps × policies
    assert {p.policy for p in pts} == {"lrf", "lru"}
    row = run_point(pts[0])
    assert row["workload"].startswith("hotset-")
    assert row["wall_s"] > 0 and row["migrations"] > 0
