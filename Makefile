PY := PYTHONPATH=src python

.PHONY: test coverage lint bench bench-smoke bench-engine bench-gates chaos-smoke bench-scale docs-check

test:
	$(PY) -m pytest -x -q

# tier-1 under pytest-cov with the committed line-coverage floor over the
# engine packages (requires requirements-dev.txt; CI runs this form)
coverage:
	$(PY) -m pytest -x -q --cov=repro.core --cov=repro.svm \
		--cov-report=term --cov-report=xml --cov-fail-under=70

# fail on any svmlint contract finding over src/repro (docs/contracts.md)
lint:
	python tools/svmlint.py

bench:
	$(PY) benchmarks/run.py

# CI smoke target: engine microbenchmark (scalar vs compiled-trace engine,
# serial vs parallel sweep), writes BENCH_engine.json
bench-smoke:
	$(PY) benchmarks/bench_engine.py --smoke

bench-engine:
	$(PY) benchmarks/bench_engine.py

# fail if any gated BENCH_engine.json ratio is below its committed floor
bench-gates:
	$(PY) benchmarks/check_gates.py

# CI chaos gate: seeded 64-request fault schedule — zero unhandled
# faults, exact conservation, bit-identical rerun (docs/robustness.md)
chaos-smoke:
	$(PY) benchmarks/chaos_smoke.py

# CI scale gate: 1024-request vectorized schedule — window tier engaged,
# wall budget held, byte-identity vs per-token on a subsampled prefix
bench-scale:
	$(PY) benchmarks/scale_smoke.py

# fail if any docs/ internal link or README anchor is broken
docs-check:
	python tools/check_docs_links.py
