"""UVM-mode baseline manager + cross-policy behaviour (Table 1 machinery)."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    GB,
    MB,
    AddressSpace,
    UVMManager,
    VABLOCK,
    simulate,
)
from repro.core.traces import Gesummv, Jacobi2d, Stream


def _space(cap=1 * GB, allocs=3, size=512 * MB):
    s = AddressSpace(cap, base=0)
    for i in range(allocs):
        s.alloc(size, f"m{i}")
    return s


def test_uvm_vablock_granularity():
    s = _space(cap=2 * GB, allocs=1, size=64 * MB)
    m = UVMManager(s)
    m.touch(0)   # range 0 covers the whole 64MB alloc (alignment 64MB)
    m.flush()    # faults buffer across ops until a driver sync point
    assert m.bytes_migrated == 64 * MB
    assert m.n_migrations >= 1
    # second touch: all VABlocks resident -> no new faults
    before = m.faults_serviceable
    assert m.touch(0) is True
    assert m.faults_serviceable == before


def test_uvm_prefetch_coalesces_contiguous_blocks():
    s = _space(cap=2 * GB, allocs=1, size=64 * MB)
    coalesced = UVMManager(s, prefetch=True)
    coalesced.touch(0)
    coalesced.flush()
    s2 = _space(cap=2 * GB, allocs=1, size=64 * MB)
    paged = UVMManager(s2, prefetch=False)
    paged.touch(0)
    paged.flush()
    assert coalesced.n_migrations < paged.n_migrations
    assert coalesced.bytes_migrated == paged.bytes_migrated


def test_uvm_evicts_at_block_granularity():
    s = _space(cap=96 * MB, allocs=3, size=64 * MB)  # DOS 200
    m = UVMManager(s)
    for r in s.ranges:
        m.touch(r.rid)
    m.flush()
    assert m.n_evictions > 0
    # trace touches never write: capacity evictions are clean unmaps
    assert m.bytes_evicted % VABLOCK == 0
    resident_bytes = len(m.resident) * VABLOCK
    assert resident_bytes <= s.capacity


def test_uvm_beats_svm_on_dispersed_thrash():
    """The paper's design contrast: 2 MB eviction granularity avoids the
    premature whole-range evictions that kill GESUMMV under SVM."""
    cap = 8 * GB
    svm = simulate(Gesummv(int(cap * 1.09)), cap, profile=False)
    uvm = simulate(Gesummv(int(cap * 1.09), retry_override=1), cap,
                   profile=False, manager_cls=UVMManager)
    assert uvm.wall_s < svm.wall_s / 3


def test_svm_matches_uvm_on_streaming():
    """...and is competitive for linear streaming (large ranges amortise)."""
    cap = 8 * GB
    svm = simulate(Stream(int(cap * 0.78)), cap, profile=False)
    uvm = simulate(Stream(int(cap * 0.78)), cap, profile=False,
                   manager_cls=UVMManager)
    assert svm.wall_s < uvm.wall_s * 1.5


@settings(max_examples=25, deadline=None)
@given(dos=st.floats(min_value=30, max_value=200),
       policy=st.sampled_from(["lrf", "lru", "clock"]))
def test_property_policies_agree_below_capacity(dos, policy):
    """Below DOS 100 the policy is irrelevant: identical migrations, zero
    evictions (single-pass streaming)."""
    cap = 4 * GB
    res = simulate(Stream(int(cap * dos / 100)), cap, policy=policy,
                   profile=False)
    if dos < 99:
        assert res.summary["evictions"] == 0
    assert res.summary["migrations"] == \
        simulate(Stream(int(cap * dos / 100)), cap, profile=False
                 ).summary["migrations"]


def test_lru_never_worse_than_lrf_on_reuse():
    cap = 8 * GB
    for dos in (109, 140):
        lrf = simulate(Jacobi2d(int(cap * dos / 100)), cap, policy="lrf",
                       profile=False)
        lru = simulate(Jacobi2d(int(cap * dos / 100)), cap, policy="lru",
                       profile=False)
        assert lru.summary["migrations"] <= lrf.summary["migrations"] * 1.05
