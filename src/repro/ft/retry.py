"""Bounded retry with exponential backoff — the one retry primitive.

Every recovery loop in the tree (chaos-layer migration-fault recovery in
`repro.svm.scheduler`, checkpoint/restart in `repro.ft.supervisor`,
straggler strike-counting in `StragglerMonitor`) runs on this module, so
retry behaviour is bounded and deterministic by construction — the
svmlint ``bounded-retry`` rule rejects ad-hoc unbounded retry loops.

Two shapes:

  * `retry_call(fn, policy=...)` — the inverted form: the utility owns
    the loop, calls ``fn(attempt)`` up to ``policy.max_attempts`` times,
    and invokes ``on_backoff(attempt, delay_s)`` between attempts.  The
    caller decides what a backoff *costs*: the chaos scheduler charges
    the simulated clock (`SVMManager.inject_latency`), a real service
    would sleep.  Exhaustion raises `RetryError` (chained to the last
    failure).
  * `RetryBudget` — the incremental form for long-lived loops that
    cannot be inverted (the supervisor's step loop): an attempt ledger
    over the same `RetryPolicy`, spending one backoff delay per recorded
    failure and reporting exhaustion.

The backoff schedule is a pure function of the policy (no RNG, no wall
clock), so a fixed seed upstream gives bit-identical recovery timing.
"""

from __future__ import annotations

import dataclasses


class RetryError(RuntimeError):
    """Retry budget exhausted; ``last`` holds the final failure."""

    def __init__(self, attempts: int, last: BaseException | None = None):
        super().__init__(
            f"retry budget exhausted after {attempts} attempt(s)"
            + (f": {last!r}" if last is not None else ""))
        self.attempts = attempts
        self.last = last


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: attempt ``k`` (1-based) waits
    ``base_delay_s * factor**(k-1)`` seconds, capped at ``max_delay_s``,
    for at most ``max_attempts`` attempts total."""

    max_attempts: int = 4
    base_delay_s: float = 1e-3
    factor: float = 2.0
    max_delay_s: float = float("inf")

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s < 0.0 or self.factor <= 0.0:
            raise ValueError("backoff delays must be non-negative")

    def delay(self, attempt: int) -> float:
        """Backoff after failed attempt ``attempt`` (1-based)."""
        d = self.base_delay_s * self.factor ** (max(attempt, 1) - 1)
        return min(d, self.max_delay_s)

    def schedule(self) -> tuple[float, ...]:
        """The full deterministic backoff schedule: the delay charged
        after each failed attempt that still has budget left."""
        return tuple(self.delay(k) for k in range(1, self.max_attempts))


DEFAULT_RETRY = RetryPolicy()


def retry_call(fn, *, policy: RetryPolicy = DEFAULT_RETRY,
               retry_on: tuple = (Exception,), on_backoff=None):
    """Call ``fn(attempt)`` (1-based) until it returns, retrying on
    ``retry_on`` with the policy's backoff; ``on_backoff(attempt,
    delay_s)`` charges each wait to whatever clock the caller owns.
    Raises `RetryError` (from the last failure) once the budget is
    spent."""
    last: BaseException | None = None
    # the attempt budget: at most policy.max_attempts invocations
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return fn(attempt)
        except retry_on as e:
            last = e
            if attempt >= policy.max_attempts:
                raise RetryError(attempt, e) from e
            d = policy.delay(attempt)
            if on_backoff is not None:
                on_backoff(attempt, d)
    raise RetryError(policy.max_attempts, last)   # pragma: no cover


class RetryBudget:
    """Incremental attempt ledger over a `RetryPolicy`, for loops that
    cannot be inverted into `retry_call` (e.g. the supervisor's
    checkpoint/restart loop): `spend()` records one failed attempt and
    returns its backoff delay; `exhausted` reports when the budget is
    gone; `reset()` re-arms after sustained success."""

    def __init__(self, policy: RetryPolicy = DEFAULT_RETRY):
        self.policy = policy
        self.attempts = 0
        self.backoff_s = 0.0

    @property
    def remaining(self) -> int:
        return max(0, self.policy.max_attempts - self.attempts)

    @property
    def exhausted(self) -> bool:
        return self.attempts >= self.policy.max_attempts

    def spend(self) -> float:
        """Record one failed attempt; returns the backoff delay to
        charge before the next try."""
        self.attempts += 1
        d = self.policy.delay(self.attempts)
        self.backoff_s += d
        return d

    def reset(self) -> None:
        self.attempts = 0
