"""Blocked SGEMM Pallas kernel — the paper's Category-III workload,
re-expressed for the TPU memory hierarchy.

The paper's SGEMM-svm-aware fix (§4.1) pins one factor device-side and
streams row panels. The TPU-native analogue: MXU-aligned (bm, bk)x(bk, bn)
tiles with the K loop innermost in the grid, the fp32 accumulator pinned in
a VMEM scratch across the K steps (the "pinned factor"), and A/B panels
streamed HBM→VMEM per step. One output tile is written once — the product
never thrashes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 256, 256, 512


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a: jax.Array, b: jax.Array,
                  interpret: bool = False) -> jax.Array:
    """C = A @ B; A: (M, K), B: (K, N). Dims should be 128-multiples for
    MXU alignment (smaller inputs fall back to single blocks)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(BM, M), min(BN, N), min(BK, K)
    while K % bk:   # K blocks must tile exactly: padded K lanes would
        bk -= 1     # contribute unspecified values to the accumulation
    nk = K // bk
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
