"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; artifacts land in
results/bench/*.json. Additionally summarises the dry-run/roofline sweeps
when their JSONL outputs exist.

Sweep figures run through the parallel sweep runner: ``--jobs N`` fans
points across N worker processes (default: one per CPU, capped at 8) and
``--no-cache`` disables the content-keyed incremental result cache."""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_figs  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def roofline_rows():
    """Summarise the dry-run roofline sweep (if present)."""
    path = os.path.join(RESULTS, "roofline_results.jsonl")
    if not os.path.exists(path):
        return [("roofline_sweep", 0.0, "missing_run_dryrun_first")]
    from repro.launch.roofline import roofline_terms
    rows = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "ok":
                continue
            t = roofline_terms(r, 256)
            rows.append((
                f"roofline_{r['arch']}_{r['shape']}",
                r.get("wall_s", 0.0) * 1e6,
                f"dom={t['dominant']}_frac={t['roofline_fraction']:.3f}",
            ))
    return rows or [("roofline_sweep", 0.0, "no_ok_rows")]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=None,
                    help="sweep worker processes (default: CPUs, max 8; "
                         "1 = serial)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the content-keyed sweep result cache")
    ap.add_argument("--only", default=None,
                    help="run only figure functions whose name contains "
                         "this substring")
    args = ap.parse_args()

    paper_figs.JOBS = (min(os.cpu_count() or 1, 8) if args.jobs is None
                       else args.jobs)
    if args.no_cache:
        paper_figs.CACHE_DIR = None

    print("name,us_per_call,derived")
    for fn in paper_figs.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}",
                  flush=True)
    for name, us, derived in roofline_rows():
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
