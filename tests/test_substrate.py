"""Data pipeline, checkpointing, optimizer, and fault-tolerance tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import MemmapTokens, SyntheticLM, batch_iterator, modality_stub
from repro.ft import StragglerMonitor, TrainSupervisor, plan_elastic_remesh
from repro.optim import OptConfig, clip_by_global_norm, cosine_schedule, make_optimizer


# ---------------------------------------------------------------- data

def test_synthetic_deterministic_and_shifted():
    src = SyntheticLM(vocab=1000, seed=7)
    a = src.batch(step=3, host=0, batch=4, seq=16)
    b = src.batch(step=3, host=0, batch=4, seq=16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    c = src.batch(step=4, host=0, batch=4, seq=16)
    assert not np.array_equal(a["tokens"], c["tokens"])
    d = src.batch(step=3, host=1, batch=4, seq=16)
    assert not np.array_equal(a["tokens"], d["tokens"])  # host-sharded


def test_batch_iterator_resumes():
    src = SyntheticLM(vocab=100, seed=1)
    it = batch_iterator(src, 2, 8, start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  src.batch(5, 0, 2, 8)["tokens"])


def test_memmap_tokens(tmp_path):
    arr = np.arange(1000, dtype=np.int32)
    path = tmp_path / "toks.bin"
    arr.tofile(path)
    ds = MemmapTokens(str(path), seq=16)
    batches = list(ds.epoch(0))
    assert len(batches) == ds.n_seqs
    b0 = batches[0]
    np.testing.assert_array_equal(b0["tokens"][0, 1:], b0["labels"][0, :-1])
    # two hosts partition the epoch
    d0 = MemmapTokens(str(path), seq=16, host=0, num_hosts=2)
    d1 = MemmapTokens(str(path), seq=16, host=1, num_hosts=2)
    assert len(list(d0.epoch(0))) + len(list(d1.epoch(0))) == ds.n_seqs


def test_modality_stub_shapes():
    x = modality_stub("image", 2, 8, 64)
    assert x.shape == (2, 8, 64) and np.isfinite(x).all()


# ------------------------------------------------------------- checkpoint

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "step": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 10, t)
    assert latest_step(str(tmp_path)) == 10
    got = restore(str(tmp_path), 10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jnp.ones((3, 3))})


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, t, blocking=True)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4]
    got = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert got is not None and got[0] == 4


# -------------------------------------------------------------- optimizer

@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    cfg = OptConfig(kind=kind, lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=100)
    init, update = make_optimizer(cfg)
    params = {"w": jnp.full((16, 16), 3.0)}
    state = init(params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
        params, state = update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).mean()) < 1.5


def test_grad_clip():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, 0)) == pytest.approx(0.0)
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------- fault tolerance

def test_supervisor_restarts_from_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3, every=2)
    sup = TrainSupervisor(ckpt, max_restarts=2)
    state0 = {"x": jnp.zeros((), jnp.float32)}
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    def step_fn(step, state):
        return {"x": state["x"] + 1.0}

    final_step, state = sup.run(state0, step_fn, steps=8,
                                failure_injector=injector)
    assert final_step == 8
    assert float(state["x"]) == 8.0            # no lost or repeated updates
    assert sup.restarts == 1
    assert any("restarted from step 4" in m for m in sup.log)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=1.5, patience=2)
    flagged = []
    for _ in range(6):   # flagged() evaluated per step, as the loop does
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        mon.record(2, 3.0)   # consistently 3x median
        flagged = mon.flagged()
    assert flagged == [2]


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh((4, 16, 16), ("pod", "data", "model"),
                               lost_pods=(3,), zero_sharded=True)
    assert plan.new_shape == (3, 16, 16)
    assert plan.surviving_chips == 768
    assert plan.microbatch_scale == 2
    assert plan.resharding == "restore_from_checkpoint"
    with pytest.raises(ValueError):
        plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"),
                            lost_pods=(0, 1), zero_sharded=False)
