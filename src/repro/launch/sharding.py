"""GSPMD sharding rules: parameter specs, ZeRO optimizer-state specs, input
and cache specs for every (arch x shape) cell.

Mesh axes: ("pod",) "data", "model". `pod` composes with `data` for data
parallelism / ZeRO / FSDP; `model` carries tensor parallelism (attention
heads, d_ff, vocab, mamba d_inner, per-expert d_ff).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any


# ------------------------------------------------------------- param rules

def _param_spec(path: str, ndim: int, dp) -> P:
    """Base tensor-parallel spec by parameter name (path is '/'-joined)."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("wq", "wk", "wv"):
        return P(None, "model")           # (d, heads*hd)
    if leaf == "wo" and "mixer" in path:
        return P("model", None)           # (heads*hd, d)
    if leaf in ("wi_gate", "wi_up"):
        if ndim == 3:                      # MoE (E, d, f)
            return P(None, None, "model")
        return P(None, "model")           # (d, f)
    if leaf == "wo":                       # ffn down-proj
        if ndim == 3:                      # MoE (E, f, d)
            return P(None, "model", None)
        return P("model", None)           # (f, d)
    if leaf == "router":
        return P(None, None)
    if leaf == "embed":
        return P("model", None)            # (V, d) vocab-sharded
    if leaf == "lm_head":
        return P(None, "model")            # (d, V)
    if leaf == "in_proj":
        return P(None, "model")            # (d, 2*di)
    if leaf == "out_proj":
        return P("model", None)            # (di, d)
    if leaf == "conv_w":
        return P(None, "model")            # (K, di)
    if leaf in ("conv_b", "dt_bias", "D"):
        return P("model")                  # (di,)
    if leaf == "x_proj":
        return P("model", None)            # (di, dtr+2N)
    if leaf == "dt_proj":
        return P(None, "model")            # (dtr, di)
    if leaf == "A_log":
        return P("model", None)            # (di, N)
    return P()                             # norms, gates, scalars


def _with_period_axis(spec: P, scanned: bool) -> P:
    return P(*((None,) + tuple(spec))) if scanned else spec


def _path_str(kp) -> str:
    return "/".join(
        getattr(k, "key", getattr(k, "name", str(k))) for k in kp)


def _axes_size(entry, sizes: dict[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= sizes[a]
        return n
    return sizes[entry]


def legalize(spec: list, shape: tuple[int, ...], sizes: dict[str, int]
             ) -> list:
    """jit argument shardings require exact divisibility: relocate each
    sharded axis whose dim is not divisible to the largest dim that is,
    else replicate it (e.g. vocab=49155 moves the 'model' shard from the
    vocab dim to d_model)."""
    spec = list(spec)
    for i in range(len(spec)):
        if spec[i] is None:
            continue
        n = _axes_size(spec[i], sizes)
        if shape[i] % n == 0:
            continue
        ax = spec[i]
        spec[i] = None
        cands = [(shape[j], j) for j in range(len(spec))
                 if spec[j] is None and shape[j] % n == 0 and shape[j] >= n]
        if cands:
            _, j = max(cands)
            spec[j] = ax
    return spec


def param_specs(params: PyTree, *, fsdp: bool, dp_axes: tuple[str, ...],
                dp_total: int, axis_sizes: dict[str, int]) -> PyTree:
    """PartitionSpec tree for a parameter tree. With fsdp=True the largest
    unsharded dim of each weight additionally shards over the data axes
    (ZeRO-3 / FSDP semantics via GSPMD)."""

    def spec_for(kp, leaf):
        path = _path_str(kp)
        scanned = "periods" in path
        base = _param_spec(path, leaf.ndim - (1 if scanned else 0), dp_axes)
        spec = list(_with_period_axis(base, scanned))
        while len(spec) < leaf.ndim:
            spec.append(None)
        spec = legalize(spec, leaf.shape, axis_sizes)
        if fsdp and leaf.ndim >= 2:
            cands = [(leaf.shape[i], i) for i in range(leaf.ndim)
                     if spec[i] is None and leaf.shape[i] >= dp_total
                     and leaf.shape[i] % dp_total == 0]
            if cands:
                _, i = max(cands)
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def zero_specs(opt_state: PyTree, pspecs: PyTree, *,
               dp_axes: tuple[str, ...], dp_total: int,
               axis_sizes: dict[str, int]) -> PyTree:
    """ZeRO: optimizer moments take the param spec plus data-axis sharding
    on the largest remaining unsharded dim."""
    flat_p = {  # param path -> spec (moments mirror the param subtree)
        _path_str(kp): s
        for kp, s in jax.tree_util.tree_leaves_with_path(pspecs)
    }

    def spec_for(kp, leaf):
        path = _path_str(kp)
        if leaf.ndim == 0 or path.endswith("step"):
            return P()
        # match the param this moment mirrors: strip the leading m/v/vr/vc
        head, sub = (path.split("/", 1) + [path])[:2]
        base = flat_p.get(sub)
        if base is None or head in ("vr", "vc"):
            # factored moments have reduced rank — re-derive from scratch
            spec = [None] * leaf.ndim
        else:
            spec = list(base)[: leaf.ndim]
            while len(spec) < leaf.ndim:
                spec.append(None)
        spec = legalize(spec, leaf.shape, axis_sizes)
        flat_axes = set()
        for s in spec:
            for a in (s if isinstance(s, (tuple, list)) else [s]):
                flat_axes.add(a)
        if any(ax in flat_axes for ax in dp_axes):
            return P(*spec)
        cands = [(leaf.shape[i], i) for i in range(leaf.ndim)
                 if spec[i] is None and leaf.shape[i] >= dp_total
                 and leaf.shape[i] % dp_total == 0]
        if cands:
            _, i = max(cands)
            spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, opt_state)


# ---------------------------------------------------------- input specs

def batch_spec(B: int, dp_axes: tuple[str, ...], dp_total: int,
               extra_dims: int = 1) -> P:
    """Shard the batch dim over data axes when divisible, else replicate."""
    if B >= dp_total and B % dp_total == 0:
        lead = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return P(*((lead,) + (None,) * extra_dims))
    return P(*((None,) * (extra_dims + 1)))


def cache_specs(cache_shapes: PyTree, B: int, dp_axes: tuple[str, ...],
                dp_total: int, model_total: int = 1) -> PyTree:
    """Specs for decode caches. Batch shards over the data axes and the KV
    time dimension over 'model' when divisible (a 550 GB VLM cache at
    batch=128 x 32k x 40 layers needs both); for B=1 long-context the KV
    time dimension shards over 'data' instead."""
    shard_batch = B >= dp_total and B % dp_total == 0
    lead = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def spec_for(kp, leaf):
        path = _path_str(kp)
        name = path.rsplit("/", 1)[-1]
        scanned = "periods" in path
        pre = (None,) if scanned else ()
        if name in ("k", "v"):            # (B, KV, W, hd)
            W = leaf.shape[2 + len(pre)]
            w_ax = "model" if (model_total > 1 and W % model_total == 0
                               and W >= model_total) else None
            if shard_batch:
                return P(*pre, lead, None, w_ax, None)
            return P(*pre, None, None, "data", None)
        if name == "pos":                  # (B, W)
            W = leaf.shape[1 + len(pre)]
            w_ax = "model" if (model_total > 1 and W % model_total == 0
                               and W >= model_total) else None
            if shard_batch:
                return P(*pre, lead, w_ax)
            return P(*pre, None, "data")
        if name == "h":                    # (B, di, N)
            return P(*pre, lead if shard_batch else None, "model", None)
        if name == "conv":                 # (B, K-1, di)
            return P(*pre, lead if shard_batch else None, None, "model")
        if name == "t":                    # (B,)
            return P(lead if shard_batch else None)
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def named(mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------- activation hints

def moe_buffer_spec(dp_axes: tuple[str, ...], dp_total: int,
                    model_total: int) -> tuple:
    """Hint tuple consumed by repro.models.moe: (capacity-dim axes,
    d-dim axis, divisors to verify against the static buffer shape)."""
    lead = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return (lead, "model", dp_total, model_total)
