"""Selective-scan (Mamba-1) Pallas kernel.

Grid (batch, d_inner blocks, time chunks) with time innermost: the SSM state
h (d_block, N) persists in VMEM scratch across chunk steps, so HBM traffic
is exactly the streaming of dt/B/C/x in and y out — the recurrence itself
runs at VPU rate on VMEM-resident state. Inside a chunk the timestep loop is
a `fori_loop` over VMEM rows (sequential in time, parallel over the
(d_block, N) state lanes), which matches the hardware-friendly formulation
of mamba's CUDA kernel re-thought for the TPU memory hierarchy: chunking
bounds VMEM, the sequential grid carries the state, and no (B,S,D,N) tensor
is ever materialised.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_T = 128
BLOCK_D = 512


def _scan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, h_ref,
                 *, L: int):
    t0 = pl.program_id(2)

    @pl.when(t0 == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                 # (dblk, N)

    def step(i, h):
        dt_i = dt_ref[0, i].astype(jnp.float32)        # (dblk,)
        x_i = x_ref[0, i].astype(jnp.float32)          # (dblk,)
        b_i = b_ref[0, i].astype(jnp.float32)          # (N,)
        c_i = c_ref[0, i].astype(jnp.float32)          # (N,)
        a = jnp.exp(dt_i[:, None] * A)                 # (dblk, N)
        h = a * h + (dt_i * x_i)[:, None] * b_i[None, :]
        y_ref[0, i] = (h @ c_i).astype(y_ref.dtype)    # (dblk,)
        return h

    h_ref[...] = jax.lax.fori_loop(0, L, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan_pallas(dt: jax.Array, A: jax.Array, B: jax.Array,
                      C: jax.Array, x: jax.Array,
                      interpret: bool = False) -> jax.Array:
    """dt, x: (Bt,S,D); A: (D,N); B, C: (Bt,S,N) -> y: (Bt,S,D)."""
    Bt, S, D = x.shape
    N = A.shape[1]
    L = min(CHUNK_T, S)
    while S % L:
        L -= 1
    dblk = min(BLOCK_D, D)
    while D % dblk:
        dblk -= 1
    grid = (Bt, D // dblk, S // L)
    kern = functools.partial(_scan_kernel, L=L)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, dblk), lambda b, d, t: (b, t, d)),  # dt
            pl.BlockSpec((1, L, N), lambda b, d, t: (b, t, 0)),     # B
            pl.BlockSpec((1, L, N), lambda b, d, t: (b, t, 0)),     # C
            pl.BlockSpec((1, L, dblk), lambda b, d, t: (b, t, d)),  # x
            pl.BlockSpec((dblk, N), lambda b, d, t: (d, 0)),        # A
        ],
        out_specs=pl.BlockSpec((1, L, dblk), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((dblk, N), jnp.float32)],
        interpret=interpret,
    )(dt, B, C, x, A)
