"""Fused scheduler rounds: one-pass batched replay of whole rounds.

Covers the fused-round primitives (`CompiledTrace.concat`,
`SegmentCache.batch_relocate`, `execute_fused` cut sampling) and the
end-to-end contract: `PoolScheduler(fused=True)` — the default — is
byte-identical to the per-token reference replay (``fused=False``)
across policy × arrival × request-count, including mid-round
retirements (token jitter) and mid-round admissions (Poisson arrivals),
and preserves the per-request/manager conservation guarantee."""

import numpy as np
import pytest

from repro.core import MB, AddressSpace, SegmentCache, SVMManager, TraceSession
from repro.core.engine import CompiledTrace, execute_fused
from repro.core.uvm import UVMManager
from repro.svm import ModelSpec, run_schedule

SPEC_A = ModelSpec.synthetic("archA", 6, 2 * MB, embed_bytes=4 * MB)
SPEC_B = ModelSpec.synthetic("archB", 10, 2 * MB, embed_bytes=6 * MB)


def _strip(r: dict) -> dict:
    """Drop the execution-mode markers that intentionally differ between
    fused and per-token runs (everything else must match byte for
    byte): the ``fused`` flag and the concat-build/memo counters."""
    r = dict(r)
    r.pop("fused")
    sc = dict(r["shared_cache"])
    for k in ("shared_concats", "concat_memo_entries",
              "concat_memo_evictions"):
        sc.pop(k)
    r["shared_cache"] = sc
    if "chaos" in r:
        # degraded_rounds counts fused→per-token degradations, which
        # only exist on the fused tier
        ch = dict(r["chaos"])
        ch.pop("degraded_rounds")
        r["chaos"] = ch
    return r


# ------------------------------------------------------------- primitives

def _space_session(n=8, align=2 * MB):
    space = AddressSpace(64 * MB, alignment=align)
    for i in range(n):
        space.alloc(align, f"a{i}")
    mgr = SVMManager(space, profile=False)
    return space, mgr, TraceSession(mgr)


def _segment(sess, rids, conc=8, comp=1e-4):
    for rid in rids:
        sess.touch(rid, concurrency=conc)
    sess.compute(comp)
    return sess.seal()


def test_compiled_trace_concat_columns_and_bounds():
    _, _, sess = _space_session()
    a = _segment(sess, (0, 1, 2))
    b = _segment(sess, (3, 4))
    mega = CompiledTrace.concat([a, b])
    assert len(mega) == len(a) + len(b)
    assert mega.seg_bounds.tolist() == [0, len(a), len(a) + len(b)]
    # column-for-column: the segments back-to-back
    assert mega.codes.tolist() == a.codes.tolist() + b.codes.tolist()
    assert mega.rids.tolist() == a.rids.tolist() + b.rids.tolist()
    # derived indices shift by the segment's op offset
    assert mega.touch_pos_np.tolist() == \
        a.touch_pos_np.tolist() + (b.touch_pos_np + len(a)).tolist()
    assert mega.touch_rid_np.tolist() == \
        a.touch_rid_np.tolist() + b.touch_rid_np.tolist()
    assert not mega.codes.flags.writeable          # frozen like any seal
    with pytest.raises(ValueError):
        CompiledTrace.concat([])


def test_concat_replay_identical_to_back_to_back():
    space, mgr, sess = _space_session(n=16)
    segs = [_segment(sess, (i, i + 1, i + 2), comp=1e-4 * (i + 1))
            for i in range(0, 12, 3)]
    for s in segs:
        sess.replay(s)
    ref = mgr.summary()
    mgr2 = SVMManager(space, profile=False)
    TraceSession(mgr2).replay(CompiledTrace.concat(segs))
    assert mgr2.summary() == ref


def test_segment_cache_batch_relocate_counters():
    _, _, sess = _space_session()
    proto = _segment(sess, (0, 1))
    cache = SegmentCache()
    assert cache.batch_relocate("tok", [0, 4]) is None    # 1 miss
    assert cache.misses == 1
    cache.put("tok", 0, proto)
    out = cache.batch_relocate("tok", [0, 2, 4])
    assert cache.hits == 3                     # one hit per base
    assert cache.relocations == 2              # bases differing from 0
    assert out[0] is proto
    assert out[1].touch_rid_np.tolist() == [2, 3]
    assert out[2].touch_rid_np.tolist() == [4, 5]


def test_batch_relocate_under_lru_pressure_matches_get_chain():
    """A round over more distinct architectures than the cache holds:
    entries evict mid-round, later lookups miss, and the batch probe's
    counters (hits/misses/relocations/evictions) must stay identical to
    driving the same lookups through sequential `get` calls."""
    _, _, sess = _space_session(n=12)
    protos = {k: _segment(sess, (i, i + 1))
              for i, k in enumerate(("a", "b", "c"))}

    def drive(batch: bool) -> tuple:
        cache = SegmentCache(cache_size=2)     # < 3 distinct archs
        outs = []
        for rnd in range(2):
            for k in ("a", "b", "c"):          # "c" evicts "a", …
                bases = [0, 2, 4]
                if batch:
                    got = cache.batch_relocate(k, bases)
                else:
                    first = cache.get(k, bases[0])
                    got = None if first is None else \
                        [first] + [cache.get(k, b) for b in bases[1:]]
                if got is None:
                    cache.put(k, 0, protos[k])
                    got = (cache.batch_relocate(k, bases) if batch else
                           [cache.get(k, b) for b in bases])
                outs.append([g.touch_rid_np.tolist() for g in got])
        stats = (cache.hits, cache.misses, cache.relocations,
                 cache.evictions, len(cache))
        return outs, stats

    b_out, b_stats = drive(batch=True)
    s_out, s_stats = drive(batch=False)
    assert b_out == s_out
    assert b_stats == s_stats
    assert b_stats[3] > 0                      # evictions actually fired
    assert b_stats[1] > 3                      # re-misses after eviction
    # the eviction counter is surfaced through stats()
    cache = SegmentCache(cache_size=1)
    cache.put("x", 0, protos["a"])
    cache.put("y", 0, protos["b"])
    assert cache.stats()["shared_evictions"] == 1


def test_shared_cache_concat_counts_builds():
    _, _, sess = _space_session()
    a = _segment(sess, (0, 1))
    cache = SegmentCache()
    mega = cache.concat([a, a])
    assert mega.seg_bounds.tolist() == [0, len(a), 2 * len(a)]
    assert cache.stats()["shared_concats"] == 1


def test_execute_fused_cut_rows_match_sequential_replay():
    """Counter rows sampled at each seg_bounds cut == the counters a
    per-segment replay loop reads from the manager between replays."""
    # a 20MB device holding 64MB of ranges: migrations AND evictions
    # happen mid-trace
    space = AddressSpace(20 * MB, alignment=4 * MB)
    for i in range(16):
        space.alloc(4 * MB, f"a{i}")
    mgr = SVMManager(space, profile=False)
    mgr2 = SVMManager(space, profile=False)
    sess = TraceSession(SVMManager(space, profile=False))
    segs = [_segment(sess, (i % 16, (i + 5) % 16, (i + 9) % 16),
                     comp=2e-5 * (i + 1)) for i in range(10)]
    mega = CompiledTrace.concat(segs)
    rows = execute_fused(mega, mgr, mega.seg_bounds[1:])
    seq = []
    s2 = TraceSession(mgr2)
    for s in segs:
        s2.replay(s)
        seq.append([mgr2.wall, mgr2.n_migrations, mgr2.n_evictions,
                    mgr2.bytes_migrated, mgr2.bytes_evicted])
    assert rows.tolist() == seq
    assert mgr.summary() == mgr2.summary()


def test_execute_fused_rejects_non_svm_manager():
    space = AddressSpace(64 * MB, alignment=2 * MB)
    space.alloc(2 * MB, "a")
    mgr = SVMManager(space, profile=False)
    sess = TraceSession(mgr)
    ct = _segment(sess, (0,))
    with pytest.raises(TypeError):
        execute_fused(ct, UVMManager(space, profile=False),
                      np.array([len(ct)]))


def test_compiled_trace_tile_columns_and_bounds():
    _, _, sess = _space_session()
    a = _segment(sess, (0, 1, 2))
    b = _segment(sess, (3, 4))
    mega = CompiledTrace.concat([a, b])
    t = mega.tile(3)
    assert len(t) == 3 * len(mega)
    assert t.codes.tolist() == mega.codes.tolist() * 3
    assert t.rids.tolist() == mega.rids.tolist() * 3
    # per-rep offsets on every derived index column
    n = len(mega)
    assert t.touch_pos_np.tolist() == [
        int(p) + r * n for r in range(3) for p in mega.touch_pos_np]
    assert t.touch_rid_np.tolist() == mega.touch_rid_np.tolist() * 3
    # seg_bounds: shared endpoints collapse — reps*(len-1)+1 entries
    assert len(t.seg_bounds) == 3 * (len(mega.seg_bounds) - 1) + 1
    assert t.seg_bounds.tolist() == sorted(
        {int(bb) + r * n for r in range(3) for bb in mega.seg_bounds})
    assert mega.tile(1) is mega
    with pytest.raises(ValueError):
        mega.tile(0)


def test_tile_replay_identical_to_repeated_replay():
    space, mgr, sess = _space_session(n=16)
    segs = [_segment(sess, (i, i + 1, i + 2), comp=1e-4 * (i + 1))
            for i in range(0, 12, 3)]
    mega = CompiledTrace.concat(segs)
    for _ in range(4):
        sess.replay(mega)
    ref = mgr.summary()
    mgr2 = SVMManager(space, profile=False)
    TraceSession(mgr2).replay(mega.tile(4))
    assert mgr2.summary() == ref


# ------------------------------------------------- end-to-end equivalence

@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
@pytest.mark.parametrize("n_requests", [2, 8])
def test_fused_equals_per_token(policy, n_requests):
    cap = int(SPEC_A.total_bytes * 1.4)
    kw = dict(policy=policy, seed=3, tokens=5, spec_choice="roundrobin",
              pin_frac=0.4)
    fused = run_schedule([SPEC_A, SPEC_B], n_requests, cap, **kw)
    ref = run_schedule([SPEC_A, SPEC_B], n_requests, cap, fused=False,
                       **kw)
    assert fused["fused"] and not ref["fused"]
    assert _strip(fused) == _strip(ref)


@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
@pytest.mark.parametrize("arrival", ["burst", "poisson"])
def test_fused_equals_per_token_64req(policy, arrival):
    """The scale case: rounds of dozens of segments concatenate into one
    pass; Poisson arrivals force mid-round admissions."""
    cap = int(SPEC_A.total_bytes * 6)
    kw = dict(policy=policy, seed=11, tokens=4, arrival=arrival,
              mean_interarrival_s=1e-4 if arrival == "poisson" else 0.0,
              spec_choice="roundrobin", pin_frac=0.4)
    fused = run_schedule([SPEC_A, SPEC_B], 64, cap, **kw)
    ref = run_schedule([SPEC_A, SPEC_B], 64, cap, fused=False, **kw)
    assert _strip(fused) == _strip(ref)


def test_fused_midround_retirement_and_admission():
    """Token jitter retires requests mid-round (block splits at the
    finisher) while Poisson stragglers admit mid-round; both paths must
    agree byte for byte, per-request rows included."""
    cap = int(SPEC_A.total_bytes * 2.5)
    kw = dict(policy="admission", seed=13, tokens=6, token_jitter=5,
              arrival="poisson", mean_interarrival_s=5e-4,
              spec_choice="roundrobin", pin_frac=0.4)
    fused = run_schedule([SPEC_A, SPEC_B], 16, cap, **kw)
    ref = run_schedule([SPEC_A, SPEC_B], 16, cap, fused=False, **kw)
    assert _strip(fused) == _strip(ref)
    # jitter actually produced unequal decode lengths
    assert len({row["tokens"] for row in fused["requests"]}) > 1


def test_fused_conservation_sums_to_manager():
    cap = int(SPEC_A.total_bytes * 1.4)
    # burst arrival + a pool that admits several tenants: svm_aware
    # rounds with arrivals still pending (or a single admitted tenant)
    # split into unit blocks, which skip the concat path entirely
    cap = int(SPEC_A.total_bytes * 6)
    r = run_schedule([SPEC_A, SPEC_B], 8, cap, policy="svm_aware", seed=7,
                     tokens=8, spec_choice="roundrobin", pin_frac=0.4)
    assert r["fused"]
    c, m = r["conservation"], r["mgr"]
    assert c["migrations"] == m["migrations"]
    assert c["evictions"] == m["evictions"]
    assert c["bytes_migrated"] == m["bytes_migrated"]
    assert c["bytes_evicted"] == m["bytes_evicted"]
    assert c["svm_wall_s"] == pytest.approx(m["wall_s"], rel=1e-12)
    assert r["shared_cache"]["shared_concats"] > 0     # rounds did fuse


@pytest.mark.parametrize("policy", ["fifo", "admission", "svm_aware"])
def test_window_tier_equals_per_token(policy, monkeypatch):
    """The multi-round vectorized window (tile + one `execute_fused`
    pass + column attribution) must be byte-identical to the per-token
    loop — and must actually engage on a burst schedule with uniform
    decode lengths."""
    from repro.svm.scheduler import PoolScheduler

    calls = {"windows": 0, "rounds": 0}
    orig = PoolScheduler._run_window_fused

    def spy(self, order, r, *a, **k):
        calls["windows"] += 1
        calls["rounds"] += r
        return orig(self, order, r, *a, **k)
    monkeypatch.setattr(PoolScheduler, "_run_window_fused", spy)

    cap = int(SPEC_A.total_bytes * 6)
    kw = dict(policy=policy, seed=11, tokens=8, arrival="burst",
              spec_choice="roundrobin", pin_frac=0.4)
    fused = run_schedule([SPEC_A, SPEC_B], 16, cap, **kw)
    assert calls["windows"] > 0 and calls["rounds"] >= 2 * calls["windows"]
    ref = run_schedule([SPEC_A, SPEC_B], 16, cap, fused=False, **kw)
    assert _strip(fused) == _strip(ref)


def test_window_tier_chaos_schedule_identical(monkeypatch):
    """Windows under an injected chaos schedule: the injector cap keeps
    every chaos round on the block/per-token tiers, and the whole run
    stays byte-identical to the per-token oracle."""
    from repro.svm.faults import FaultPlan
    from repro.svm.scheduler import PoolScheduler

    calls = {"windows": 0}
    orig = PoolScheduler._run_window_fused

    def spy(self, order, r, *a, **k):
        calls["windows"] += 1
        return orig(self, order, r, *a, **k)
    monkeypatch.setattr(PoolScheduler, "_run_window_fused", spy)

    cap = int(SPEC_A.total_bytes * 6)
    kw = dict(policy="svm_aware", seed=11, tokens=10, arrival="burst",
              spec_choice="roundrobin", pin_frac=0.4)
    plan = FaultPlan.default(3, n_requests=16, tokens=10)
    fused = run_schedule([SPEC_A, SPEC_B], 16, cap, fault_plan=plan,
                         **kw)
    ref = run_schedule([SPEC_A, SPEC_B], 16, cap, fault_plan=plan,
                       fused=False, **kw)
    assert calls["windows"] > 0          # windows engaged between events
    assert _strip(fused) == _strip(ref)


def test_executor_decode_steps_matches_step_loop():
    """`StreamingExecutor.decode_steps` (one concatenated replay) must
    match the per-token `decode_step` loop on every manager-derived
    metric; only the session's hit counter differs (the fused path
    genuinely fetches the segment once)."""
    from repro.svm import StreamingExecutor

    rng = np.random.default_rng(0)
    params = {f"l{i}": rng.standard_normal((64, 64)).astype(np.float32)
              for i in range(10)}
    layer_paths = [[f"l{i}"] for i in range(10)]
    flops = [1e9] * 10
    budget = 5 * 64 * 64 * 4

    def run(fused):
        ex = StreamingExecutor(params, budget, policy="lrf",
                               profile=False)
        if fused:
            ex.decode_steps(layer_paths, flops, 12, materialize=False)
        else:
            for _ in range(12):
                ex.decode_step(layer_paths, flops, materialize=False)
        return ex.metrics()

    a, b = run(True), run(False)
    a.pop("segment_cache_hits"), b.pop("segment_cache_hits")
    assert a == b


def test_result_reports_shared_cache_counters():
    cap = int(SPEC_A.total_bytes * 1.4)
    r = run_schedule([SPEC_A], 3, cap, policy="fifo", seed=0, tokens=4)
    sc = r["shared_cache"]
    for k in ("shared_segments", "shared_lookup_hits",
              "shared_lookup_misses", "shared_relocations",
              "shared_concats"):
        assert k in sc
    assert sc["shared_relocations"] >= 2     # 2 co-tenants relocated
