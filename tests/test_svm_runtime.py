"""Executable SVM runtime: weight streaming + activation offload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GB, MB
from repro.svm import (
    StreamingExecutor,
    plan_offload,
    plan_param_ranges,
    simulate_offload,
)
from repro.svm.executor import run_layer_stream


def _params(n_layers=8, d=64):
    key = jax.random.PRNGKey(0)
    return {
        "embed": jax.random.normal(key, (256, d), jnp.float32),
        "layers": {
            f"l{i}": {"w": jax.random.normal(
                jax.random.fold_in(key, i), (d, d), jnp.float32)}
            for i in range(n_layers)
        },
    }


def test_plan_param_ranges_tiles_leaves():
    params = _params()
    plan = plan_param_ranges(params, hbm_budget=1 * MB * 64)
    assert plan.total_bytes == sum(plan.leaf_bytes.values())
    for path, rids in plan.leaf_ranges.items():
        sizes = sum(plan.space.ranges[r].size for r in rids)
        assert sizes >= plan.leaf_bytes[path]


def _run_stream(budget_frac, policy="lrf", prefetch=False, pin=(),
                steps=3, n_layers=8, d=64):
    params = _params(n_layers, d)
    total = sum(np.prod(l.shape) * 4 for l in jax.tree.leaves(params))
    ex = StreamingExecutor(params, int(total * budget_frac), policy=policy,
                           prefetch=prefetch, pin=pin)
    paths = [[f"layers/l{i}/w"] for i in range(n_layers)]
    paths[0] = ["embed"] + paths[0]          # embeddings touched first

    outputs = []

    def apply_layer(i, tensors):
        outputs.append(float(jnp.sum(tensors[f"layers/l{i}/w"])))
        return 2.0 * d * d

    m = run_layer_stream(ex, paths, apply_layer, steps=steps)
    return m, outputs, params


def test_streaming_not_oversubscribed_no_evictions():
    m, _, _ = _run_stream(2.0)
    assert m["evictions"] == 0
    # after warmup, all fetches hit: migrations == number of leaves
    assert m["migrations"] == 9


def test_streaming_oversubscribed_thrashes_like_jacobi():
    """Decode loops over layers = repeated cyclic traversal: under LRF the
    earliest-fetched layer is evicted right before the next step needs it
    (the paper's Category-II pathology, now on weights)."""
    m, _, _ = _run_stream(0.6, steps=4)
    assert m["evictions"] > 0
    assert m["evict_to_mig"] > 0.5
    # thrash: migrations far exceed one-per-leaf
    assert m["migrations"] > 9 * 2


def test_streaming_math_is_correct_under_eviction():
    """Evictions must never corrupt the computation."""
    _, out_a, params = _run_stream(0.5, steps=2)
    want = [float(jnp.sum(params["layers"][f"l{i}/w".split('/')[0]]["w"]))
            if False else float(jnp.sum(params["layers"][f"l{i}"]["w"]))
            for i in range(8)] * 2
    np.testing.assert_allclose(out_a, want, rtol=1e-6)


def test_prefetch_overlap_reduces_wall():
    base, _, _ = _run_stream(0.6, prefetch=False, steps=4)
    pre, _, _ = _run_stream(0.6, prefetch=True, steps=4)
    assert pre["migrations"] == base["migrations"]
    assert pre["wall_s"] < base["wall_s"]


def test_pinning_protects_hot_leaves():
    m, _, _ = _run_stream(0.6, pin=("embed",), steps=4)
    # the embedding never migrates again after the pin
    base, _, _ = _run_stream(0.6, steps=4)
    assert m["evictions"] <= base["evictions"]


# ----------------------------------------------------------- offload plans

def test_offload_reverse_beats_forward_replay():
    """The Jacobi2d reverse-traversal insight mapped to activation offload:
    a forward-order replay (remat/pipeline style) cyclically thrashes under
    FIFO eviction; the reverse-order schedule migrates each spilled
    activation exactly once."""
    kw = dict(n_layers=24, act_bytes=64 * MB, budget_bytes=8 * 64 * MB)
    fwd = simulate_offload(plan_offload(**kw, svm_aware=False))
    rev = simulate_offload(plan_offload(**kw, svm_aware=True))
    assert rev["wall_s"] < fwd["wall_s"]
    assert rev["migrations"] < fwd["migrations"]
    # forward replay misses on (almost) every re-read — cyclic pathology
    assert fwd["migrations"] >= 24 + 20
    # reverse: each of the spilled (24-8) activations migrates back once
    assert rev["migrations"] == 24 + (24 - 8)


def test_offload_fits_no_transfers():
    kw = dict(n_layers=8, act_bytes=16 * MB, budget_bytes=16 * 8 * MB * 2)
    out = simulate_offload(plan_offload(**kw, svm_aware=False))
    assert out["evictions"] == 0
    assert out["migrations"] == 8   # one write-allocate per activation
