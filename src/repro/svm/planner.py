"""Range planning over model parameters.

Each parameter leaf is one managed allocation (the hipMallocManaged
analogue); the paper's alignment rule splits it into SVM ranges. The plan
maps leaves <-> range ids so the streaming executor can drive the
SVMManager's fault/migration/eviction machinery with real tensors.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core import AddressSpace, SVMManager
from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.core.ranges import DEFAULT_BASE

PyTree = Any


def _path_str(kp) -> str:
    return "/".join(
        getattr(k, "key", getattr(k, "name", str(k))) for k in kp)


@dataclasses.dataclass
class ParamRanges:
    space: AddressSpace
    leaf_ranges: dict[str, list[int]]      # leaf path -> range ids
    leaf_bytes: dict[str, int]
    hbm_budget: int
    rid_to_leaf: dict[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.rid_to_leaf:
            self.rid_to_leaf = {rid: path
                                for path, rids in self.leaf_ranges.items()
                                for rid in rids}

    @property
    def total_bytes(self) -> int:
        return sum(self.leaf_bytes.values())

    def dos(self) -> float:
        return self.total_bytes / self.hbm_budget * 100.0

    def manager(self, *, policy: str = "lrf",
                params: CostParams = TPU_V5E_HOST,
                **kw) -> SVMManager:
        return SVMManager(self.space, policy=policy, params=params, **kw)


def plan_param_ranges(params: PyTree, hbm_budget: int,
                      base: int = DEFAULT_BASE) -> ParamRanges:
    """Build the unified address space + range table for a param tree."""
    space = AddressSpace(hbm_budget, base=base)
    leaf_ranges: dict[str, list[int]] = {}
    leaf_bytes: dict[str, int] = {}
    for kp, leaf in jax.tree_util.tree_leaves_with_path(params):
        path = _path_str(kp)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize \
            if leaf.shape else leaf.dtype.itemsize
        alloc = space.alloc(max(nbytes, 1), name=path)
        leaf_ranges[path] = [r.rid for r in space.ranges_of(alloc)]
        leaf_bytes[path] = nbytes
    return ParamRanges(space=space, leaf_ranges=leaf_ranges,
                       leaf_bytes=leaf_bytes, hbm_budget=hbm_budget)
