"""Optimizers: AdamW (fp32 moments, ZeRO-shardable) and a factored-second-
moment variant ("adafactor-m": bf16 first moment + row/col-factored second
moment) for trillion-scale parameter budgets where full fp32 moments exceed
HBM (jamba-398B on a 256-chip pod).

Functional API (no optax dependency): state pytrees mirror the param tree so
the launch layer can attach ZeRO PartitionSpecs leaf-by-leaf.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    gsq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros((), jnp.float32))
    norm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ------------------------------------------------------------------- adamw

def adamw_init(params: PyTree) -> PyTree:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, params: PyTree, grads: PyTree,
                 state: PyTree) -> tuple[PyTree, PyTree]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# --------------------------------------------------------------- adafactor

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adafactor_init(params: PyTree) -> PyTree:
    def vrow(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vcol(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "vr": jax.tree.map(vrow, params),
        "vc": jax.tree.map(vcol, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, params: PyTree, grads: PyTree,
                     state: PyTree) -> tuple[PyTree, PyTree]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b2 = cfg.b2

    def upd(p, g, m, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        if _factored(p):
            vr_new = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            vc_new = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = (vr_new[..., None] * vc_new[..., None, :]
                     / jnp.maximum(
                         jnp.mean(vr_new, axis=-1)[..., None, None], 1e-30))
            rms = jnp.sqrt(denom) + cfg.eps
        else:
            vr_new = b2 * vr + (1 - b2) * g2
            vc_new = vc
            rms = jnp.sqrt(vr_new) + cfg.eps
        m_new = (cfg.b1 * m.astype(jnp.float32)
                 + (1 - cfg.b1) * (g32 / rms)).astype(jnp.bfloat16)
        delta = m_new.astype(jnp.float32)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, vr_new, vc_new

    out = jax.tree.map(upd, params, grads, state["m"], state["vr"],
                       state["vc"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"m": pick(1), "vr": pick(2), "vc": pick(3),
                     "step": step}


def make_optimizer(cfg: OptConfig) -> tuple[Callable, Callable]:
    if cfg.kind == "adamw":
        return adamw_init, adamw_update
    if cfg.kind == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {cfg.kind!r}")
