"""Activation offload scheduling — the paper's Jacobi2d insight applied to
training.

Forward writes per-layer activations into a fixed device pool; a second
pass re-reads them. The *order* of the second pass decides everything under
LRF/FIFO eviction (paper §3.2/§4.1):

  * "forward" (naive) — the second pass re-reads activations in FORWARD
    order. This is the access shape of remat-segment recomputation replays
    and pipeline-parallel microbatch replays, and it is exactly the
    paper's naive Jacobi2d: a cyclic traversal where FIFO evicts each
    activation right before it is needed — every read misses.
  * "reverse" (svm-aware) — the second pass runs last→first (what plain
    backprop does naturally, and what an SVM-aware recompute/pipeline
    schedule should do): the resident tail is consumed first, each spilled
    activation migrates back exactly once, and eager spill during forward
    moves evictions off the critical path (paper Alg. 2 + §4.2 parallel
    eviction).
"""

from __future__ import annotations

import dataclasses

from repro.core import AddressSpace, SVMManager
from repro.core.costmodel import CostParams, TPU_V5E_HOST


@dataclasses.dataclass
class OffloadPlan:
    n_layers: int
    act_bytes: int              # bytes per layer-boundary activation
    budget_bytes: int           # device pool for activations
    order: str                  # "forward" (naive) | "reverse" (svm-aware)

    @property
    def resident_layers(self) -> int:
        return max(1, self.budget_bytes // self.act_bytes)


def plan_offload(n_layers: int, act_bytes: int, budget_bytes: int,
                 svm_aware: bool = True) -> OffloadPlan:
    return OffloadPlan(n_layers, act_bytes, budget_bytes,
                       "reverse" if svm_aware else "forward")


def simulate_offload(plan: OffloadPlan, *,
                     params: CostParams = TPU_V5E_HOST,
                     compute_per_layer_s: float = 0.0) -> dict:
    """Run produce+consume through the SVM manager, one range per
    activation."""
    space = AddressSpace(plan.budget_bytes, base=0,
                         alignment=max(plan.act_bytes, 2 * 1024 * 1024))
    allocs = [space.alloc(plan.act_bytes, f"act{i}")
              for i in range(plan.n_layers)]
    rids = [space.ranges_of(a)[0].rid for a in allocs]
    mgr = SVMManager(space, policy="lrf", params=params)

    # ---- forward: produce activations in order
    for i in range(plan.n_layers):
        if plan.order == "reverse":
            # SVM-aware: eagerly spill the policy's victim (oldest under
            # LRF/FIFO) when the pool fills, 85 % overlapped with forward
            # compute (§4.2 parallel eviction, via the public spill API)
            while mgr.free < plan.act_bytes and len(mgr.policy) > 0:
                mgr.spill_oldest(overlap=0.85)
        mgr.touch(rids[i], concurrency=8)     # write-allocate the activation
        mgr.advance(compute_per_layer_s)

    # ---- second pass: consume (recompute replay or backward)
    order = (range(plan.n_layers) if plan.order == "forward"
             else range(plan.n_layers - 1, -1, -1))
    for i in order:
        mgr.touch(rids[i], concurrency=8)
        mgr.advance(compute_per_layer_s * 2.0)

    s = mgr.summary()
    s["order"] = plan.order
    s["resident_layers"] = plan.resident_layers
    return s
