"""repro: range-granular shared virtual memory (SVM) for oversubscribed
JAX training/serving — reproduction + TPU adaptation of Cooper, Scogland &
Ge, "Shared Virtual Memory: Its Design and Performance Implications for
Diverse Applications" (ICS'24).

Import-light by design: subpackages import jax lazily so launch/dryrun can
set XLA flags before backend initialisation."""

__version__ = "1.0.0"
