"""Launch-layer tests: sharding-rule legalization properties and an actual
jit lower+compile of train/serve steps on a 1x1 mesh (the full 512-device
dry-run runs via launch/dryrun.py; these keep the sharding code paths under
CI on one device)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.settings import SHAPES, cell_skipped, settings_for
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import init_cache, init_params
from repro.optim import OptConfig, make_optimizer

SIZES = {"data": 16, "model": 16}
DP = ("data",)


def _axes_of(spec):
    for s in spec:
        if s is None:
            continue
        yield from (s if isinstance(s, (tuple, list)) else [s])


def _check_divisible(specs, shapes):
    for (kp, spec), (_, leaf) in zip(
            jax.tree_util.tree_leaves_with_path(specs),
            jax.tree_util.tree_leaves_with_path(
                shapes, is_leaf=lambda x: hasattr(x, "shape"))):
        for dim, s in zip(leaf.shape, tuple(spec)):
            if s is None:
                continue
            n = 1
            for a in (s if isinstance(s, (tuple, list)) else [s]):
                n *= SIZES[a]
            assert dim % n == 0, f"{kp}: {dim} % {n}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_legal_for_full_configs(arch):
    """Every sharded dim of every FULL-config parameter divides evenly on
    the production mesh (the dry-run requirement)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    for fsdp in (False, True):
        specs = shd.param_specs(params, fsdp=fsdp, dp_axes=DP, dp_total=16,
                                axis_sizes=SIZES)
        _check_divisible(specs, params)
        # TP must actually engage: at least half the big weights sharded
        n_sharded = sum(1 for _, s in jax.tree_util.tree_leaves_with_path(
            specs) if any(True for _ in _axes_of(s)))
        assert n_sharded > 0


@pytest.mark.parametrize("arch", ["gemma3-1b", "jamba-1.5-large-398b",
                                  "mixtral-8x7b", "falcon-mamba-7b"])
def test_zero_specs_shard_moments(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.param_specs(params, fsdp=False, dp_axes=DP, dp_total=16,
                             axis_sizes=SIZES)
    opt_init, _ = make_optimizer(OptConfig(kind="adamw"))
    opt = jax.eval_shape(opt_init, params)
    ospecs = shd.zero_specs(opt, pspecs, dp_axes=DP, dp_total=16,
                            axis_sizes=SIZES)
    _check_divisible(ospecs, opt)
    # ZeRO engaged: large moments carry a data axis
    big = [s for (kp, s), (_, l) in zip(
        jax.tree_util.tree_leaves_with_path(ospecs),
        jax.tree_util.tree_leaves_with_path(opt))
        if l.ndim >= 2 and max(l.shape) >= 1024]
    assert any("data" in list(_axes_of(s)) for s in big)


def test_cache_specs_shard_batch_and_window():
    cfg = get_config("granite-3-2b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = shd.cache_specs(cache, 128, DP, 16, 16)
    _check_divisible(specs, cache)
    # B=1 long-context: time dim takes the data axis
    cache1 = jax.eval_shape(lambda: init_cache(cfg, 1, 4096))
    specs1 = shd.cache_specs(cache1, 1, DP, 16, 16)
    flat = {"/".join(str(getattr(k, 'key', k)) for k in kp): s
            for kp, s in jax.tree_util.tree_leaves_with_path(specs1)}
    kspec = next(v for p, v in flat.items() if p.endswith("/k"))
    assert "data" in list(_axes_of(kspec))


@settings(max_examples=30, deadline=None)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=70000), min_size=1,
                  max_size=4),
    axis=st.sampled_from(["model", "data", ("data", "model")]),
    pos=st.integers(min_value=0, max_value=3),
)
def test_property_legalize_always_divisible(dims, axis, pos):
    spec = [None] * len(dims)
    spec[min(pos, len(dims) - 1)] = axis
    out = shd.legalize(spec, tuple(dims), SIZES)
    for dim, s in zip(dims, out):
        if s is None:
            continue
        n = 1
        for a in (s if isinstance(s, (tuple, list)) else [s]):
            n *= SIZES[a]
        assert dim % n == 0


def test_train_and_serve_compile_on_host_mesh():
    """End-to-end lower+compile of the jitted steps on a 1x1 mesh."""
    cfg = dataclasses.replace(get_reduced("granite-3-2b"), n_layers=2)
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig()
    opt_init, _ = make_optimizer(opt_cfg)
    opt = opt_init(params)
    step = make_train_step(cfg, opt_cfg, microbatches=2)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "labels": jnp.zeros((4, 16), jnp.int32)}
    with mesh:
        p2, o2, m = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))

    serve = make_serve_step(cfg)
    cache = init_cache(cfg, 2, 16)
    with mesh:
        ids, cache = jax.jit(serve)(params, jnp.zeros((2, 1), jnp.int32),
                                    cache)
    assert ids.shape == (2, 1)


def test_cell_skip_table():
    skipped = [(a, s) for a in ARCH_IDS for s in SHAPES
               if cell_skipped(a, s)]
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s in skipped)
    assert ("falcon-mamba-7b", "long_500k") not in skipped
    assert ("jamba-1.5-large-398b", "long_500k") not in skipped
    # every arch has settings
    for a in ARCH_IDS:
        assert settings_for(a).microbatches >= 1
