"""Checkpointing: pytree save/restore with atomic step directories, async
writes, and retention. Multi-host posture: each process writes only its own
param shards (`shard_id`), manifests are msgpack, and a step is committed by
an atomic rename — a crash mid-write never corrupts the latest checkpoint.
"""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import msgpack
import numpy as np

import jax

PyTree = Any

_MANIFEST = "manifest.msgpack"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    out = {}
    for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
        path = "/".join(
            getattr(k, "key", getattr(k, "name", str(k))) for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """numpy's savez can't serialise ml_dtypes (bfloat16 etc.) — store the
    raw bits as uint16/uint8 and record the logical dtype."""
    name = arr.dtype.name
    if name == "bfloat16":
        return arr.view(np.uint16), name
    if name.startswith("float8"):
        return arr.view(np.uint8), name
    return arr, name


def _from_storable(arr: np.ndarray, name: str) -> np.ndarray:
    if name == "bfloat16":
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    if name.startswith("float8"):
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, name))
    return arr


def save(root: str, step: int, tree: PyTree, *, shard_id: int = 0) -> str:
    """Write `tree` under root/step_<step>; atomic via tmp+rename."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + f".tmp{shard_id}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {}
    stored = {}
    for i, (k, v) in enumerate(flat.items()):
        sv, logical = _to_storable(v)
        stored[str(i)] = sv
        manifest[k] = {"idx": i, "shape": list(v.shape), "dtype": logical}
    with open(os.path.join(tmp, f"shard{shard_id}.npz"), "wb") as f:
        np.savez(f, **stored)
    with open(os.path.join(tmp, _MANIFEST), "wb") as f:
        f.write(msgpack.packb({"step": step, "leaves": manifest,
                               "shard": shard_id}))
    os.replace(tmp, final) if not os.path.exists(final) else _merge(tmp, final)
    return final


def _merge(tmp: str, final: str) -> None:
    for name in os.listdir(tmp):
        os.replace(os.path.join(tmp, name), os.path.join(final, name))
    shutil.rmtree(tmp, ignore_errors=True)


def restore(root: str, step: int, like: PyTree, *, shard_id: int = 0
            ) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, f"shard{shard_id}.npz"))
    flat_like = _flatten(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for (path, ref), leaf in zip(flat_like.items(), leaves):
        meta = manifest["leaves"][path]
        arr = _from_storable(data[str(meta["idx"])], meta["dtype"])
        if list(arr.shape) != list(ref.shape):
            raise ValueError(
                f"checkpoint mismatch at {path}: {arr.shape} vs {ref.shape}")
        out.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(root)
             if n.startswith("step_") and not n.endswith(".tmp0")
             and "." not in n.split("_")[1]]
    return max(steps) if steps else None


class CheckpointManager:
    """Async, retained checkpointing for the train loop."""

    def __init__(self, root: str, *, keep: int = 3, every: int = 100):
        self.root = root
        self.keep = keep
        self.every = every
        self._thread: threading.Thread | None = None

    def maybe_save(self, step: int, tree: PyTree, *, blocking: bool = False
                   ) -> bool:
        if step % self.every != 0:
            return False
        self.wait()
        # snapshot to host memory before returning control to the step loop
        snap = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.root, step, snap)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and "." not in n.split("_", 1)[1]))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like: PyTree) -> tuple[int, PyTree] | None:
        self.wait()
        step = latest_step(self.root)
        if step is None:
            return None
        return step, restore(self.root, step, like)
