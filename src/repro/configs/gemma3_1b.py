"""gemma3-1b: 26L d_model=1152 4H (GQA kv=1, head_dim=256) d_ff=6912
vocab=262144 — 5:1 local(sw=512):global interleave, dual RoPE theta
[hf:google/gemma-3-1b-pt; unverified]."""

import dataclasses

from repro.models.config import ATTN, ATTN_LOCAL, MLP, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    vocab=262144,
    d_model=1152,
    n_layers=26,
    d_ff=6912,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    layer_pattern=(ATTN_LOCAL,) * 5 + (ATTN,),
    ffn_pattern=(MLP,),
    sliding_window=512,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=8, d_ff=128,
        n_heads=4, n_kv_heads=1, head_dim=16, sliding_window=8)
