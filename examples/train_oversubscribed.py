"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with SVM-managed activation offload, comparing the naive forward-order
replay schedule against the SVM-aware reverse schedule (the paper's
Jacobi2d insight mapped to training).

    PYTHONPATH=src python examples/train_oversubscribed.py [--steps N]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import SyntheticLM
from repro.ft import TrainSupervisor
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, make_optimizer
from repro.svm import plan_offload, simulate_offload
from repro.core import MB


def build_100m():
    """~100M-parameter dense config (granite family, shrunk)."""
    base = get_reduced("granite-3-2b")
    return dataclasses.replace(
        base, name="granite-100m", vocab=32768, d_model=512, n_layers=8,
        d_ff=2048, n_heads=8, n_kv_heads=4)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    # --- SVM activation-offload plan for this model under a tight budget
    act_bytes = args.batch * args.seq * cfg.d_model * 2
    budget = 3 * act_bytes  # device pool holds 3 of 8 layer activations
    naive = simulate_offload(plan_offload(cfg.n_layers, act_bytes, budget,
                                          svm_aware=False))
    aware = simulate_offload(plan_offload(cfg.n_layers, act_bytes, budget,
                                          svm_aware=True))
    print(f"offload schedule (DOS={cfg.n_layers*act_bytes/budget*100:.0f}%):"
          f" naive replay {naive['migrations']} migs/{naive['wall_s']*1e3:.2f}ms"
          f" vs svm-aware {aware['migrations']} migs/"
          f"{aware['wall_s']*1e3:.2f}ms "
          f"({naive['wall_s']/aware['wall_s']:.2f}x)")

    # --- real training under the fault-tolerant supervisor
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt_init, _ = make_optimizer(opt_cfg)
    state = {"params": params, "opt": opt_init(params)}
    train_step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))
    data = SyntheticLM(vocab=cfg.vocab, seed=0)
    losses = []

    def step_fn(step, st):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(step, 0, args.batch, args.seq).items()}
        p, o, m = train_step(st["params"], st["opt"], batch)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"  step {step:4d} loss={losses[-1]:.4f}")
        return {"params": p, "opt": o}

    sup = TrainSupervisor(CheckpointManager(args.ckpt, keep=2, every=50))
    t0 = time.time()
    final_step, state = sup.run(state, step_fn, steps=args.steps)
    dt = time.time() - t0
    print(f"finished {final_step} steps in {dt:.1f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
