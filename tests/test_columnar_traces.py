"""Columnar trace tier + cross-point compiled-trace sharing.

Golden contract #1: `Workload.emit_columns(space)` produces op-for-op
identical columns to lowering the `trace()` generator through
`compile_trace` — every array compared exactly (fargs bitwise) — for
every Table-2 workload × DOS {78, 109, 147} × svm-aware/naive variant.

Golden contract #2: a CompiledTrace cached under a `trace_key` and
replayed across policy / variant / manager points yields byte-identical
`summary()` and profile events to a fresh compile (and to the scalar op
loop)."""

import numpy as np
import pytest

from repro.core import (
    GB,
    MB,
    SweepPoint,
    TraceCache,
    run_point,
    run_sweep,
    simulate,
)
from repro.core.engine import (
    TRACE_CACHE,
    compile_trace,
    compile_workload,
    execute_compiled,
)
from repro.core.ranges import AddressSpace
from repro.core.simulator import Workload, apply_trace
from repro.core.svm import SVMManager
from repro.core.sweep import trace_key
from repro.core.traces import make_workload

CAP = 4 * GB
DOS_POINTS = (78, 109, 147)
POLICIES = ("lrf", "lru", "clock", "random")

# every Table-2 workload, including the svm-aware rewrites
TABLE2_VARIANTS = [
    ("stream", {}),
    ("conv2d", {}),
    ("jacobi2d", {}),
    ("jacobi2d", {"svm_aware": True}),
    ("bfs", {}),
    ("sgemm", {}),
    ("sgemm", {"svm_aware": True}),
    ("syr2k", {}),
    ("syr2k", {"svm_aware": True}),
    ("mvt", {}),
    ("gesummv", {}),
]

COLUMNS = ("codes", "rids", "concs", "hints", "fargs", "boundaries",
           "touch_pos_np", "touch_rid_np")


def _build(name, kw, dos, alignment=None):
    space = AddressSpace(CAP, base=175 * MB, alignment=alignment)
    wl = make_workload(name, int(CAP * dos / 100), **kw)
    wl.build(space)
    return space, wl


@pytest.mark.parametrize("name,kw", TABLE2_VARIANTS,
                         ids=[n + ("-aware" if k else "")
                              for n, k in TABLE2_VARIANTS])
def test_emit_columns_identical_to_generator_lowering(name, kw):
    for dos in DOS_POINTS:
        space, wl = _build(name, kw, dos)
        ct_gen = compile_trace(wl.trace(space))
        ct_col = wl.emit_columns(space)
        for f in COLUMNS:
            a, b = getattr(ct_gen, f), getattr(ct_col, f)
            assert a.dtype == b.dtype, (dos, f)
            assert np.array_equal(a, b), (dos, f)
        assert ct_gen.touch_pos == ct_col.touch_pos
        assert ct_gen.touch_rid == ct_col.touch_rid
        assert ct_gen.n_ops == ct_col.n_ops


def test_emit_columns_identical_on_fine_grained_ranges():
    """Many-range spaces (the microbenchmark shape) stay exact."""
    for name, kw in (("stream", {}), ("bfs", {}), ("sgemm", {})):
        space, wl = _build(name, kw, 147, alignment=8 * MB)
        ct_gen = compile_trace(wl.trace(space))
        ct_col = wl.emit_columns(space)
        for f in COLUMNS:
            assert np.array_equal(getattr(ct_gen, f), getattr(ct_col, f))


def test_compile_workload_dispatches_to_columnar():
    space, wl = _build("stream", {}, 125)
    calls = []
    orig = wl.emit_columns

    def spy(sp):
        calls.append(sp)
        return orig(sp)

    wl.emit_columns = spy
    ct = compile_workload(wl, space)
    assert calls == [space]
    assert np.array_equal(ct.codes, compile_trace(wl.trace(space)).codes)


def test_compile_workload_generator_fallbacks():
    # max_ops truncation counts kernel markers op-for-op: generator path
    space, wl = _build("stream", {}, 147)
    ct = compile_workload(wl, space, max_ops=17)
    assert np.array_equal(
        ct.codes, compile_trace(wl.trace(space), max_ops=17).codes)

    # custom workloads without emit_columns lower the generator
    class Custom(Workload):
        def build(self, sp):
            self.a = sp.alloc(self.total_bytes, "a")

        def trace(self, sp):
            for r in sp.ranges_of(self.a):
                yield ("touch", r.rid, 8, 0)

    space2 = AddressSpace(CAP, base=175 * MB)
    cwl = Custom(GB)
    cwl.build(space2)
    ct2 = compile_workload(cwl, space2)
    assert len(ct2) == len(space2.ranges_of(cwl.a))


def test_compiled_trace_frozen_and_copy():
    space, wl = _build("jacobi2d", {}, 109)
    ct = compile_workload(wl, space)
    with pytest.raises(ValueError):
        ct.rids[0] = 99
    cp = ct.copy()
    assert cp.rids is ct.rids            # columns shared
    assert cp.span_cache is not ct.span_cache
    mgr = SVMManager(space, profile=False)
    execute_compiled(cp, mgr)            # copy is executable
    assert mgr.n_migrations > 0


def test_trace_cache_lru_semantics():
    cache = TraceCache(maxsize=2)
    space, wl = _build("stream", {}, 109)
    ct = compile_workload(wl, space, cache=cache, key="k1")
    assert cache.misses == 1 and len(cache) == 1
    assert compile_workload(wl, space, cache=cache, key="k1") is ct
    assert cache.hits == 1
    compile_workload(wl, space, cache=cache, key="k2")
    cache.get("k1")                      # refresh k1, k2 becomes LRU
    compile_workload(wl, space, cache=cache, key="k3")
    assert len(cache) == 2
    assert cache.get("k2") is None       # evicted
    assert cache.get("k1") is not None


def test_trace_key_shares_across_policy_variant_manager_axes():
    def pt(**kw):
        return SweepPoint.make("jacobi2d", int(CAP * 1.09), CAP, **kw)

    keys = {trace_key(pt()),
            trace_key(pt(policy="lru")),
            trace_key(pt(mgr_kwargs={"previct_watermark": 0.1})),
            trace_key(pt(manager="uvm")),
            trace_key(pt(zero_copy="biggest"))}
    assert len(keys) == 1
    assert trace_key(pt()) != trace_key(
        SweepPoint.make("jacobi2d", int(CAP * 1.25), CAP))
    assert trace_key(pt()) != trace_key(
        SweepPoint.make("jacobi2d", int(CAP * 1.09), CAP,
                        wl_kwargs={"svm_aware": True}))


def test_cached_trace_reuse_byte_identical_across_policies():
    """One cached CompiledTrace replayed across policies and fresh spaces
    == fresh compiles == the scalar op loop (summary AND events)."""
    cache = TraceCache()
    key = ("jacobi2d", int(CAP * 1.09), (), CAP, 175 * MB, None)
    for policy in POLICIES:
        space, wl = _build("jacobi2d", {}, 109)
        ct = compile_workload(wl, space, cache=cache, key=key)
        mgr = SVMManager(space, policy=policy, profile=True)
        execute_compiled(ct, mgr)

        space_f, wl_f = _build("jacobi2d", {}, 109)
        mgr_f = SVMManager(space_f, policy=policy, profile=True)
        execute_compiled(compile_workload(wl_f, space_f), mgr_f)

        space_s, wl_s = _build("jacobi2d", {}, 109)
        mgr_s = SVMManager(space_s, policy=policy, profile=True)
        apply_trace(mgr_s, wl_s.trace(space_s))

        assert mgr.summary() == mgr_f.summary() == mgr_s.summary()
        assert mgr.events == mgr_f.events == mgr_s.events
        assert mgr.resident == mgr_f.resident == mgr_s.resident
        assert mgr.free == mgr_f.free == mgr_s.free
    assert cache.misses == 1 and cache.hits == len(POLICIES) - 1


def test_run_sweep_grouped_rows_match_uncached_run_point():
    pts = [SweepPoint(workload="stream", total_bytes=int(CAP * 1.25),
                      capacity=CAP, policy=p) for p in POLICIES]
    pts.append(SweepPoint(workload="stream", total_bytes=int(CAP * 1.25),
                          capacity=CAP, manager="uvm"))
    TRACE_CACHE.clear()
    stats = {}
    grouped = run_sweep(pts, jobs=0, stats=stats)
    assert stats["trace_groups"] == 1
    fresh = [run_point(p, trace_cache=False) for p in pts]
    assert grouped == fresh
    assert TRACE_CACHE.hits >= len(pts) - 1


def test_raw_single_block_does_not_freeze_caller_arrays():
    from repro.core import ColumnEmitter
    from repro.core.engine import OP_TOUCH

    n = 8
    codes = np.full(n, OP_TOUCH, dtype=np.int8)
    rids = np.arange(n, dtype=np.int64)
    concs = np.full(n, 4, dtype=np.int64)
    hints = np.zeros(n, dtype=np.int64)
    fargs = np.zeros(n)
    em = ColumnEmitter()
    em.raw(codes, rids, concs, hints, fargs)
    ct = em.finish()
    rids[0] = 99                      # caller's array stays writable...
    assert ct.rids[0] == 0            # ...and the trace is unaffected
    with pytest.raises(ValueError):
        ct.rids[0] = 1                # the trace itself is frozen

    # same for a single touches() block
    user = np.arange(5, dtype=np.int64)
    em2 = ColumnEmitter()
    em2.touches(user, 4)
    ct2 = em2.finish()
    user[0] = 77
    assert ct2.touch_rid_np[0] == 0


def test_simulate_rejects_bare_string_zero_copy():
    with pytest.raises(ValueError, match="biggest"):
        simulate(make_workload("gesummv", int(CAP * 1.25)), CAP,
                 profile=False, zero_copy_alloc_names="A")
    # a sweep point with a bare name must raise too, not char-split it
    with pytest.raises(ValueError, match="biggest"):
        run_point(SweepPoint(workload="gesummv",
                             total_bytes=int(CAP * 1.25), capacity=CAP,
                             zero_copy="v0"))


def test_parallel_sweep_splits_large_groups():
    """All points share one TraceKey; parallel rows must still match."""
    pts = [SweepPoint(workload="stream", total_bytes=int(CAP * 1.09),
                      capacity=CAP, policy=p, mgr_kwargs=mk)
           for p in POLICIES
           for mk in ((), (("previct_watermark", 0.1),))]
    serial = run_sweep(pts, jobs=0)
    parallel = run_sweep(pts, jobs=4)
    assert serial == parallel


def test_zero_copy_biggest_resolves_from_simulation_build():
    row = run_point(SweepPoint(workload="gesummv",
                               total_bytes=int(CAP * 1.25), capacity=CAP,
                               zero_copy="biggest"))
    direct = simulate(make_workload("gesummv", int(CAP * 1.25)), CAP,
                      profile=False, zero_copy_alloc_names=("A",)).row()
    assert row == direct
    # sentinel also accepted by simulate directly, off the same build
    via_sim = simulate(make_workload("gesummv", int(CAP * 1.25)), CAP,
                       profile=False,
                       zero_copy_alloc_names="biggest").row()
    assert via_sim == direct
