"""Jit'd dispatch wrappers for the Pallas kernels.

`impl="auto"` picks the Pallas kernel on TPU backends and the pure-jnp
reference elsewhere (this CPU container validates the kernels in
interpret mode; ``impl="pallas"`` forces interpret=True off-TPU).
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.jacobi2d import jacobi2d_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.stream_triad import triad_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if impl == "auto":
        return (True, False) if _on_tpu() else (False, False)
    if impl == "pallas":
        return True, not _on_tpu()
    if impl == "jnp":
        return False, False
    raise ValueError(f"impl must be auto|pallas|jnp, got {impl!r}")


def triad(b, c, alpha, impl: str = "auto"):
    use, interp = _resolve(impl)
    if use:
        return triad_pallas(b, c, alpha, interpret=interp)
    return ref.triad_ref(b, c, alpha)


def jacobi2d(a, impl: str = "auto"):
    use, interp = _resolve(impl)
    if use:
        return jacobi2d_pallas(a, interpret=interp)
    return ref.jacobi2d_ref(a)


def matmul(a, b, impl: str = "auto"):
    use, interp = _resolve(impl)
    if use:
        return matmul_pallas(a, b, interpret=interp)
    return ref.matmul_ref(a, b)


def flash_attention(q, k, v, causal: bool = True, impl: str = "auto"):
    use, interp = _resolve(impl)
    if use:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=interp)
    return ref.flash_attention_ref(q, k, v, causal=causal)


def mamba_scan(dt, A, B, C, x, impl: str = "auto"):
    use, interp = _resolve(impl)
    if use:
        return mamba_scan_pallas(dt, A, B, C, x, interpret=interp)
    return ref.mamba_scan_ref(dt, A, B, C, x)
