from repro.data.pipeline import (
    MemmapTokens,
    SyntheticLM,
    batch_iterator,
    modality_stub,
)

__all__ = ["SyntheticLM", "MemmapTokens", "batch_iterator", "modality_stub"]
