"""Top-k Mixture-of-Experts with capacity-bounded scatter dispatch.

Dispatch uses flat scatter-add / gather (not (T,E,C) one-hot masks) so the
memory footprint is O(E*C*d) and HLO FLOPs reflect *active* expert compute —
which keeps the roofline's MODEL_FLOPS/HLO_FLOPS ratio honest for MoE archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import activation, dense_init

Array = jax.Array

# Optional GSPMD hint for the (E*C, d) dispatch buffer; set by the launch
# layer before lowering so the buffer never materialises replicated on a
# production mesh (tests/examples on one device leave it None).
BUFFER_SPEC = None

# Shard-local expert-parallel dispatch (beyond-paper §Perf optimization):
# when set to (mesh, dp_axes, model_axis), the dispatch/expert/combine runs
# inside shard_map with per-shard capacity — GSPMD never sees the global
# scatter (which it can only partition by full rematerialisation, observed
# as 100s-scale collective terms and replicated expert compute).
SHARD_MAP_SPEC = None


def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(k0, (d, e)),
        "wi_gate": dense_init(k1, (e, d, f)),
        "wi_up": dense_init(k2, (e, d, f)),
        "wo": dense_init(k3, (e, f, d)),
    }


def moe_apply(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """x: (B,S,d) -> (y, aux_loss). Tokens over capacity are dropped
    (standard capacity-factor routing); aux = load-balancing loss.

    Dispatches to the shard-local EP path when SHARD_MAP_SPEC is set."""
    if SHARD_MAP_SPEC is not None:
        return _moe_apply_shardmap(p, cfg, x)
    return _moe_core(p, cfg, x)


def _moe_core(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    T = B * S
    cap = max(8, int(cfg.capacity_factor * T * k / e))
    xt = x.reshape(T, d)

    logits = (xt @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (T, k)
    gate = (gate / jnp.sum(gate, axis=-1, keepdims=True)).astype(x.dtype)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)           # (T, k, E)
    flatoh = onehot.reshape(T * k, e)
    pos_in_expert = (jnp.cumsum(flatoh, axis=0) - flatoh)      # (T*k, E)
    pos = jnp.sum(pos_in_expert * flatoh, axis=-1)             # (T*k,)
    keep = pos < cap
    slot = idx.reshape(T * k) * cap + jnp.minimum(pos, cap - 1)
    slot = jnp.where(keep, slot, e * cap)                      # overflow sink

    # dispatch: scatter tokens into (E*C + 1, d)
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    xk = jnp.repeat(xt, k, axis=0)                             # (T*k, d)
    buf = buf.at[slot].add(xk)
    buf = buf[: e * cap].reshape(e, cap, d)
    if BUFFER_SPEC is not None and SHARD_MAP_SPEC is None:
        cap_ax, d_ax, dp_total, model_total = BUFFER_SPEC
        spec = jax.sharding.PartitionSpec(
            None,
            cap_ax if cap % dp_total == 0 else None,
            d_ax if d % model_total == 0 else None)
        buf = jax.lax.with_sharding_constraint(buf, spec)

    # expert compute (active FLOPs only: E * C * d * f)
    h = activation(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]), cfg.act)
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # (E, C, d)

    # combine: gather back and weight by gates
    flat = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), dtype=out.dtype)], axis=0)
    yk = flat[slot].reshape(T, k, d)
    y = jnp.sum(yk * gate[..., None], axis=1).reshape(B, S, d)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y, aux


def _moe_apply_shardmap(p: dict, cfg: ModelConfig, x: Array
                        ) -> tuple[Array, Array]:
    """Expert-parallel-style shard-local dispatch.

    Tokens stay on their data shard; capacity is per-shard; the only
    communication is one psum of the (T_loc, d) combined output over the
    tensor axis (the expert f-dim is TP-sharded) plus the aux-loss mean.
    """
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    mesh, dp_axes, model_ax = SHARD_MAP_SPEC
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def local_fn(pl, xl):
        y, aux = _moe_core(pl, cfg, xl)
        y = jax.lax.psum(y, model_ax)
        aux = jax.lax.pmean(aux, dp_axes + (model_ax,))
        return y, aux

    pspecs = {
        "router": P(None, None),
        "wi_gate": P(None, None, model_ax),
        "wi_up": P(None, None, model_ax),
        "wo": P(None, model_ax, None),
    }
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspecs, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(p, x)
