"""Range planning over model parameters.

Each parameter leaf is one managed allocation (the hipMallocManaged
analogue); the paper's alignment rule splits it into SVM ranges. The plan
maps leaves <-> range ids so the streaming executor can drive the
SVMManager's fault/migration/eviction machinery with real tensors.

Shared-pool planning (multi-tenant serving): `plan_leaf_ranges` can plan
into an **existing** `AddressSpace`, appending this tenant's allocations
after whatever is already placed there.  With ``align_start=True`` the
plan begins on an alignment boundary, so every same-architecture tenant
gets a congruent range layout (identical per-leaf range counts and
relative rids) — the precondition for relocating compiled trace segments
between tenants (`CompiledTrace.relocate`)."""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.core import AddressSpace, SVMManager
from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.core.ranges import DEFAULT_BASE

PyTree = Any


def _path_str(kp) -> str:
    return "/".join(
        getattr(k, "key", getattr(k, "name", str(k))) for k in kp)


@dataclasses.dataclass
class ParamRanges:
    """The leaf ↔ range mapping for one planned parameter set.

    ``space`` may be private to this plan or shared with other tenants'
    plans (shared-pool serving); ``rid_base`` is the first range id this
    plan owns, and ``geometry()`` fingerprints the plan's relative range
    layout (equal geometry ⇒ compiled segments are relocatable between
    the two plans)."""

    space: AddressSpace
    leaf_ranges: dict[str, list[int]]      # leaf path -> range ids
    leaf_bytes: dict[str, int]
    hbm_budget: int
    rid_base: int = 0
    rid_to_leaf: dict[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.rid_to_leaf:
            self.rid_to_leaf = {rid: path
                                for path, rids in self.leaf_ranges.items()
                                for rid in rids}

    @property
    def total_bytes(self) -> int:
        return sum(self.leaf_bytes.values())

    def dos(self) -> float:
        """This plan's own degree of oversubscription (%) against the
        budget (a shared space's aggregate DOS is ``space.dos()``)."""
        return self.total_bytes / self.hbm_budget * 100.0

    def geometry(self) -> tuple:
        """Relative range layout: per-leaf (path, size, rid offsets from
        ``rid_base``).  Two plans with equal geometry are congruent — a
        segment recorded against one relocates onto the other by a pure
        rid shift."""
        return tuple(
            (path, self.leaf_bytes[path],
             tuple(rid - self.rid_base for rid in rids))
            for path, rids in self.leaf_ranges.items())

    def manager(self, *, policy: str = "lrf",
                params: CostParams = TPU_V5E_HOST,
                **kw) -> SVMManager:
        """A fresh `SVMManager` over this plan's address space."""
        return SVMManager(self.space, policy=policy, params=params, **kw)

    def clone_into(self, space: AddressSpace) -> "ParamRanges":
        """A congruent copy of this plan at ``space``'s current cursor.

        The shared-pool fast path for repeated architectures: this plan's
        allocations and ranges replicate under constant address / rid /
        alloc-id shifts (both plans start on an alignment boundary of the
        same space, so every alignment cut lands at the same relative
        offset), skipping the per-leaf ``alloc``/`split_allocation` walk.
        Requires ``self`` to have been planned into the same ``space``
        with ``align_start=True`` — exactly how `PoolScheduler` plans
        tenants.  Congruence (`geometry()` equality) holds by
        construction."""
        from repro.core.ranges import Allocation, Range

        space.pad_to_alignment()
        n_r = sum(len(rids) for rids in self.leaf_ranges.values())
        proto_ranges = space.ranges[self.rid_base:self.rid_base + n_r]
        aid0 = proto_ranges[0].alloc_id
        d_addr = space._cursor - proto_ranges[0].start
        d_rid = len(space.ranges) - self.rid_base
        d_aid = len(space.allocations) - aid0
        new_ranges = [Range(rid=r.rid + d_rid, alloc_id=r.alloc_id + d_aid,
                            start=r.start + d_addr, end=r.end + d_addr)
                      for r in proto_ranges]
        space.ranges.extend(new_ranges)
        for a in space.allocations[aid0:aid0 + len(self.leaf_bytes)]:
            space.allocations.append(Allocation(
                alloc_id=a.alloc_id + d_aid, name=a.name,
                start=a.start + d_addr, size=a.size))
            space._ranges_by_alloc[a.alloc_id + d_aid] = [
                new_ranges[r.rid - self.rid_base]
                for r in space._ranges_by_alloc[a.alloc_id]]
            space._cursor += a.size
        return ParamRanges(
            space=space,
            leaf_ranges={path: [rid + d_rid for rid in rids]
                         for path, rids in self.leaf_ranges.items()},
            leaf_bytes=dict(self.leaf_bytes),
            hbm_budget=self.hbm_budget,
            rid_base=self.rid_base + d_rid)


def plan_leaf_ranges(leaves: Sequence[tuple[str, int]], hbm_budget: int,
                     base: int = DEFAULT_BASE, *,
                     space: AddressSpace | None = None,
                     align_start: bool = False) -> ParamRanges:
    """Plan named byte-sized leaves into managed allocations + ranges.

    ``leaves`` is ``[(path, nbytes), ...]`` in fetch order.  Pass an
    existing ``space`` to co-tenant this plan with others in one shared
    pool; ``align_start=True`` pads the space's cursor to an alignment
    boundary first so congruent specs produce congruent plans."""
    if space is None:
        space = AddressSpace(hbm_budget, base=base)
    if align_start:
        space.pad_to_alignment()
    rid_base = len(space.ranges)
    leaf_ranges: dict[str, list[int]] = {}
    leaf_bytes: dict[str, int] = {}
    for path, nbytes in leaves:
        alloc = space.alloc(max(int(nbytes), 1), name=path)
        leaf_ranges[path] = [r.rid for r in space.ranges_of(alloc)]
        leaf_bytes[path] = int(nbytes)
    return ParamRanges(space=space, leaf_ranges=leaf_ranges,
                       leaf_bytes=leaf_bytes, hbm_budget=hbm_budget,
                       rid_base=rid_base)


def tree_leaf_sizes(params: PyTree) -> list[tuple[str, int]]:
    """(path, nbytes) for every leaf of a parameter tree, in tree order."""
    out = []
    for kp, leaf in jax.tree_util.tree_leaves_with_path(params):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize \
            if leaf.shape else leaf.dtype.itemsize
        out.append((_path_str(kp), nbytes))
    return out


def plan_param_ranges(params: PyTree, hbm_budget: int,
                      base: int = DEFAULT_BASE, *,
                      space: AddressSpace | None = None,
                      align_start: bool = False) -> ParamRanges:
    """Build the unified address space + range table for a param tree."""
    return plan_leaf_ranges(tree_leaf_sizes(params), hbm_budget, base,
                            space=space, align_start=align_start)
