"""Differential fuzzing: batched interpreters vs the scalar op loop.

Random address-space layouts and op mixes are replayed twice — once
op-for-op through `apply_trace` and once through `execute_compiled` on a
lowered `CompiledTrace` — and the full `summary()` dict, residency, free
bytes, pin set and victim-queue order are compared with ``==`` (the
engine's byte-identity contract, no tolerances).  SVM traces may include
eager ``spill`` ops; UVM traces must not (`UVMManager` has no
``spill_oldest`` and its batched interpreter rejects ``OP_SPILL``).

The seeded cores below always run.  When `hypothesis` is installed (CI
installs requirements-dev.txt; the local image may not have it) a thin
property wrapper widens the seed space."""

import numpy as np
import pytest

from repro.core import GB, MB
from repro.core.engine import compile_trace
from repro.core.engine import execute_compiled
from repro.core.ranges import AddressSpace
from repro.core.simulator import apply_trace
from repro.core.svm import SVMManager
from repro.core.uvm import UVMManager

SEEDS = tuple(range(12))


def random_space(rng) -> AddressSpace:
    """A random managed layout: 2-6 allocations, ragged sizes, the
    non-aligned 175 MB-style base offset from the paper's Fig. 2."""
    cap = int(rng.integers(24, 64)) * MB
    space = AddressSpace(cap, base=int(rng.integers(0, 8)) * MB + 1024,
                         alignment=2 * MB)
    for i in range(int(rng.integers(2, 7))):
        space.alloc(int(rng.integers(1, 20)) * MB // 2, f"a{i}")
    return space


def random_ops(rng, space: AddressSpace, n_ops: int, *,
               allow_spill: bool) -> list:
    """A random op mix over ``space``.  Pins are bounded (two live pins,
    released soon after) so a fuzz seed can't wedge the device full of
    pinned ranges — that failure mode has its own directed test in
    test_engine_equivalence.py."""
    n = len(space.ranges)
    ops = []
    pinned: list[int] = []
    weights = np.array([0.62, 0.12, 0.08, 0.05, 0.07, 0.06])
    kinds = np.array(["touch", "compute", "writeback", "pin", "unpin",
                      "spill"])
    if not allow_spill:
        weights, kinds = weights[:-1], kinds[:-1]
    weights = weights / weights.sum()
    for kind in rng.choice(kinds, size=n_ops, p=weights):
        if kind == "touch":
            ops.append(("touch", int(rng.integers(0, n)),
                        int(rng.choice([1, 8, 32, 64])),
                        int(rng.integers(0, 4))))
        elif kind == "compute":
            ops.append(("compute", float(rng.integers(1, 50)) * 1e-5))
        elif kind == "writeback":
            ops.append(("writeback", int(rng.integers(0, n))))
        elif kind == "pin" and len(pinned) < 2:
            rid = int(rng.integers(0, n))
            pinned.append(rid)
            ops.append(("pin", rid))
        elif kind == "unpin":
            rid = pinned.pop() if pinned else int(rng.integers(0, n))
            ops.append(("unpin", rid))
        elif kind == "spill":
            ops.append(("spill", int(rng.integers(1, 8)) * MB,
                        float(rng.choice([0.0, 0.5]))))
    ops.extend(("unpin", rid) for rid in pinned)
    return ops


def _queue(mgr):
    q = getattr(mgr.policy, "_q", getattr(mgr.policy, "_order", None))
    return None if q is None else list(q)


def assert_differential(seed: int, *, manager: str, policy: str = "lrf",
                        profile: bool = False) -> None:
    """The fuzz core: scalar replay ≡ batched replay, byte-for-byte."""
    rng = np.random.default_rng(seed)
    svm = manager == "svm"
    sa, sb = random_space(rng), random_space(np.random.default_rng(seed))
    assert [r.size for r in sa.ranges] == [r.size for r in sb.ranges]
    ops = random_ops(rng, sa, int(rng.integers(50, 400)),
                     allow_spill=svm)
    if svm:
        ma = SVMManager(sa, policy=policy, profile=profile)
        mb = SVMManager(sb, policy=policy, profile=profile)
    else:
        ma = UVMManager(sa, profile=profile)
        mb = UVMManager(sb, profile=profile)
    apply_trace(ma, iter(ops))
    ct = compile_trace(iter(ops))
    assert len(ct) == len(ops)
    execute_compiled(ct, mb)
    assert ma.summary() == mb.summary()
    assert ma.resident == mb.resident
    assert ma.free == mb.free
    if svm:
        assert ma.pinned == mb.pinned
        assert _queue(ma) == _queue(mb)
    if profile and svm:
        assert ma.events == mb.events


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_svm_differential(seed):
    assert_differential(seed, manager="svm")


@pytest.mark.parametrize("policy", ("lru", "clock", "random"))
def test_fuzz_svm_policies(policy):
    for seed in SEEDS[:4]:
        assert_differential(seed, manager="svm", policy=policy)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_fuzz_svm_profiled(seed):
    assert_differential(seed, manager="svm", profile=True)


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_uvm_differential(seed):
    assert_differential(seed, manager="uvm")


def test_uvm_batched_rejects_spill():
    """The guard the fuzz generator relies on: lowering a spill op into
    the UVM interpreter is a loud error, not a silent skip."""
    space = AddressSpace(8 * MB, alignment=2 * MB)
    space.alloc(4 * MB, "a")
    ct = compile_trace(iter([("touch", 0, 32, 0),
                             ("spill", 1 * MB, 0.0)]))
    with pytest.raises((ValueError, NotImplementedError, RuntimeError)):
        execute_compiled(ct, UVMManager(space))


def test_fuzz_trace_reexecution_stable():
    """A lowered fuzz trace replays identically on fresh managers."""
    rng = np.random.default_rng(99)
    space = random_space(rng)
    ops = random_ops(rng, space, 200, allow_spill=True)
    ct = compile_trace(iter(ops))
    runs = []
    for _ in range(2):
        s2 = random_space(np.random.default_rng(99))
        m = SVMManager(s2, profile=False)
        execute_compiled(ct, m)
        runs.append(m.summary())
    assert runs[0] == runs[1]


def test_fuzz_touch_columns_match_ops():
    """The profiler-facing touch columns mirror the touch ops exactly
    (positions ascending, rids in op order) — the contract hotset.py's
    estimator is built on."""
    rng = np.random.default_rng(7)
    space = random_space(rng)
    ops = random_ops(rng, space, 300, allow_spill=True)
    ct = compile_trace(iter(ops))
    pos, rid = ct.touch_columns()
    expect = [(i, op[1]) for i, op in enumerate(ops)
              if op[0] == "touch"]
    assert pos.tolist() == [p for p, _ in expect]
    assert rid.tolist() == [r for _, r in expect]
    counts = ct.touch_counts(minlength=len(space.ranges))
    assert counts.tolist() == np.bincount(
        [r for _, r in expect], minlength=len(space.ranges)).tolist()


# ------------------------------------------------ hypothesis widening
# Guarded import (not importorskip) so the seeded cores above still run
# on images without the dev extras; CI installs requirements-dev.txt and
# gets the widened property pass.

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

if given is not None:
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           manager=st.sampled_from(["svm", "uvm"]))
    @settings(max_examples=40, deadline=None)
    def test_fuzz_property_differential(seed, manager):
        assert_differential(seed, manager=manager)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fuzz_property_differential():
        pass
