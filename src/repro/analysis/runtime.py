"""Runtime audit backing the ``frozen-mutation`` lint rule.

The static rule catches *source* that writes into compiled-trace
columns; this module checks the *object*: after `CompiledTrace.freeze`
(including `relocate`/`concat` outputs and `SegmentCache` hits) every
op-column array must report ``writeable=False``.  Tests run it over
freshly compiled, concatenated, and relocated traces so a regression in
any freeze path fails loudly instead of corrupting a shared trace.
"""

from __future__ import annotations

import numpy as np

#: CompiledTrace op-column attribute names (mirrors rules.COLUMN_FIELDS;
#: kept literal here so the runtime audit has no import-order coupling
#: with the AST layer)
COLUMN_FIELDS = ("codes", "rids", "concs", "hints", "fargs", "boundaries",
                 "touch_pos_np", "touch_rid_np", "seg_bounds")


def frozen_violations(ct) -> list[str]:
    """Column names of ``ct`` that are missing, non-array, or writeable.

    ``seg_bounds`` is optional (None outside concat mega-traces); every
    other column must be a read-only ndarray.
    """
    bad: list[str] = []
    for field in COLUMN_FIELDS:
        arr = getattr(ct, field, None)
        if arr is None:
            if field != "seg_bounds":
                bad.append(f"{field}: missing")
            continue
        if not isinstance(arr, np.ndarray):
            bad.append(f"{field}: not an ndarray ({type(arr).__name__})")
        elif arr.flags.writeable:
            bad.append(f"{field}: writeable=True after freeze")
    return bad


def assert_frozen(ct, where: str = "trace") -> None:
    """Raise ``AssertionError`` naming every unfrozen column of ``ct``."""
    bad = frozen_violations(ct)
    if bad:
        raise AssertionError(
            f"frozen-column audit failed for {where}: " + "; ".join(bad))
