"""Grouped-query attention with RoPE, sliding windows, KV caches, and
cross-attention — shared by every attention-bearing architecture."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init

Array = jax.Array

NEG_INF = -2.0e38


def attn_init(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, nq)),
        "wk": dense_init(k2, (d, nkv)),
        "wv": dense_init(k3, (d, nkv)),
        "wo": dense_init(k4, (nq, d)),
    }


def _split_heads(x: Array, n: int, hd: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_scores(q: Array, k: Array) -> Array:
    """q: (B,S,KV,G,D), k: (B,T,KV,D) -> (B,KV,G,S,T)."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def _gqa_out(w: Array, v: Array) -> Array:
    """w: (B,KV,G,S,T), v: (B,T,KV,D) -> (B,S,KV,G,D)."""
    return jnp.einsum("bkgst,btkd->bskgd", w, v)


def _softmax(scores: Array) -> Array:
    s = scores.astype(jnp.float32)
    s = s - jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    w = jnp.exp(s)
    return (w / jnp.sum(w, axis=-1, keepdims=True))


# ------------------------------------------------------- blockwise attention

BLOCK_T = 512
# use the blockwise path once the (S, T) score matrix would exceed this
FLASH_THRESHOLD = 4096 * 4096


def _blockwise_attention(
    q: Array,            # (B,S,KV,G,D), already scaled
    k: Array,            # (B,T,KV,D)
    v: Array,            # (B,T,KV,D)
    qpos: Array,         # (B,S) absolute query positions
    kpos: Array,         # (B,T) absolute key positions
    window: int,
) -> Array:
    """Flash-semantics attention: lax.scan over KV blocks with running
    (max, denom, acc) — the S×T score matrix is never materialised, only a
    (B,KV,G,S,BLOCK_T) transient per step. The KV-block body is rematted so
    the backward pass recomputes block scores instead of storing them."""
    B, S, KV, G, D = q.shape
    T = k.shape[1]
    pad = (-T) % BLOCK_T
    nblk = (T + pad) // BLOCK_T
    SENTINEL = jnp.iinfo(jnp.int32).max

    def blocked(x, fill=0.0):
        cfg = [(0, 0)] * x.ndim
        cfg[1] = (0, pad)
        x = jnp.pad(x, cfg, constant_values=fill)
        return jnp.moveaxis(
            x.reshape(x.shape[0], nblk, BLOCK_T, *x.shape[2:]), 1, 0)

    kb, vb = blocked(k), blocked(v)                      # (nblk,B,BT,KV,D)
    kpb = blocked(kpos.astype(jnp.int32), fill=SENTINEL)  # (nblk,B,BT)
    qp = qpos[:, None, None, :, None]                    # (B,1,1,S,1)

    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, kp_i = inp
        s = jnp.einsum("bskgd,btkd->bkgst", q, k_i).astype(jnp.float32)
        tp = kp_i[:, None, None, None, :]                # (B,1,1,1,BT)
        mask = (tp <= qp) & (tp != SENTINEL)
        if window:
            mask &= (qp - tp) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v_i.dtype), v_i
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, D), jnp.float32)
    body = jax.checkpoint(body, prevent_cse=False)
    (_, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,KV,G,S,D)
    out = jnp.moveaxis(out, 3, 1)                        # (B,S,KV,G,D)
    return out.astype(v.dtype).reshape(B, S, KV * G * D)


def _attend(q, k, v, qpos, kpos, window) -> Array:
    """Dispatch between direct and blockwise attention.
    q: (B,S,KV,G,D) scaled; k/v: (B,T,KV,D); qpos/kpos None => non-causal.
    Returns (B,S,H*D)."""
    B, S, KV, G, D = q.shape
    T = k.shape[1]
    causal = qpos is not None
    if S * T > FLASH_THRESHOLD:
        if not causal:  # non-causal: all positions visible, pad masked out
            qpos = jnp.full((1, S), T, jnp.int32)
            kpos = jnp.arange(T, dtype=jnp.int32)[None, :]
        return _blockwise_attention(q, k, v, qpos, kpos, window)
    scores = _gqa_scores(q, k)
    if causal:
        tp = kpos[:, None, None, None, :]
        qp = qpos[:, None, None, :, None]
        mask = tp <= qp
        if window:
            mask &= (qp - tp) < window
        scores = jnp.where(mask, scores, NEG_INF)
    w = _softmax(scores).astype(v.dtype)
    o = _gqa_out(w, v)
    return o.reshape(B, S, KV * G * D)


def self_attention(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    *,
    positions: Array,             # (B, S) absolute positions of queries
    window: int = 0,              # 0 => global causal
    theta: float | None = None,
    cache: Optional[dict] = None,  # decode: {"k","v","pos"} rolling buffers
) -> tuple[Array, Optional[dict]]:
    """Causal (optionally sliding-window) GQA self-attention.

    Train/prefill: cache is None -> attends within the sequence, returns the
    (rope-applied) K/V so the caller can build a cache.
    Decode: cache given, S == 1 -> appends to the rolling buffer and attends
    over it.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    th = cfg.rope_theta if theta is None else theta

    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    q = apply_rope(q, positions, th, cfg.partial_rotary)
    k = apply_rope(k, positions, th, cfg.partial_rotary)
    q = q.reshape(B, S, KV, G, hd) * (hd ** -0.5)

    if cache is None:
        kv_pos = positions                                     # (B, S)
        o = _attend(q, k, v, positions, kv_pos, window)        # (B,S,H*hd)
        new_cache = {"k": k, "v": v, "pos": kv_pos.astype(jnp.int32)}
        return o @ p["wo"], new_cache

    # ---------------- decode: S == 1, rolling buffer of width Wbuf
    Wbuf = cache["k"].shape[2]                                 # (B,KV,W,hd)
    qpos = positions[:, 0]                                     # (B,)
    slot = (qpos % Wbuf).astype(jnp.int32)
    k_new = jnp.swapaxes(k, 1, 2)                              # (B,KV,1,hd)
    v_new = jnp.swapaxes(v, 1, 2)
    bidx = jnp.arange(B)
    ck = cache["k"].at[bidx, :, slot].set(k_new[:, :, 0])
    cv = cache["v"].at[bidx, :, slot].set(v_new[:, :, 0])
    cpos = cache["pos"].at[bidx, slot].set(qpos.astype(jnp.int32))
    scores = _gqa_scores(q, jnp.swapaxes(ck, 1, 2))            # (B,KV,G,1,W)
    tp = cpos[:, None, None, None, :]
    qp = qpos[:, None, None, None, None]
    mask = (tp >= 0) & (tp <= qp)
    if window:
        mask &= (qp - tp) < window
    scores = jnp.where(mask, scores, NEG_INF)
    w = _softmax(scores).astype(v.dtype)
    o = _gqa_out(w, jnp.swapaxes(cv, 1, 2)).reshape(B, 1, H * hd)
    return o @ p["wo"], {"k": ck, "v": cv, "pos": cpos}


def cross_attention(p: dict, cfg: ModelConfig, x: Array, ctx: Array) -> Array:
    """Cross-attention onto a static context (image patches / encoder out).
    No positional rotation (context is an unordered/pre-encoded set)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    q = _split_heads(x @ p["wq"], H, hd).reshape(B, S, KV, G, hd) * (hd ** -0.5)
    k = _split_heads(ctx @ p["wk"], KV, hd)
    v = _split_heads(ctx @ p["wv"], KV, hd)
    o = _attend(q, k, v, None, None, 0)
    return o @ p["wo"]


def encoder_self_attention(p: dict, cfg: ModelConfig, x: Array) -> Array:
    """Bidirectional (non-causal) self-attention for encoder stacks."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    q = apply_rope(q, pos, cfg.rope_theta, cfg.partial_rotary)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.partial_rotary)
    q = q.reshape(B, S, KV, G, hd) * (hd ** -0.5)
    o = _attend(q, k, v, None, None, 0)
    return o @ p["wo"]
