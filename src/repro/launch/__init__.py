"""Distributed launch layer: mesh construction, GSPMD sharding rules,
train/serve steps, the multi-pod dry-run, and roofline analysis.

NOTE: this package must stay import-light — dryrun.py sets XLA_FLAGS before
its own jax import, and importing repro.launch must never initialise a jax
backend."""
