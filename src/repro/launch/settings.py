"""Per-(arch x shape) launch settings: the input-shape table assigned to this
paper, per-arch memory strategy (microbatching, FSDP, optimizer flavour),
and the long_500k applicability list (see DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------- shapes

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k runs only for sub-quadratic archs (SSM / hybrid / SWA-bounded);
# pure full-attention archs skip it (documented in DESIGN.md).
LONG_CONTEXT_ARCHS = {
    "gemma3-1b",        # 5:1 local(sw=512):global
    "mixtral-8x7b",     # SWA-4096 everywhere
    "jamba-1.5-large-398b",  # 63/72 layers O(1)-state mamba
    "falcon-mamba-7b",  # attention-free
}


def cells(arch_ids) -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, with documented skips."""
    out = []
    for a in arch_ids:
        for s in SHAPES:
            out.append((a, s))
    return out


def cell_skipped(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return "pure full-attention arch: 500k decode skipped per DESIGN.md"
    return None


# ------------------------------------------------------- per-arch strategy

@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1        # grad-accumulation microbatches (train_4k)
    fsdp_train: bool = False     # shard params over data axes for train
    fsdp_serve: bool = False     # ... and for serve (398B-class only)
    optimizer: str = "adamw"     # "adamw" | "adafactor"


TRAIN_SETTINGS: dict[str, TrainSettings] = {
    "gemma3-1b": TrainSettings(),
    "granite-3-2b": TrainSettings(microbatches=4),
    "chatglm3-6b": TrainSettings(microbatches=4, fsdp_train=True),
    "granite-20b": TrainSettings(microbatches=4, fsdp_train=True),
    "mixtral-8x7b": TrainSettings(microbatches=8, fsdp_train=True),
    "granite-moe-1b-a400m": TrainSettings(microbatches=2),
    "jamba-1.5-large-398b": TrainSettings(
        microbatches=4, fsdp_train=True, fsdp_serve=True,
        optimizer="adafactor"),
    "falcon-mamba-7b": TrainSettings(microbatches=16, fsdp_train=True),
    "llama-3.2-vision-11b": TrainSettings(microbatches=4, fsdp_train=True),
    "seamless-m4t-medium": TrainSettings(microbatches=2),
}


def settings_for(arch: str) -> TrainSettings:
    return TRAIN_SETTINGS.get(arch, TrainSettings())
