"""Access-trace generators for the paper's workload suite (Table 2).

Each workload lays out its managed allocations and yields a lazy op trace
capturing the *access pattern class* the paper analyses.  Every workload
additionally implements ``emit_columns(space)`` — the columnar compile
tier: the engine's flat op columns are constructed directly with
`np.repeat`/`np.tile`/`np.arange` over range-id arrays, op-for-op
identical to lowering the ``trace()`` generator (which stays the golden
reference; see tests/test_columnar_traces.py) but without materialising
per-op tuples.

The pattern classes:

  Category I   — STREAM, Conv2d, BFS: linear streaming, no (or algorithmic)
                 reuse → permanent evictions only.
  Category II  — Jacobi2d: repeated linear traversal (two kernels per
                 iteration) → cyclic premature eviction under LRF.
  Category III — SGEMM/SYR2K: intense factor reuse (row-panel × all-columns)
                 → chain thrashing; MVT/GESUMMV: concurrent accesses
                 dispersed across all ranges (BLAS-2 thread-per-row) →
                 wavefront-retry thrashing.

Calibration notes (documented in EXPERIMENTS.md §Validation):
  * `concurrency` sets per-migration duplicate-fault counts (fault density),
    calibrated to paper Fig. 8/9 (STREAM≈200 … GESUMMV≈20).
  * Jacobi2d per-touch compute folds the fault/compute overlap a serial
    trace cannot express; the value (≈70 GB/s effective) is calibrated so
    the DOS=109 relative performance lands at the paper's 0.40.
  * Wave workloads (MVT/GESUMMV) amplify same-page XNACK replay with a
    static retry factor  retries = AMP·(WS/C_eff − 1)  (AMP=200, capped),
    reproducing the paper's ≈0.05 serviceable-faults-per-migration under
    thrash. The *onset* and *category* behaviour are structural (capacity
    pressure + LRF), only the replay multiplicity is calibrated.
"""

from __future__ import annotations

import math
from typing import Iterator

import numpy as np

from repro.core.engine import ColumnEmitter, CompiledTrace
from repro.core.ranges import AddressSpace, GB, MB
from repro.core.simulator import Op, Workload

PEAK_FLOPS = 24e12       # MI250X GCD fp32 vector peak
HBM_BW = 1.6e12          # MI250X GCD HBM2e bandwidth

WAVE_RETRY_AMP = 200.0   # XNACK-replay amplification under thrash
WAVE_RETRY_CAP = 400


def _rids(space: AddressSpace, alloc) -> list[int]:
    return [r.rid for r in space.ranges_of(alloc)]


def _rid_arr(space: AddressSpace, alloc) -> np.ndarray:
    rs = space.ranges_of(alloc)   # rids are consecutive per allocation
    return np.arange(rs[0].rid, rs[-1].rid + 1, dtype=np.int64)


def _sizes(space: AddressSpace) -> np.ndarray:
    return space.size_array()


def _interleave(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a[0], b[0], a[1], b[1], … with the longer array's tail appended —
    the ``for i in range(max(la, lb)): if i < la … if i < lb …`` pattern."""
    m = min(len(a), len(b))
    out = np.empty(len(a) + len(b), dtype=np.int64)
    out[0:2 * m:2] = a[:m]
    out[1:2 * m:2] = b[:m]
    out[2 * m:] = a[m:] if len(a) > m else b[m:]
    return out


def _multi_arange(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenation of ``arange(starts[i], starts[i] + lens[i])``."""
    total = int(lens.sum())
    cum = np.cumsum(lens) - lens
    return np.repeat(starts - cum, lens) + np.arange(total)


class Stream(Workload):
    """Triad a[i] = b[i] + s*c[i] — linear single pass, 3 equal allocations."""

    name = "stream"
    concurrency = 200

    def build(self, space: AddressSpace) -> None:
        third = self.total_bytes // 3
        self.a = space.alloc(third, "a")
        self.b = space.alloc(third, "b")
        self.c = space.alloc(third, "c")

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        yield ("kernel", "triad")
        ra, rb, rc = (_rids(space, x) for x in (self.a, self.b, self.c))
        n = min(len(ra), len(rb), len(rc))
        for i in range(n):
            for rid in (rb[i], rc[i], ra[i]):
                yield ("touch", rid, self.concurrency, 0)
            nbytes = sum(space.ranges[r].size for r in (rb[i], rc[i], ra[i]))
            yield ("compute", nbytes / HBM_BW)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        em.kernel()
        sz = _sizes(space)
        ra, rb, rc = (_rid_arr(space, x) for x in (self.a, self.b, self.c))
        n = min(len(ra), len(rb), len(rc))
        ra, rb, rc = ra[:n], rb[:n], rc[:n]
        em.rows(np.stack([rb, rc, ra], axis=1), self.concurrency,
                (sz[rb] + sz[rc] + sz[ra]) / HBM_BW)
        return em.finish()


class Conv2d(Workload):
    """Full 2-D convolution: linear in/out streams + small weight alloc."""

    name = "conv2d"
    concurrency = 130
    FLOPS_PER_BYTE = 12.0   # ~K*K MACs per element, K≈5

    def build(self, space: AddressSpace) -> None:
        w = min(64 * MB, max(2 * MB, self.total_bytes // 100))
        half = (self.total_bytes - w) // 2
        self.inp = space.alloc(half, "input")
        self.out = space.alloc(half, "output")
        self.wgt = space.alloc(w, "weights")

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        yield ("kernel", "conv2d")
        for rid in _rids(space, self.wgt):
            yield ("touch", rid, self.concurrency, 0)
        ri, ro = _rids(space, self.inp), _rids(space, self.out)
        for i in range(min(len(ri), len(ro))):
            yield ("touch", ri[i], self.concurrency, 0)
            yield ("touch", ro[i], self.concurrency, 0)
            nb = space.ranges[ri[i]].size + space.ranges[ro[i]].size
            yield ("compute", nb * self.FLOPS_PER_BYTE / PEAK_FLOPS
                   + nb / HBM_BW)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        em.kernel()
        em.touches(_rid_arr(space, self.wgt), self.concurrency)
        sz = _sizes(space)
        ri, ro = _rid_arr(space, self.inp), _rid_arr(space, self.out)
        n = min(len(ri), len(ro))
        ri, ro = ri[:n], ro[:n]
        nb = sz[ri] + sz[ro]
        em.rows(np.stack([ri, ro], axis=1), self.concurrency,
                nb * self.FLOPS_PER_BYTE / PEAK_FLOPS + nb / HBM_BW)
        return em.finish()


class Jacobi2d(Workload):
    """Two alternating 5-point stencil kernels over matrices A, B.

    Naive: both kernels traverse first→last row (paper Alg. 1) — under LRF
    this is cyclic reuse and every touch misses once oversubscribed.
    SVM-aware: the second kernel traverses last→first (paper Alg. 2),
    reusing the still-resident tail.
    """

    name = "jacobi2d"
    concurrency = 95
    ITERS = 2
    # seconds of compute per byte touched; folds fault/compute overlap —
    # calibrated to paper's 0.40 relative perf at DOS=109 (≈60 GB/s eff.)
    INTENSITY = 5.9e-11

    def __init__(self, total_bytes: int, svm_aware: bool = False):
        super().__init__(total_bytes)
        self.svm_aware = svm_aware
        if svm_aware:
            self.name = "jacobi2d-svm-aware"

    def build(self, space: AddressSpace) -> None:
        half = self.total_bytes // 2
        self.A = space.alloc(half, "A")
        self.B = space.alloc(half, "B")

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        ra, rb = _rids(space, self.A), _rids(space, self.B)
        n = min(len(ra), len(rb))
        for it in range(self.ITERS):
            yield ("kernel", f"jacobi_k1_iter{it}")
            for i in range(n):  # B <- stencil(A): read A_i, write B_i
                yield ("touch", ra[i], self.concurrency, 0)
                yield ("touch", rb[i], self.concurrency, 0)
                nb = space.ranges[ra[i]].size + space.ranges[rb[i]].size
                yield ("compute", nb * self.INTENSITY)
            yield ("kernel", f"jacobi_k2_iter{it}")
            order = range(n - 1, -1, -1) if self.svm_aware else range(n)
            for i in order:  # A <- stencil(B)
                yield ("touch", rb[i], self.concurrency, 0)
                yield ("touch", ra[i], self.concurrency, 0)
                nb = space.ranges[ra[i]].size + space.ranges[rb[i]].size
                yield ("compute", nb * self.INTENSITY)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        sz = _sizes(space)
        ra, rb = _rid_arr(space, self.A), _rid_arr(space, self.B)
        n = min(len(ra), len(rb))
        ra, rb = ra[:n], rb[:n]
        f = (sz[ra] + sz[rb]) * self.INTENSITY
        k1 = np.stack([ra, rb], axis=1)
        k2 = np.stack([rb, ra], axis=1)
        for _ in range(self.ITERS):
            em.kernel()
            em.rows(k1, self.concurrency, f)
            em.kernel()
            if self.svm_aware:
                em.rows(k2[::-1], self.concurrency, f[::-1])
            else:
                em.rows(k2, self.concurrency, f)
        return em.finish()

    def work_units(self) -> float:
        return float(self.total_bytes * 2 * self.ITERS)


class BFS(Workload):
    """EMOGI-style BFS: per-level linear windows over the edge list, sparse
    node accesses, frontier written back to the host each level."""

    name = "bfs"
    concurrency = 12
    LEVEL_FRACS = (0.04, 0.12, 0.30, 0.28, 0.15, 0.07, 0.03)

    def build(self, space: AddressSpace) -> None:
        self.nodes = space.alloc(int(self.total_bytes * 0.10), "nodes")
        self.edges = space.alloc(int(self.total_bytes * 0.85), "edges")
        self.front = space.alloc(
            max(2 * MB, int(self.total_bytes * 0.05)), "frontier")

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        re = _rids(space, self.edges)
        rn = _rids(space, self.nodes)
        rf = _rids(space, self.front)
        off = 0
        for lvl, frac in enumerate(self.LEVEL_FRACS):
            yield ("kernel", f"bfs_level{lvl}")
            win = max(1, int(len(re) * frac))
            for j in range(win):  # linear window across edge ranges
                yield ("touch", re[(off + j) % len(re)], self.concurrency, lvl)
            off += win
            for j in range(0, len(rn), 3):  # sparse node accesses
                yield ("touch", rn[j], self.concurrency, lvl)
            nb = sum(space.ranges[re[(off - win + j) % len(re)]].size
                     for j in range(win))
            yield ("compute", nb * 2.0 / HBM_BW)
            for rid in rf:  # algorithmic device→host frontier output
                yield ("touch", rid, self.concurrency, lvl)
                yield ("writeback", rid)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        sz = _sizes(space)
        re = _rid_arr(space, self.edges)
        rn = _rid_arr(space, self.nodes)
        rf = _rid_arr(space, self.front)
        off = 0
        for lvl, frac in enumerate(self.LEVEL_FRACS):
            em.kernel()
            win = max(1, int(len(re) * frac))
            w_rids = re[(off + np.arange(win)) % len(re)]
            em.touches(w_rids, self.concurrency, lvl)
            off += win
            em.touches(rn[::3], self.concurrency, lvl)
            em.compute(int(sz[w_rids].sum()) * 2.0 / HBM_BW)
            em.touch_writeback(rf, self.concurrency, lvl)
        return em.finish()

    def work_units(self) -> float:
        return float(self.total_bytes * sum(self.LEVEL_FRACS))


class _GemmLike(Workload):
    """Shared structure for SGEMM / SYR2K: migrate factors, then row-panel
    waves that re-traverse whole factor allocations (intense reuse)."""

    WAVE_ROWS = 256
    dtype_bytes = 4

    def __init__(self, total_bytes: int, svm_aware: bool = False):
        super().__init__(total_bytes)
        self.svm_aware = svm_aware
        if svm_aware:
            self.name = self.name + "-svm-aware"

    def build(self, space: AddressSpace) -> None:
        third = self.total_bytes // 3
        self.A = space.alloc(third, "A")
        self.B = space.alloc(third, "B")
        self.C = space.alloc(third, "C")
        self.n = max(1, int(math.isqrt(third // self.dtype_bytes)))

    def _waves(self) -> int:
        return max(1, math.ceil(self.n / self.WAVE_ROWS))

    def work_units(self) -> float:
        return 2.0 * float(self.n) ** 3

    def _panel(self, rids, w: int, waves: int):
        """Contiguous range slice for wave w's row panel (list or array)."""
        lo = int(w * len(rids) / waves)
        hi = max(lo + 1, int((w + 1) * len(rids) / waves))
        return rids[lo:hi]


class Sgemm(_GemmLike):
    """C = A·B. Naive (rocBLAS-profile-alike, paper §4.1): migrate both
    factors fully, then compute C row-panels, each re-reading all of B —
    LRF chain-thrashes the factors once C fills the device.
    SVM-aware: pin B on-device, stream A/C row panels with partial sums
    (paper's SGEMM-svm-aware; valid while B fits, i.e. DOS ≲ 300)."""

    name = "sgemm"
    concurrency = 40

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        ra, rb, rc = (_rids(space, x) for x in (self.A, self.B, self.C))
        waves = self._waves()
        flops_per_wave = self.work_units() / waves

        if self.svm_aware:
            yield ("kernel", "sgemm_pin_B")
            for rid in rb:
                yield ("pin", rid)
        else:
            yield ("kernel", "sgemm_migrate_factors")
            for i in range(max(len(ra), len(rb))):
                if i < len(ra):
                    yield ("touch", ra[i], self.concurrency, 0)
                if i < len(rb):
                    yield ("touch", rb[i], self.concurrency, 0)

        yield ("kernel", "sgemm_compute")
        for w in range(waves):
            apanel = self._panel(ra, w, waves)
            cpanel = self._panel(rc, w, waves)
            for rid in apanel:                      # A row panel
                yield ("touch", rid, self.concurrency, 0)
            if not self.svm_aware:
                # Blocked-GEMM aggregate access: every wave of product
                # blocks re-reads all of B, and — once the accumulating
                # product rows overflow the device — also the LRF-churned
                # slice of A (paper Fig. 12a: BOTH factors thrash; §4.1:
                # "chain of thrashing over factor matrix elements"). The
                # churned slice grows with the overflow fraction: LRF keeps
                # evicting the oldest-faulted factor ranges (blind to their
                # reuse) and every re-migration displaces further factor
                # data.
                for rid in rb:                      # all of B, every wave
                    yield ("touch", rid, self.concurrency, 0)
                overflow = (self.A.size + self.B.size
                            + self.C.size * (w + 1) / waves
                            ) / space.capacity - 1.0
                frac = min(1.0, max(0.0, 2.0 * overflow))
                churn = int(frac * len(ra))
                for j in range(churn):              # churned A slice
                    yield ("touch", ra[(w + j) % len(ra)],
                           self.concurrency, 0)
            for rid in cpanel:                      # C output panel
                yield ("touch", rid, self.concurrency, 0)
            yield ("compute", flops_per_wave / PEAK_FLOPS)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        ra, rb, rc = (_rid_arr(space, x) for x in (self.A, self.B, self.C))
        waves = self._waves()
        cval = (self.work_units() / waves) / PEAK_FLOPS
        conc = self.concurrency
        em.kernel()
        if self.svm_aware:
            em.pins(rb)
            em.kernel()
            self._emit_aware_waves(em, ra, rc, waves, conc, cval)
            return em.finish()
        em.touches(_interleave(ra, rb), conc)
        em.kernel()
        la = len(ra)
        for w in range(waves):
            em.touches(self._panel(ra, w, waves), conc)
            em.touches(rb, conc)
            overflow = (self.A.size + self.B.size
                        + self.C.size * (w + 1) / waves
                        ) / space.capacity - 1.0
            frac = min(1.0, max(0.0, 2.0 * overflow))
            churn = int(frac * la)
            if churn:
                em.touches(ra[(w + np.arange(churn)) % la], conc)
            em.touches(self._panel(rc, w, waves), conc)
            em.compute(cval)
        return em.finish()

    def _emit_aware_waves(self, em: ColumnEmitter, ra, rc, waves, conc,
                          cval) -> None:
        """All svm-aware waves ([A panel, C panel, compute] each) as one
        vectorised block.  Panel bounds replicate `_panel`'s float-division
        truncation exactly (quotients are far from integers relative to
        one ulp, so `astype(int64)` == `int()` op-for-op)."""
        from repro.core.engine import OP_COMPUTE, OP_TOUCH

        w = np.arange(waves)
        la, lc = len(ra), len(rc)
        lo_a = (w * la / waves).astype(np.int64)
        hi_a = np.maximum(lo_a + 1, ((w + 1) * la / waves).astype(np.int64))
        lo_c = (w * lc / waves).astype(np.int64)
        hi_c = np.maximum(lo_c + 1, ((w + 1) * lc / waves).astype(np.int64))
        len_a, len_c = hi_a - lo_a, hi_c - lo_c
        per_wave = len_a + len_c + 1
        n = int(per_wave.sum())
        wave_off = np.cumsum(per_wave) - per_wave
        a_pos = _multi_arange(wave_off, len_a)
        c_pos = _multi_arange(wave_off + len_a, len_c)
        comp_pos = wave_off + len_a + len_c
        codes = np.full(n, OP_TOUCH, dtype=np.int8)
        codes[comp_pos] = OP_COMPUTE
        rids = np.empty(n, dtype=np.int64)
        rids[a_pos] = ra[_multi_arange(lo_a, len_a)]
        rids[c_pos] = rc[_multi_arange(lo_c, len_c)]
        rids[comp_pos] = -1
        concs = np.full(n, conc, dtype=np.int64)
        concs[comp_pos] = 0
        fargs = np.zeros(n)
        fargs[comp_pos] = cval
        em.raw(codes, rids, concs, np.zeros(n, dtype=np.int64), fargs)


class Syr2k(_GemmLike):
    """C = α·A·Bᵀ + α·B·Aᵀ + C — both factors fully re-traversed per
    row-panel wave (even more reuse than SGEMM)."""

    name = "syr2k"
    concurrency = 45

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        ra, rb, rc = (_rids(space, x) for x in (self.A, self.B, self.C))
        waves = self._waves()
        flops_per_wave = 2.0 * self.work_units() / waves
        yield ("kernel", "syr2k_migrate_factors")
        for i in range(max(len(ra), len(rb))):
            if i < len(ra):
                yield ("touch", ra[i], self.concurrency, 0)
            if i < len(rb):
                yield ("touch", rb[i], self.concurrency, 0)
        yield ("kernel", "syr2k_compute")
        for w in range(waves):
            for rid in self._panel(ra, w, waves) + self._panel(rb, w, waves):
                yield ("touch", rid, self.concurrency, 0)
            for rid in ra:
                yield ("touch", rid, self.concurrency, 0)
            for rid in rb:
                yield ("touch", rid, self.concurrency, 0)
            for rid in self._panel(rc, w, waves):
                yield ("touch", rid, self.concurrency, 0)
            yield ("compute", flops_per_wave / PEAK_FLOPS)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        ra, rb, rc = (_rid_arr(space, x) for x in (self.A, self.B, self.C))
        waves = self._waves()
        cval = (2.0 * self.work_units() / waves) / PEAK_FLOPS
        conc = self.concurrency
        em.kernel()
        em.touches(_interleave(ra, rb), conc)
        em.kernel()
        for w in range(waves):
            em.touches(np.concatenate([self._panel(ra, w, waves),
                                       self._panel(rb, w, waves)]), conc)
            em.touches(ra, conc)
            em.touches(rb, conc)
            em.touches(self._panel(rc, w, waves), conc)
            em.compute(cval)
        return em.finish()


def _wave_retries(ws_bytes: int, other_bytes: int, capacity: int) -> int:
    """Static XNACK-replay amplification for dispersed-access waves."""
    c_eff = max(capacity - other_bytes, 1)
    ratio = ws_bytes / c_eff
    if ratio <= 1.0:
        return 1
    return min(WAVE_RETRY_CAP, max(1, round(WAVE_RETRY_AMP * (ratio - 1.0))))


class Mvt(Workload):
    """x1 = A·y1 then x2 = Aᵀ·y2 — the transpose pass disperses concurrent
    accesses across every range of A (paper's spatial Category-III type)."""

    name = "mvt"
    concurrency = 25
    WAVE_COLS = 8192
    dtype_bytes = 4

    def __init__(self, total_bytes: int, retry_override: int | None = None):
        super().__init__(total_bytes)
        self.retry_override = retry_override

    def build(self, space: AddressSpace) -> None:
        vec = max(2 * MB, int(self.total_bytes * 0.005))
        self.A = space.alloc(self.total_bytes - 4 * vec, "A")
        self.vecs = [space.alloc(vec, f"v{i}") for i in range(4)]
        self.n = max(1, int(math.isqrt(self.A.size // self.dtype_bytes)))

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        ra = _rids(space, self.A)
        for v in self.vecs:
            for rid in _rids(space, v):
                yield ("touch", rid, self.concurrency, 0)
        yield ("kernel", "mvt_row_pass")  # x1 = A·y1 — linear
        for rid in ra:
            yield ("touch", rid, self.concurrency, 0)
        yield ("compute", 2.0 * self.A.size / self.dtype_bytes / PEAK_FLOPS)
        yield ("kernel", "mvt_col_pass")  # x2 = Aᵀ·y2 — dispersed waves
        waves = max(1, math.ceil(self.n / self.WAVE_COLS))
        other = sum(v.size for v in self.vecs)
        retries = (self.retry_override if self.retry_override is not None
                   else _wave_retries(self.A.size, other, space.capacity))
        for w in range(waves):
            for _ in range(retries):
                for rid in ra:
                    yield ("touch", rid, self.concurrency, 1 + w)
            yield ("compute",
                   2.0 * self.A.size / self.dtype_bytes / PEAK_FLOPS / waves)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        conc = self.concurrency
        for v in self.vecs:
            em.touches(_rid_arr(space, v), conc)
        ra = _rid_arr(space, self.A)
        em.kernel()
        em.touches(ra, conc)
        em.compute(2.0 * self.A.size / self.dtype_bytes / PEAK_FLOPS)
        em.kernel()
        waves = max(1, math.ceil(self.n / self.WAVE_COLS))
        other = sum(v.size for v in self.vecs)
        retries = (self.retry_override if self.retry_override is not None
                   else _wave_retries(self.A.size, other, space.capacity))
        cval = 2.0 * self.A.size / self.dtype_bytes / PEAK_FLOPS / waves
        tiled = np.tile(ra, retries)
        for w in range(waves):
            em.touches(tiled, conc, 1 + w)
            em.compute(cval)
        return em.finish()

    def work_units(self) -> float:
        return float(2 * self.A.size)


class Gesummv(Workload):
    """y = α·A·x + β·B·x — thread-per-row over TWO large matrices: waves of
    concurrent accesses dispersed across all ranges of A and B (the paper's
    worst thrasher)."""

    name = "gesummv"
    concurrency = 20
    WAVE_ROWS = 16384
    dtype_bytes = 4

    def __init__(self, total_bytes: int, retry_override: int | None = None):
        super().__init__(total_bytes)
        self.retry_override = retry_override

    def build(self, space: AddressSpace) -> None:
        vec = max(2 * MB, int(self.total_bytes * 0.004))
        half = (self.total_bytes - 3 * vec) // 2
        self.A = space.alloc(half, "A")
        self.B = space.alloc(half, "B")
        self.vecs = [space.alloc(vec, f"v{i}") for i in range(3)]
        self.n = max(1, int(math.isqrt(half // self.dtype_bytes)))

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        ra, rb = _rids(space, self.A), _rids(space, self.B)
        for v in self.vecs:
            for rid in _rids(space, v):
                yield ("touch", rid, self.concurrency, 0)
        yield ("kernel", "gesummv")
        waves = max(1, math.ceil(self.n / self.WAVE_ROWS))
        ws = self.A.size + self.B.size
        other = sum(v.size for v in self.vecs)
        retries = (self.retry_override if self.retry_override is not None
                   else _wave_retries(ws, other, space.capacity))
        flops = 4.0 * ws / self.dtype_bytes
        for w in range(waves):
            for _ in range(retries):
                for i in range(max(len(ra), len(rb))):
                    if i < len(ra):
                        yield ("touch", ra[i], self.concurrency, 1 + w)
                    if i < len(rb):
                        yield ("touch", rb[i], self.concurrency, 1 + w)
            yield ("compute", flops / PEAK_FLOPS / waves)

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        conc = self.concurrency
        for v in self.vecs:
            em.touches(_rid_arr(space, v), conc)
        em.kernel()
        ra, rb = _rid_arr(space, self.A), _rid_arr(space, self.B)
        waves = max(1, math.ceil(self.n / self.WAVE_ROWS))
        ws = self.A.size + self.B.size
        other = sum(v.size for v in self.vecs)
        retries = (self.retry_override if self.retry_override is not None
                   else _wave_retries(ws, other, space.capacity))
        flops = 4.0 * ws / self.dtype_bytes
        cval = flops / PEAK_FLOPS / waves
        tiled = np.tile(_interleave(ra, rb), retries)
        for w in range(waves):
            em.touches(tiled, conc, 1 + w)
            em.compute(cval)
        return em.finish()

    def work_units(self) -> float:
        return float(self.A.size + self.B.size)


class HotSet(Workload):
    """Seeded synthetic hot-set trace (cache-algorithm-simulator style):
    random touches over one allocation where a ``hot_frac`` window of the
    ranges receives ``hot_prob`` of the accesses.

    ``mode``:

      * ``static``      — one hot window for the whole trace (the
                          baseline every eviction policy should ace),
      * ``dynamic``      — the window jumps to a fresh seeded-random
                          position each phase (working-set drift),
      * ``oscillating``  — the window ping-pongs between two fixed
                          positions each phase: the phase-change
                          adversary for schedulers and fused rounds
                          (every flip invalidates the resident hot set).

    The full touch sequence is drawn **once** with a seeded generator and
    shared by ``trace()`` and ``emit_columns`` — generator-vs-columnar
    parity holds by construction (and is tested).  One kernel marker and
    one compute op per phase."""

    name = "hotset"
    concurrency = 32
    MODES = ("static", "dynamic", "oscillating")

    def __init__(self, total_bytes: int, mode: str = "static",
                 hot_frac: float = 0.125, hot_prob: float = 0.9,
                 phases: int = 8, ops: int = 4096, seed: int = 0):
        super().__init__(total_bytes)
        if mode not in self.MODES:
            raise ValueError(f"unknown hot-set mode {mode!r}; "
                             f"available: {self.MODES}")
        self.mode = mode
        self.name = f"hotset-{mode}"
        self.hot_frac = hot_frac
        self.hot_prob = hot_prob
        self.phases = max(1, int(phases)) if mode != "static" else 1
        self.ops = int(ops)
        self.seed = seed
        self._seq: tuple | None = None

    def build(self, space: AddressSpace) -> None:
        self.data = space.alloc(self.total_bytes, "data")

    def _sequence(self, space: AddressSpace):
        """(touch rids, phase op bounds, per-phase compute seconds) —
        drawn once, then shared by both trace tiers."""
        if self._seq is not None:
            return self._seq
        rids = _rid_arr(space, self.data)
        n = len(rids)
        rng = np.random.default_rng(self.seed)
        nhot = max(1, int(round(n * self.hot_frac)))
        if self.mode == "static":
            starts = np.array([int(rng.integers(n))], dtype=np.int64)
        elif self.mode == "dynamic":
            starts = rng.integers(0, n, size=self.phases).astype(np.int64)
        else:                              # oscillating: ping-pong
            a, b = 0, n // 2
            starts = np.array(
                [a if p % 2 == 0 else b for p in range(self.phases)],
                dtype=np.int64)
        per = math.ceil(self.ops / self.phases)
        pidx = np.minimum(np.arange(self.ops) // per, self.phases - 1)
        hot = rng.random(self.ops) < self.hot_prob
        cold_pos = rng.integers(0, n, size=self.ops)
        hot_off = rng.integers(0, nhot, size=self.ops)
        pos = np.where(hot, (starts[pidx] + hot_off) % n, cold_pos)
        seq = rids[pos]
        bounds = np.minimum(np.arange(self.phases + 1) * per, self.ops)
        sz = _sizes(space)
        comp = np.array([float(sz[seq[a:b]].sum()) / HBM_BW
                         for a, b in zip(bounds[:-1], bounds[1:])])
        self._seq = (seq, bounds, comp)
        return self._seq

    def trace(self, space: AddressSpace) -> Iterator[Op]:
        seq, bounds, comp = self._sequence(space)
        conc = self.concurrency
        for p in range(len(bounds) - 1):
            yield ("kernel", f"hotset_p{p}")
            for rid in seq[bounds[p]:bounds[p + 1]].tolist():
                yield ("touch", rid, conc, 0)
            yield ("compute", comp[p])

    def emit_columns(self, space: AddressSpace) -> CompiledTrace:
        em = ColumnEmitter()
        seq, bounds, comp = self._sequence(space)
        for p in range(len(bounds) - 1):
            em.kernel()
            em.touches(seq[bounds[p]:bounds[p + 1]], self.concurrency)
            em.compute(comp[p])
        return em.finish()

    def work_units(self) -> float:
        return float(self.ops)


WORKLOADS: dict[str, type[Workload]] = {
    "stream": Stream,
    "conv2d": Conv2d,
    "jacobi2d": Jacobi2d,
    "bfs": BFS,
    "sgemm": Sgemm,
    "syr2k": Syr2k,
    "mvt": Mvt,
    "gesummv": Gesummv,
    "hotset": HotSet,
}


def make_workload(name: str, total_bytes: int, **kw) -> Workload:
    """Instantiate a Table-2 workload by name at the given footprint."""
    try:
        cls = WORKLOADS[name]
    except KeyError:
        raise ValueError(f"unknown workload {name!r}; "
                         f"available: {sorted(WORKLOADS)}") from None
    return cls(total_bytes, **kw)
