"""Core SVM model: ranges, policies, cost model, driver state machine,
discrete-event simulator, and the paper's workload traces."""

from repro.core.costmodel import (
    CostParams,
    CostVector,
    MI250X,
    TPU_V5E_HOST,
    eviction_cost,
    migration_cost,
    zerocopy_cost,
)
from repro.core.policies import LRF, LRU, Clock, RandomPolicy, make_policy
from repro.core.ranges import (
    GB,
    KB,
    MB,
    PAGE,
    AddressSpace,
    Allocation,
    Range,
    pow2_floor,
    split_allocation,
    svm_alignment,
)
from repro.core.engine import (
    TRACE_CACHE,
    ColumnEmitter,
    CompiledTrace,
    SegmentCache,
    TraceCache,
    TraceSession,
    compile_trace,
    compile_workload,
    compiled_from_columns,
    execute_compiled,
    execute_fused,
)
from repro.core.simulator import RunResult, Workload, apply_trace, dos_sweep, simulate
from repro.core.svm import DensitySample, Event, MigrationError, SVMManager
from repro.core.sweep import SweepPoint, run_point, run_sweep, trace_key
from repro.core.traces import WORKLOADS, make_workload
from repro.core.uvm import UVMManager, VABLOCK

__all__ = [
    "AddressSpace", "Allocation", "Range", "pow2_floor", "split_allocation",
    "svm_alignment", "GB", "MB", "KB", "PAGE",
    "CostParams", "CostVector", "MI250X", "TPU_V5E_HOST",
    "migration_cost", "eviction_cost", "zerocopy_cost",
    "LRF", "LRU", "Clock", "RandomPolicy", "make_policy",
    "SVMManager", "Event", "DensitySample", "MigrationError",
    "UVMManager", "VABLOCK",
    "RunResult", "Workload", "simulate", "apply_trace", "dos_sweep",
    "WORKLOADS", "make_workload",
    "CompiledTrace", "compile_trace", "compile_workload", "execute_compiled",
    "execute_fused",
    "ColumnEmitter", "SegmentCache", "TraceCache", "TraceSession",
    "TRACE_CACHE",
    "compiled_from_columns",
    "SweepPoint", "run_point", "run_sweep", "trace_key",
]
