"""jamba-1.5-large-398b: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2 — Mamba:attention 7:1 interleave (attention
at offset 4 of each 8-layer period), MoE every other layer
[arXiv:2403.19887; hf]."""

import dataclasses

from repro.models.config import ATTN, MAMBA, MLP, MOE, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    vocab=65536,
    d_model=8192,
    n_layers=72,
    d_ff=24576,
    n_heads=64,
    n_kv_heads=8,
    layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    ffn_pattern=(MLP, MOE),
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_expand=2,
    rope_theta=10_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=8, d_ff=128,
        n_heads=4, n_kv_heads=2, n_experts=4, top_k=2, ssm_state=4)
