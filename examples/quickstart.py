"""Quickstart: build a small LM from the public API, train a few steps on
synthetic data, checkpoint, and decode — all on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import SyntheticLM
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import init_cache, init_params, prefill
from repro.optim import OptConfig, make_optimizer


def main() -> None:
    cfg = dataclasses.replace(get_reduced("granite-3-2b"), n_layers=4)
    print(f"model: {cfg.name} reduced ({cfg.param_count()/1e6:.2f}M params)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    opt_init, _ = make_optimizer(opt_cfg)
    opt_state = opt_init(params)
    train_step = jax.jit(make_train_step(cfg, opt_cfg))

    data = SyntheticLM(vocab=cfg.vocab, seed=0)
    t0 = time.time()
    for step in range(30):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(step, 0, 8, 64).items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if step % 10 == 0 or step == 29:
            print(f"step {step:3d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    print(f"trained 30 steps in {time.time()-t0:.1f}s")

    # greedy decode a few tokens from a prompt
    prompt = jnp.asarray(data.batch(999, 0, 1, 8)["tokens"])
    logits, cache = prefill(params, cfg, prompt, cache_len=32)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    for _ in range(8):
        tok, cache = serve(params, tok, cache)
        out.append(int(tok[0, 0]))
    print("decoded continuation ids:", out)


if __name__ == "__main__":
    main()
