"""Serving launcher: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 16 --decode 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import SyntheticLM, modality_stub
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    params = init_params(cfg, jax.random.PRNGKey(0))

    data = SyntheticLM(vocab=cfg.vocab, seed=1)
    prompts = jnp.asarray(
        data.batch(0, 0, args.batch, args.prompt_len)["tokens"])
    ctx = None
    if cfg.is_vlm:
        ctx = jnp.asarray(modality_stub("image", args.batch,
                                        cfg.image_tokens, cfg.d_model),
                          jnp.bfloat16)
    elif cfg.is_encdec:
        ctx = jnp.asarray(modality_stub("frames", args.batch,
                                        cfg.encoder_frames, cfg.d_model),
                          jnp.bfloat16)

    prefill_jit = jax.jit(make_prefill_step(cfg))
    serve_jit = jax.jit(make_serve_step(cfg))

    with mesh:
        t0 = time.time()
        if ctx is not None:
            logits, cache = prefill_jit(params, prompts, ctx)
        else:
            logits, cache = prefill_jit(params, prompts)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_pre = time.time() - t0
        outs = [tok]
        t0 = time.time()
        for _ in range(args.decode):
            if ctx is not None and cfg.is_encdec or cfg.is_vlm:
                from repro.models import encode
                c = encode(params, cfg, ctx) if cfg.is_encdec else ctx
                tok, cache = serve_jit(params, tok, cache, c)
            else:
                tok, cache = serve_jit(params, tok, cache)
            outs.append(tok)
        t_dec = time.time() - t0

    seq = jnp.concatenate(outs, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_pre*1e3:.1f}ms; "
          f"decoded {args.decode} tokens in {t_dec*1e3:.1f}ms "
          f"({args.batch*args.decode/max(t_dec,1e-9):.1f} tok/s)")
    print("first request continuation:", seq[0].tolist())


if __name__ == "__main__":
    main()
