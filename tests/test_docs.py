"""Docs subsystem guards: public-API docstring coverage (so the docs/
pages can't silently rot against an undocumented API) and the docs
link/anchor checker."""

import importlib.util
import inspect
import os

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_link_checker():
    path = os.path.join(ROOT, "tools", "check_docs_links.py")
    spec = importlib.util.spec_from_file_location("check_docs_links", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------ docstring coverage

@pytest.mark.parametrize("modname",
                         ["repro.core", "repro.svm", "repro.launch"])
def test_public_exports_have_nontrivial_docstrings(modname):
    """Every class/function exported from the package __init__ must carry
    a docstring of at least three words (constants are exempt — their
    meaning is documented at their definition site)."""
    mod = importlib.import_module(modname)
    thin = []
    for name in getattr(mod, "__all__", []):
        obj = getattr(mod, name)
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        doc = inspect.getdoc(obj)
        if not doc or len(doc.split()) < 3:
            thin.append(f"{modname}.{name}: {doc!r}")
    assert not thin, f"undocumented public symbols: {thin}"


def test_core_and_svm_export_a_public_api():
    """The coverage test above must actually be covering something."""
    import repro.core
    import repro.svm
    assert len(repro.core.__all__) > 20
    assert len(repro.svm.__all__) >= 10


# ---------------------------------------------------------- link checking

def test_docs_pages_exist_and_readme_links_them():
    for page in ("architecture.md", "serving.md", "figures.md"):
        assert os.path.exists(os.path.join(ROOT, "docs", page)), page
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for page in ("docs/architecture.md", "docs/serving.md",
                 "docs/figures.md"):
        assert page in readme, f"README must link {page}"


def test_docs_links_and_anchors_resolve():
    mod = _load_link_checker()
    errors = mod.collect_errors(ROOT)
    assert not errors, errors


def test_link_checker_detects_breakage(tmp_path):
    """The checker must actually fail on broken targets/anchors — a
    checker that passes everything guards nothing."""
    mod = _load_link_checker()
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "README.md").write_text(
        "# Top\n[ok](docs/a.md#real)\n[bad](docs/missing.md)\n"
        "[badfrag](docs/a.md#fake)\n[selfbad](#nowhere)\n")
    (docs / "a.md").write_text("# Real\nbody\n```\n# not a heading\n```\n")
    errors = mod.collect_errors(str(tmp_path))
    assert len(errors) == 3
    assert any("missing.md" in e for e in errors)
    assert any("#fake" in e for e in errors)
    assert any("#nowhere" in e for e in errors)
    # fenced pseudo-headings are not anchors
    slugs = mod.heading_slugs(str(docs / "a.md"))
    assert slugs == {"real"}


def test_link_checker_ignores_fenced_code(tmp_path):
    mod = _load_link_checker()
    (tmp_path / "README.md").write_text(
        "# Top\n```\n[display only](does/not/exist.md)\n```\n")
    assert mod.collect_errors(str(tmp_path)) == []


def test_slugify_github_style():
    mod = _load_link_checker()
    assert mod.slugify("Performance gates") == "performance-gates"
    assert mod.slugify("Tier 2 — columnar compile + cross-point "
                       "trace sharing") == \
        "tier-2--columnar-compile--cross-point-trace-sharing"
