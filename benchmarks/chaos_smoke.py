"""Chaos smoke: the seeded 64-request fault schedule, end to end.

CI gate for the robustness layer (docs/robustness.md): a 64-request
mixed-architecture serving schedule runs under the default seeded
`FaultPlan` — capacity dip + restore, a slow-page window, armed
migration faults, one mid-decode crash — with the thrash guard enabled.
The run must

  * complete with **zero unhandled faults**: every planned event applied
    (`events_remaining == 0`), no retry budget blown
    (`retry_exhausted == 0`), no request failed,
  * decode every requested token on every request,
  * satisfy **exact conservation**: per-request attributed wall /
    migration / eviction / byte counters sum to the shared manager's
    aggregates, including every chaos-injected cost,
  * be **bit-identical on rerun**: same plan seed ⇒ same per-request
    rows, incident log, chaos counters, and makespan.

Exit status is nonzero on any violation, so `make chaos-smoke` can sit
in CI next to the bench gates.

Usage:  PYTHONPATH=src python benchmarks/chaos_smoke.py
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import MB  # noqa: E402
from repro.svm import (  # noqa: E402
    FaultPlan,
    ModelSpec,
    PoolScheduler,
    make_requests,
)

REQUESTS = 64
TOKENS = 8
PLAN_SEED = 0
CAP = 100 * MB

_checks: list[str] = []


def check(ok: bool, what: str) -> None:
    _checks.append(f"{'ok  ' if ok else 'FAIL'} {what}")
    if not ok:
        print("\n".join(_checks))
        print(f"chaos-smoke: FAIL ({what})")
        sys.exit(1)


def run() -> dict:
    specs = [ModelSpec.synthetic("archA", 12, 4 * MB, embed_bytes=8 * MB),
             ModelSpec.synthetic("archB", 24, 4 * MB, embed_bytes=24 * MB)]
    reqs = make_requests(specs, REQUESTS, seed=0, tokens=TOKENS,
                         mean_interarrival_s=2e-3)
    plan = FaultPlan.default(PLAN_SEED, n_requests=REQUESTS, tokens=TOKENS)
    sched = PoolScheduler(CAP, policy="svm_aware", fault_plan=plan,
                          thrash_watermark=3.0, thrash_window=32)
    return sched.run(reqs)


def main() -> None:
    t0 = time.perf_counter()
    r = run()
    host_s = time.perf_counter() - t0
    ch, inj = r["chaos"], r["chaos"]["injector"]

    check(inj["events_remaining"] == 0,
          f"all {inj['events_total']} planned fault events applied")
    check(ch["retry_exhausted"] == 0, "no retry budget exhausted")
    check(r["n_failed"] == 0 and r["n_requests"] == REQUESTS,
          f"all {REQUESTS} requests completed")
    check(all(q["tokens"] == TOKENS for q in r["requests"]),
          f"every request decoded {TOKENS}/{TOKENS} tokens")
    check(ch["migration_faults"] >= 1 and ch["crashes"] >= 1,
          "migration faults and a crash actually fired")

    c, m = r["conservation"], r["mgr"]
    check(abs(c["svm_wall_s"] - m["wall_s"]) < 1e-9,
          "wall conservation exact (incl. chaos surcharges)")
    check(c["migrations"] == m["migrations"]
          and c["evictions"] == m["evictions"]
          and c["bytes_migrated"] == m["bytes_migrated"]
          and c["bytes_evicted"] == m["bytes_evicted"],
          "migration/eviction/byte conservation exact")

    r2 = run()
    check(r2["requests"] == r["requests"]
          and r2["incidents"] == r["incidents"]
          and r2["chaos"] == r["chaos"]
          and r2["makespan_s"] == r["makespan_s"],
          "rerun bit-identical (rows, incidents, chaos counters)")

    print("\n".join(_checks))
    print(f"chaos-smoke: PASS — {REQUESTS} requests x {TOKENS} tokens, "
          f"{inj['events_total']} fault events, "
          f"{ch['migration_faults']} faults / {ch['retries']} retries / "
          f"{ch['crashes']} crash(es) / {ch['preemptions']} preemption(s), "
          f"{len(r['incidents'])} incidents, "
          f"makespan {r['makespan_s']:.3f}s sim, {host_s:.1f}s host")


if __name__ == "__main__":
    main()
