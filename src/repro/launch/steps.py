"""Train and serve step factories: loss, microbatched grad accumulation,
ZeRO-sharded optimizer update, greedy decode."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, encode, forward
from repro.models.config import ModelConfig
from repro.optim import OptConfig, clip_by_global_norm, make_optimizer

PyTree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """CE via logsumexp — never materialises log-probs over the (possibly
    vocab-sharded) logits; only (B,S) reductions leave the shard."""
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    lab = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - lab)


CE_CHUNK = 512


def chunked_cross_entropy(x: jax.Array, head: jax.Array, labels: jax.Array,
                          transpose_head: bool,
                          vocab: int | None = None) -> jax.Array:
    """Fused LM-head + CE, scanned over sequence chunks: the (B,S,V) logits
    tensor never exists — each chunk computes its (B,C,V) logits, reduces to
    logsumexp/label-logit scalars, and is rematerialised in the backward.
    This is the production memory-safe CE (vocab up to 262k at S=4k/32k)."""
    B, S, D = x.shape
    C = min(CE_CHUNK, S)
    pad = (-S) % C
    nc = (S + pad) // C
    xs = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    xs = jnp.moveaxis(xs.reshape(B, nc, C, D), 1, 0)          # (nc,B,C,D)
    ls = jnp.pad(labels, ((0, 0), (0, pad)))
    ls = jnp.moveaxis(ls.reshape(B, nc, C), 1, 0)             # (nc,B,C)
    valid = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    vs = jnp.moveaxis(valid.reshape(B, nc, C), 1, 0)

    V = head.shape[0] if transpose_head else head.shape[-1]
    pad_mask = (jnp.arange(V) >= vocab) if (vocab and vocab != V) else None

    def body(acc, inp):
        x_c, l_c, v_c = inp
        logits = (x_c @ head.T if transpose_head else x_c @ head)
        lg = logits.astype(jnp.float32)
        if pad_mask is not None:  # padded vocab tail never scores
            lg = jnp.where(pad_mask, -2.0e38, lg)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        lab = jnp.take_along_axis(lg, l_c[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - lab) * v_c), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (xs, ls, vs))
    return total / (B * S)


def loss_fn(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, ctx: Optional[jax.Array]) -> jax.Array:
    c = encode(params, cfg, ctx) if cfg.is_encdec else ctx
    x, aux = forward(params, cfg, tokens, ctx=c, return_hidden=True)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    ce = chunked_cross_entropy(x, head, labels, cfg.tie_embeddings,
                               vocab=cfg.vocab)
    return ce + 0.01 * aux


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch = {"tokens","labels"[,"ctx"]} with a global batch dim
    that microbatching splits on-device (grad accumulation via lax.scan)."""
    _, opt_update = make_optimizer(opt_cfg)

    def grads_of(params, tokens, labels, ctx):
        return jax.value_and_grad(loss_fn)(params, cfg, tokens, labels, ctx)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        ctx = batch.get("ctx")
        if microbatches == 1:
            loss, grads = grads_of(params, tokens, labels, ctx)
        else:
            B = tokens.shape[0]
            mb = B // microbatches

            def split(x):
                return x.reshape(microbatches, mb, *x.shape[1:])

            mtok, mlab = split(tokens), split(labels)
            mctx = split(ctx) if ctx is not None else None

            def body(acc, inp):
                g_acc, l_acc = acc
                t, l, c = inp
                loss_i, g_i = grads_of(params, t, l, c)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, g_i)
                return (g_acc, l_acc + loss_i), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16)
                              if p.dtype == jnp.bfloat16
                              else jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)),
                (mtok, mlab, mctx))
            scale = 1.0 / microbatches
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)
            loss = loss * scale
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = opt_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, token, cache[, ctx]) -> (next_ids, cache):
    one greedy decode step over a seq_len-deep KV/SSM cache."""

    def serve_step(params, token, cache, ctx=None):
        logits, cache = decode_step(params, cfg, token, cache, ctx=ctx)
        next_ids = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_ids[:, None], cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Returns prefill_step(params, tokens[, ctx]) -> (last_logits, cache).
    Only the final position's logits are returned — serving samples from
    them, and a full (B,S,V) logits output would dominate the step's output
    bytes (537 GB for a 256k vocab at 32k prefill)."""
    from repro.models import prefill

    def prefill_step(params, tokens, ctx=None):
        c = encode(params, cfg, ctx) if cfg.is_encdec else ctx
        logits, cache = prefill(params, cfg, tokens, ctx=c)
        return logits[:, -1:], cache

    return prefill_step
