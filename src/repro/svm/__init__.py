"""Executable SVM runtime: range-granular host<->HBM streaming for
oversubscribed serving (weight streaming), training (activation offload),
and multi-tenant serving over one shared device pool, driven by the
paper's range/fault/eviction model."""

from repro.svm.planner import (
    ParamRanges,
    plan_leaf_ranges,
    plan_param_ranges,
    tree_leaf_sizes,
)
from repro.svm.executor import StreamingExecutor, run_layer_stream
from repro.svm.offload import (
    OffloadPlan,
    plan_offload,
    record_offload,
    simulate_offload,
)
from repro.svm.faults import FaultEvent, FaultInjector, FaultPlan
from repro.svm.hotset import (
    HotSetProfile,
    ProfileCache,
    spec_profile,
    token_trace,
)
from repro.svm.scheduler import (
    ModelSpec,
    PoolScheduler,
    Request,
    make_requests,
    run_schedule,
)

__all__ = ["plan_param_ranges", "plan_leaf_ranges", "tree_leaf_sizes",
           "ParamRanges", "StreamingExecutor", "run_layer_stream",
           "OffloadPlan", "plan_offload", "record_offload",
           "simulate_offload", "ModelSpec", "PoolScheduler", "Request",
           "make_requests", "run_schedule",
           "FaultPlan", "FaultEvent", "FaultInjector",
           "HotSetProfile", "ProfileCache", "spec_profile",
           "token_trace"]
