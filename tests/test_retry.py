"""Shared bounded-retry utility (`repro.ft.retry`) and its adopters.

Covers: the pure deterministic backoff schedule, `retry_call` semantics
(1-based attempts, backoff callbacks, `RetryError` chaining on
exhaustion), the incremental `RetryBudget` ledger, and the migration of
`TrainSupervisor` / `StragglerMonitor` onto the shared primitive —
restart counts pinned, backoff schedules bit-identical across reruns."""

import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import (
    RetryBudget,
    RetryError,
    RetryPolicy,
    StragglerMonitor,
    TrainSupervisor,
    retry_call,
)

# ------------------------------------------------------------- policy

def test_policy_schedule_is_pure_and_capped():
    p = RetryPolicy(max_attempts=4, base_delay_s=0.5, factor=2.0,
                    max_delay_s=1.5)
    assert p.delay(1) == 0.5
    assert p.delay(2) == 1.0
    assert p.delay(3) == 1.5            # capped
    assert p.schedule() == (0.5, 1.0, 1.5)
    assert p.schedule() == p.schedule()  # pure: no RNG, no clock


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base_delay_s=-1.0)
    with pytest.raises(ValueError):
        RetryPolicy(factor=0.0)


# ---------------------------------------------------------- retry_call

def test_retry_call_succeeds_after_transient_failures():
    p = RetryPolicy(max_attempts=4, base_delay_s=0.1)
    seen, backoffs = [], []

    def flaky(attempt):
        seen.append(attempt)
        if attempt <= 2:
            raise OSError("transient")
        return "ok"

    out = retry_call(flaky, policy=p, retry_on=(OSError,),
                     on_backoff=lambda a, d: backoffs.append((a, d)))
    assert out == "ok"
    assert seen == [1, 2, 3]
    assert backoffs == [(1, 0.1), (2, 0.2)]


def test_retry_call_exhaustion_raises_chained_retry_error():
    p = RetryPolicy(max_attempts=3, base_delay_s=0.0)

    def always(attempt):
        raise OSError(f"attempt {attempt}")

    with pytest.raises(RetryError) as ei:
        retry_call(always, policy=p, retry_on=(OSError,))
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, OSError)
    assert isinstance(ei.value.__cause__, OSError)


def test_retry_call_does_not_swallow_foreign_exceptions():
    def bad(attempt):
        raise ValueError("not retryable")
    with pytest.raises(ValueError):
        retry_call(bad, retry_on=(OSError,))


# --------------------------------------------------------- RetryBudget

def test_budget_ledger_and_reset():
    b = RetryBudget(RetryPolicy(max_attempts=3, base_delay_s=1.0))
    assert b.remaining == 3 and not b.exhausted
    assert b.spend() == 1.0
    assert b.spend() == 2.0
    assert b.remaining == 1 and not b.exhausted
    assert b.spend() == 4.0
    assert b.exhausted and b.remaining == 0
    assert b.backoff_s == pytest.approx(7.0)
    b.reset()
    assert not b.exhausted and b.attempts == 0
    # the cumulative backoff ledger survives a re-arm
    assert b.backoff_s == pytest.approx(7.0)


# --------------------------------------------- supervisor on the budget

def run_supervisor(tmp_path, n_failures, max_restarts=3):
    ckpt = CheckpointManager(str(tmp_path), keep=3, every=2)
    sup = TrainSupervisor(ckpt, max_restarts=max_restarts)
    state0 = {"x": jnp.zeros((), jnp.float32)}
    left = {"n": n_failures}

    def injector(step):
        if step == 5 and left["n"] > 0:
            left["n"] -= 1
            raise RuntimeError("simulated node failure")

    final_step, state = sup.run(state0, lambda s, st: {"x": st["x"] + 1.0},
                                steps=8, failure_injector=injector)
    return sup, final_step, state


def test_supervisor_restart_count_pinned(tmp_path):
    sup, final_step, state = run_supervisor(tmp_path, n_failures=2,
                                            max_restarts=3)
    assert final_step == 8 and float(state["x"]) == 8.0
    assert sup.restarts == 2
    assert sup.budget.remaining == 1


def test_supervisor_budget_exhaustion_reraises_original(tmp_path):
    with pytest.raises(RuntimeError, match="simulated node failure"):
        run_supervisor(tmp_path, n_failures=99, max_restarts=2)


def test_supervisor_backoff_schedule_deterministic(tmp_path):
    sup1, _, _ = run_supervisor(tmp_path / "a", n_failures=2)
    sup2, _, _ = run_supervisor(tmp_path / "b", n_failures=2)
    assert sup1.budget.backoff_s == sup2.budget.backoff_s
    assert sup1.budget.backoff_s == pytest.approx(
        sum(sup1.budget.policy.schedule()[:2]))
    backoffs1 = [m for m in sup1.log if m.startswith("backoff")]
    backoffs2 = [m for m in sup2.log if m.startswith("backoff")]
    assert backoffs1 == backoffs2 and len(backoffs1) == 2


# ----------------------------------------- straggler monitor on budgets

def test_straggler_strikes_ride_retry_budget():
    mon = StragglerMonitor(threshold=1.5, patience=3)

    def step(slow):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        mon.record(2, 3.0 if slow else 1.0)
        return mon.flagged()

    assert step(True) == []
    assert step(True) == []
    assert mon.strikes[2] == 2
    assert step(False) == []      # host recovers: its budget re-arms
    assert mon.strikes[2] == 0
    out = []
    for _ in range(3):
        out = step(True)
    assert out == [2]
    assert mon.strikes[0] == 0 and mon.strikes[1] == 0
