"""Docs link checker: every relative markdown link and heading anchor in
README.md and docs/*.md must resolve.

Checks, for each `[text](target)` link:
  * relative file targets exist (resolved against the linking file's
    directory; external http(s)/mailto links are skipped),
  * `#fragment` anchors — same-file or `file.md#fragment` — match a
    heading slug of the target file (GitHub-style slugging, duplicate
    headings get ``-1``/``-2`` suffixes).

Run directly (CI / `make docs-check`) or import `check_files` /
`collect_errors` from tests.

Usage:  python tools/check_docs_links.py [repo_root]
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line."""
    text = heading.strip().lower()
    kept = [c for c in text if c.isalnum() or c in " -_"]
    return "".join(kept).replace(" ", "-")


def heading_slugs(path: str) -> set[str]:
    """All anchor slugs a markdown file exposes (fenced code skipped;
    duplicate headings numbered like GitHub)."""
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = slugify(m.group(2))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_files(md_files: list[str]) -> list[str]:
    """Return a list of 'file: problem' strings (empty = all links ok)."""
    errors: list[str] = []
    slug_cache: dict[str, set[str]] = {}

    def slugs_of(path: str) -> set[str]:
        key = os.path.abspath(path)
        if key not in slug_cache:
            slug_cache[key] = heading_slugs(path)
        return slug_cache[key]

    for md in md_files:
        base = os.path.dirname(md)
        targets = []
        in_fence = False
        with open(md, encoding="utf-8") as f:
            for line in f:
                # display-only code is not a link (mirrors heading_slugs)
                if line.lstrip().startswith("```"):
                    in_fence = not in_fence
                    continue
                if not in_fence:
                    targets += LINK_RE.findall(line)
        for target in targets:
            if target.startswith(EXTERNAL):
                continue
            path_part, _, frag = target.partition("#")
            tgt = md if not path_part else os.path.normpath(
                os.path.join(base, path_part))
            if not os.path.exists(tgt):
                errors.append(f"{md}: broken link target {target!r}")
                continue
            if frag and os.path.isfile(tgt):
                if frag not in slugs_of(tgt):
                    errors.append(
                        f"{md}: anchor #{frag} not found in {tgt}")
    return errors


def collect_errors(root: str) -> list[str]:
    """Check README.md plus every markdown file under docs/."""
    md_files = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        md_files.append(readme)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        md_files += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                     if f.endswith(".md")]
    return check_files(md_files)


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..")
    errors = collect_errors(root)
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    if errors:
        return 1
    print("docs links + anchors all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
