"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and absence of NaNs. The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import decode_step, encode, forward, init_cache, init_params, prefill

B, S = 2, 16


def _ctx_for(cfg, key, B):
    if cfg.is_encdec:
        frames = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model)).astype(jnp.bfloat16)
        return frames, "frames"
    if cfg.is_vlm:
        img = jax.random.normal(
            key, (B, cfg.image_tokens, cfg.d_model)).astype(jnp.bfloat16)
        return img, "image"
    return None, None


def _run_forward(cfg):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    ctx, kind = _ctx_for(cfg, jax.random.PRNGKey(2), B)
    if kind == "frames":
        ctx = encode(params, cfg, ctx)
    logits, aux = forward(params, cfg, tokens, ctx=ctx)
    return params, tokens, ctx, logits, aux


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params, tokens, ctx, logits, aux = _run_forward(cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_and_finite(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    ctx, kind = _ctx_for(cfg, jax.random.PRNGKey(2), B)

    def loss_fn(p):
        c = encode(p, cfg, ctx) if kind == "frames" else ctx
        logits, aux = forward(p, cfg, tokens, ctx=c)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return -jnp.mean(ll) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, 0.0)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                       params, grads)
    loss2 = loss_fn(new)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-1b",
                                  "falcon-mamba-7b", "mixtral-8x7b",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced forward and prefill+decode must produce the same
    next-token logits (validates cache correctness incl. rolling windows
    and SSM state hand-off). MoE capacity is raised so no tokens drop —
    capacity-based routing otherwise drops *different* tokens for different
    total token counts, which is expected behaviour, not a cache bug."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced(arch), capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    full_logits, _ = forward(params, cfg, tokens)
    pre_logits, cache = prefill(params, cfg, tokens[:, : S - 2],
                                cache_len=S)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1], np.float32),
        np.asarray(full_logits[:, S - 3], np.float32), rtol=2e-2, atol=2e-2)
    # decode the last two tokens and compare against teacher-forced logits
    logits_a, cache = decode_step(params, cfg, tokens[:, S - 2: S - 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_a[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32), rtol=2e-2, atol=2e-2)
    logits_b, cache = decode_step(params, cfg, tokens[:, S - 1: S], cache)
    np.testing.assert_allclose(
        np.asarray(logits_b[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32), rtol=2e-2, atol=2e-2)


def test_param_count_matches_analytic():
    """Analytic 6ND accounting must match the real parameter tree."""
    for arch in ("granite-3-2b", "falcon-mamba-7b", "mixtral-8x7b"):
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(params))
        assert actual == cfg.param_count(), arch


def test_full_configs_param_counts():
    """Sanity: full configs land near their nominal sizes (no allocation —
    analytic count only)."""
    expect = {
        "gemma3-1b": (0.9e9, 1.6e9),
        "granite-3-2b": (2.0e9, 3.0e9),
        "chatglm3-6b": (5.5e9, 7.0e9),
        "granite-20b": (18e9, 22e9),
        "mixtral-8x7b": (44e9, 49e9),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "falcon-mamba-7b": (6.5e9, 8.0e9),
        "llama-3.2-vision-11b": (9e9, 12e9),
        "seamless-m4t-medium": (0.55e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
