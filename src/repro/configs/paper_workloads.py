"""The paper's own workload suite (Table 2) as a selectable config set,
mirroring the architecture registry so benchmarks and examples can
enumerate them uniformly."""

from __future__ import annotations

import dataclasses

from repro.core import GB
from repro.core.traces import WORKLOADS, make_workload


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    name: str
    description: str
    domain: str
    category: str          # paper §3.1 category at oversubscription
    svm_aware_variant: bool


PAPER_WORKLOADS = {
    "stream": WorkloadConfig(
        "stream", "Triad-only scaled dot product of two vectors",
        "Synthetic", "I", False),
    "conv2d": WorkloadConfig(
        "conv2d", "Full 2-D convolution with varying weights",
        "Machine Learning", "I", False),
    "jacobi2d": WorkloadConfig(
        "jacobi2d", "Forward/backward adjacent convolution, equal weights",
        "Machine Learning", "II", True),
    "bfs": WorkloadConfig(
        "bfs", "Breadth-first traversal from a random start node",
        "Graph Traversal", "I", False),
    "syr2k": WorkloadConfig(
        "syr2k", "Symmetric rank-2k update", "Linear Algebra", "III", False),
    "sgemm": WorkloadConfig(
        "sgemm", "General matrix-matrix product", "Linear Algebra", "III",
        True),
    "mvt": WorkloadConfig(
        "mvt", "Matrix-vector then matrix-transpose-vector product",
        "Linear Algebra", "III", False),
    "gesummv": WorkloadConfig(
        "gesummv", "Sum of two scaled matrix-vector products",
        "Linear Algebra", "III", False),
}

DEFAULT_CAPACITY = 8 * GB


def build(name: str, dos: float, capacity: int = DEFAULT_CAPACITY, **kw):
    """Instantiate a paper workload at a target degree of oversubscription."""
    if name not in PAPER_WORKLOADS:
        raise ValueError(
            f"unknown paper workload {name!r}; have {sorted(PAPER_WORKLOADS)}")
    return make_workload(name, int(capacity * dos / 100.0), **kw)


assert set(PAPER_WORKLOADS) == set(WORKLOADS), "registry drift"
