"""SVMManager — the SVM driver state machine (paper §2.2–§2.4).

Reproduces the driver-visible dynamics:

  * page-level faults, range-level migration (one serviceable fault migrates
    the whole range; concurrent faults on the same range are *duplicates*
    and dismissed — 97–99 % of all faults),
  * synchronous range eviction on the migration critical path, victim chosen
    by the eviction policy (LRF by default),
  * the five-term host-visible cost model, with eviction charged to the
    triggering migration's `alloc` term,
  * migration/eviction event profiles and fault-density samples (paper
    Figs. 7–10).

TPU adaptation note (DESIGN.md §2): TPUs have no device-initiated demand
paging, so this manager is driven by access *traces* rather than hardware
interrupts; the policy logic, range construction, and cost accounting are
the faithful part. The same manager also backs the executable streaming
runtime in `repro.svm`, where "touch" events come from a planned compute
schedule instead.

Beyond-paper / §4.2 driver variants (all selectable):
  * ``parallel_evict``  — overlap eviction with the blocked migration
    (paper §4.2 "Parallel Implementation"): wall time takes
    max(evictions, migration) instead of their sum.
  * ``policy="clock"|"lru"|"random"`` — alternative victim selection.
  * ``defer_granule``/``defer_k`` — adaptive granularity: the first
    ``defer_k - 1`` serviceable faults on a range migrate only a granule,
    deferring the full-range migration (paper §4.2 "Granularity",
    density/access-count triggered prefetching).
  * ``zero_copy`` allocations — never migrated; accesses are charged
    remote-access cost (paper §4.2 "Zero-Copy instead of Demand Paging").
  * ``previct_watermark`` — background pre-eviction below a free-space
    watermark (beyond paper; cf. Li et al. ASPLOS'19), removing eviction
    from the critical path at the cost of mild contention.

All of the above run on the compiled-trace fast tier (`repro.core.engine`)
with byte-identical `summary()` output — no variant drops a sweep to the
scalar per-op path anymore.
"""

from __future__ import annotations

import dataclasses

from repro.core.costmodel import (
    CostParams,
    CostVector,
    MI250X,
    eviction_cost,
    migration_cost,
    zerocopy_cost,
)
from repro.core.policies import EvictionPolicy, make_policy
from repro.core.ranges import AddressSpace, Range


class MigrationError(RuntimeError):
    """An injected (or, on real hardware, reported) range-migration
    failure.  Raised by `SVMManager` *before* any state mutation for the
    failing migration, so the manager is left exactly as it was and the
    caller can retry the access — subclassing ``RuntimeError`` keeps the
    batched engine's mid-span snapshot/restore + scalar re-raise path
    applicable, surfacing the error at the exact op with consistent
    state."""


@dataclasses.dataclass
class Event:
    """One migration or eviction, for profile plots (paper Fig. 7)."""

    t: float          # wall-clock seconds at completion
    kind: str         # "mig" | "evt" | "zc"
    rid: int
    alloc_id: int
    nbytes: int


@dataclasses.dataclass
class DensitySample:
    """Faults satisfied by one migration (paper §3.3 'fault density')."""

    t: float
    rid: int
    alloc_id: int
    faults: int          # serviceable + duplicates (dismissed)
    trigger_page: int    # virtual page that raised the serviceable fault


class SVMManager:
    """The SVM driver state machine (see module docstring): page faults,
    range-granular migration/eviction, the five-term cost model, and the
    simulated wall clock, driven by `touch`/`advance`/… calls or — far
    faster — by compiled traces through `repro.core.engine`."""

    def __init__(
        self,
        space: AddressSpace,
        *,
        policy: str | EvictionPolicy = "lrf",
        params: CostParams = MI250X,
        profile: bool = True,
        parallel_evict: bool = False,
        defer_granule: int | None = None,
        defer_k: int = 0,
        previct_watermark: float = 0.0,
        previct_overlap: float = 0.9,
        seed: int = 0,
    ) -> None:
        self.space = space
        self.params = params
        self.policy = (policy if isinstance(policy, EvictionPolicy)
                       else make_policy(policy))
        self.profile = profile
        self.parallel_evict = parallel_evict
        self.defer_granule = defer_granule
        self.defer_k = defer_k
        self.previct_watermark = previct_watermark
        self.previct_overlap = previct_overlap
        self._seed = seed

        self.capacity = space.capacity
        self.free = space.capacity
        self.resident: set[int] = set()
        self.pinned: set[int] = set()
        self.zero_copy_allocs: set[int] = set()
        self._defer_count: dict[int, int] = {}

        # clock & ledgers
        self.wall = 0.0                 # critical-path seconds
        self.compute_time = 0.0
        self.cost = CostVector()        # five-term host-visible work
        self.evict_cost_total = 0.0     # also folded into cost.alloc
        self.chaos_wall = 0.0           # injected latency (faults/backoff)

        # chaos hooks: armed migration-fault countdown + fault ledger
        self.fault_armed = 0
        self.migration_faults = 0

        # counters
        self.n_migrations = 0
        self.n_evictions = 0
        self.n_zerocopy = 0
        self.bytes_migrated = 0
        self.bytes_evicted = 0
        self.bytes_zerocopy = 0
        self.faults_serviceable = 0
        self.faults_duplicate = 0
        self.trigger_pages: set[int] = set()

        # profiles
        self.events: list[Event] = []
        self.density: list[DensitySample] = []

        # push-based eviction notification: callbacks fire with the evicted
        # rid, and the epoch counter bumps once per eviction, so clients
        # (e.g. the streaming executor's device pool) can invalidate only
        # what actually changed instead of rescanning residency
        self.eviction_epoch = 0
        self._evict_listeners: list = []

    # ------------------------------------------------------------------ api

    def pin(self, rid: int) -> None:
        """Pin a resident range (excluded from eviction). Migrates it first
        if needed (app-directed placement, as in SGEMM-svm-aware §4.1)."""
        if rid not in self.resident:
            self.touch(rid, concurrency=1)
        self.pinned.add(rid)
        self.policy.remove(rid)

    def unpin(self, rid: int) -> None:
        if rid in self.pinned:
            self.pinned.discard(rid)
            if rid in self.resident:
                self.policy.insert(rid, self.wall)

    def set_zero_copy(self, alloc_id: int) -> None:
        """Mark an allocation host-pinned / zero-copy (paper §4.2)."""
        self.zero_copy_allocs.add(alloc_id)

    def add_evict_listener(self, callback) -> None:
        """Register ``callback(rid)`` to fire whenever a range is evicted."""
        self._evict_listeners.append(callback)

    def previct(self, rid: int, *, overlap: float = 0.0) -> float:
        """Pre-evict a specific resident range off the migration critical
        path (background eviction, cf. §4.2 / Li et al. ASPLOS'19).

        ``overlap`` is the fraction of the eviction cost hidden behind
        concurrent compute; the remainder lands on the wall clock.  Returns
        the full eviction cost (0.0 if the range was not evictable)."""
        if rid not in self.resident or rid in self.pinned:
            return 0.0
        w = self._evict(rid, charge=None)
        self.wall += w * (1.0 - overlap)
        return w

    def spill_oldest(self, *, overlap: float = 0.0) -> int | None:
        """Pre-evict the policy's current victim (oldest under LRF/FIFO);
        returns its rid, or None when nothing is evictable."""
        if len(self.policy) == 0:
            return None
        victim = self.policy.victim()
        self.previct(victim, overlap=overlap)
        return victim

    def advance(self, seconds: float) -> None:
        """Pure device compute time (no driver involvement)."""
        self.wall += seconds
        self.compute_time += seconds

    # -------------------------------------------------------- chaos hooks
    #
    # Public entry points for the fault-injection layer (docs/
    # robustness.md).  They are deliberately *not* op-driving calls: the
    # runtime layer may invoke them directly without breaking the
    # manager-encapsulation contract, because none of them replays a
    # recorded access — they perturb the environment the replays run in.

    def inject_latency(self, seconds: float) -> None:
        """Charge injected wall time (slow-page surcharge, retry
        backoff) to the critical path.  Not compute, not migration work —
        ledgered separately in ``chaos_wall``."""
        self.wall += seconds
        self.chaos_wall += seconds

    def arm_migration_faults(self, n: int) -> None:
        """Arm the next ``n`` migrations to raise `MigrationError`
        (``n=0`` disarms).  The raise happens before any state mutation
        for that migration, so a retry sees the manager unchanged."""
        self.fault_armed = int(n)

    def resize_capacity(self, new_capacity: int) -> float:
        """Transient co-tenancy: grow/shrink the device pool at runtime
        (another tenant grabbed or released pool bytes).  Shrinking below
        current occupancy emergency-evicts policy victims until the pool
        fits again; the eviction wall lands on the critical path.
        Returns the emergency-eviction wall cost."""
        new_capacity = int(new_capacity)
        if new_capacity < 1:
            raise ValueError("pool capacity must stay positive")
        delta = new_capacity - self.capacity
        self.capacity = new_capacity
        self.free += delta
        w = 0.0
        while self.free < 0:
            victim = self._pick_victim()
            w += self._evict(victim, charge=None)
        self.wall += w
        return w

    def touch(
        self,
        rid: int,
        *,
        bytes_touched: int | None = None,
        concurrency: int = 32,
        page_hint: int | None = None,
        write: bool = False,
    ) -> bool:
        """The kernel accesses data in range `rid`.

        Returns True if the access hit resident data (no migration).
        ``concurrency`` models the number of in-flight wavefront page
        requests during a fault-service window — it sets the duplicate-fault
        count (fault density) for a triggered migration.
        ``page_hint`` identifies the faulting page (defaults to the range's
        first page — linear kernels fault at range starts, paper Fig. 9d-f).
        """
        r = self.space.ranges[rid]
        if r.alloc_id in self.zero_copy_allocs:
            nb = bytes_touched if bytes_touched is not None else r.size
            self.wall += zerocopy_cost(nb, self.params)
            self.n_zerocopy += 1
            self.bytes_zerocopy += nb
            if self.profile:
                self.events.append(Event(self.wall, "zc", rid, r.alloc_id, nb))
            return True

        if rid in self.resident:
            self.policy.on_touch(rid, self.wall)
            return True

        # -------- serviceable page fault → range migration (paper §2.2)
        trigger = (r.start // 4096) + (page_hint or 0)
        self.faults_serviceable += 1
        self.trigger_pages.add(trigger)
        if concurrency >= 32:
            # high-occupancy kernels land a second in-flight fault page in
            # the driver before CAM dedupe (paper Fig. 9d-f: ≈2 faulting
            # pages per migration for STREAM/SGEMM)
            self.trigger_pages.add(trigger + 1)

        # adaptive granularity: defer full-range migration (§4.2)
        if self.defer_granule and self.defer_k > 0:
            c = self._defer_count.get(rid, 0) + 1
            self._defer_count[rid] = c
            if c < self.defer_k:
                nb = min(self.defer_granule, r.size)
                self._migrate_bytes(nb, r, resident=False,
                                    concurrency=concurrency, trigger=trigger)
                return False

        self._migrate_bytes(r.size, r, resident=True,
                            concurrency=concurrency, trigger=trigger)
        return False

    def writeback(self, rid: int) -> None:
        """Algorithmic device→host transfer (e.g. BFS frontier output).

        Counted as an eviction (paper §3.4: BFS's eviction-to-migration
        ratio is nonzero even below DOS 100 because it "algorithmically
        transfers data from the device to the host")."""
        if rid in self.resident:
            w = self._evict(rid, charge=None)
            self.wall += w

    # ------------------------------------------------------------ internals

    def _noise(self, k: int) -> float:
        """Deterministic ±20 % jitter for fault-density samples."""
        h = (k * 2654435761 + self._seed * 97) & 0xFFFFFFFF
        return 0.8 + 0.4 * (h / 0xFFFFFFFF)

    def _migrate_bytes(self, nbytes: int, r: Range, *, resident: bool,
                       concurrency: int, trigger: int) -> None:
        if self.fault_armed > 0:
            # armed chaos fault: fail this migration before touching any
            # state (counters, residency, policy, clock all unchanged)
            self.fault_armed -= 1
            self.migration_faults += 1
            raise MigrationError(
                f"injected migration failure on range {r.rid} "
                f"({nbytes} bytes)")
        mc = migration_cost(nbytes, self.params)

        # ---- allocation: evict until there is room (paper §2.2, Fig. 3)
        base_mig = mc.total()  # migration work excluding evictions
        evict_wall = 0.0
        while self.free < nbytes:
            victim = self._pick_victim()
            evict_wall += self._evict(victim, charge=mc)

        if self.parallel_evict and evict_wall > 0.0:
            # §4.2 Parallel Implementation: overlap eviction(s) with the
            # blocked migration; lock/rollback overhead on top.
            wall_delta = max(base_mig, evict_wall) + 5e-6
        else:
            wall_delta = mc.total()  # evictions already folded into mc.alloc

        self.cost.add(mc)
        self.wall += wall_delta
        self.n_migrations += 1
        self.bytes_migrated += nbytes
        if resident:
            self.free -= nbytes
            self.resident.add(r.rid)
            if r.rid not in self.pinned:
                self.policy.insert(r.rid, self.wall)
            self._defer_count.pop(r.rid, None)
        else:
            pass  # deferred granule copy: not tracked as residency

        dup = max(0, int(concurrency * self._noise(self.n_migrations)) - 1)
        self.faults_duplicate += dup
        if self.profile:
            self.events.append(
                Event(self.wall, "mig", r.rid, r.alloc_id, nbytes))
            self.density.append(
                DensitySample(self.wall, r.rid, r.alloc_id, 1 + dup, trigger))

        # background pre-eviction below watermark (beyond paper)
        if self.previct_watermark > 0.0:
            target = self.previct_watermark * self.capacity
            while self.free < target and len(self.policy) > 0:
                victim = self._pick_victim()
                w = self._evict(victim, charge=None)
                # mostly off critical path
                self.wall += w * (1.0 - self.previct_overlap)

    def _pick_victim(self) -> int:
        if len(self.policy) == 0:
            raise RuntimeError(
                "SVM: device full of pinned/unevictable ranges "
                f"(free={self.free}, need more; pinned={len(self.pinned)})")
        return self.policy.victim()

    def _evict(self, rid: int, charge: CostVector | None) -> float:
        """Evict one range; returns its wall cost. If `charge` is given the
        cost is folded into that migration's `alloc` term (paper §2.4)."""
        r = self.space.ranges[rid]
        ec = eviction_cost(r.size, self.params)
        if charge is not None:
            charge.alloc += ec
        else:
            self.cost.alloc += ec
        self.evict_cost_total += ec
        self.policy.remove(rid)
        self.resident.discard(rid)
        self.free += r.size
        self.n_evictions += 1
        self.bytes_evicted += r.size
        self.eviction_epoch += 1
        if self._evict_listeners:
            for cb in self._evict_listeners:
                cb(rid)
        if self.profile:
            self.events.append(Event(self.wall, "evt", rid, r.alloc_id, r.size))
        return ec

    # ------------------------------------------------------------- metrics

    @property
    def faults_total(self) -> int:
        return self.faults_serviceable + self.faults_duplicate

    @property
    def duplicate_share(self) -> float:
        t = self.faults_total
        return self.faults_duplicate / t if t else 0.0

    @property
    def evict_to_mig_ratio(self) -> float:
        return self.n_evictions / self.n_migrations if self.n_migrations else 0.0

    @property
    def mean_fault_density(self) -> float:
        if not self.density:
            return 0.0
        return sum(d.faults for d in self.density) / len(self.density)

    @property
    def serviceable_per_migration(self) -> float:
        """Unique trigger pages / migrations (paper Fig. 9d-f: ≈2 for
        streaming, ≈0.05 for thrashing GESUMMV)."""
        if not self.n_migrations:
            return 0.0
        return len(self.trigger_pages) / self.n_migrations

    def summary(self) -> dict:
        return {
            "wall_s": self.wall,
            "compute_s": self.compute_time,
            "migrations": self.n_migrations,
            "evictions": self.n_evictions,
            "evict_to_mig": self.evict_to_mig_ratio,
            "bytes_migrated": self.bytes_migrated,
            "bytes_evicted": self.bytes_evicted,
            "faults_serviceable": self.faults_serviceable,
            "faults_duplicate": self.faults_duplicate,
            "duplicate_share": self.duplicate_share,
            "mean_fault_density": self.mean_fault_density,
            "serviceable_per_migration": self.serviceable_per_migration,
            "cost_breakdown": self.cost.as_dict(),
            "dos": self.space.dos(),
            "capacity_bytes": self.capacity,
            "chaos_wall_s": self.chaos_wall,
            "migration_faults": self.migration_faults,
        }
