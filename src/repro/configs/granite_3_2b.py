"""granite-3-2b: 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base; hf]."""

import dataclasses

from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    vocab=49155,
    d_model=2048,
    n_layers=40,
    d_ff=8192,
    n_heads=32,
    n_kv_heads=8,
    layer_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=128,
        n_heads=4, n_kv_heads=2)
