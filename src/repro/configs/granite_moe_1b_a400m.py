"""granite-moe-1b-a400m: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base;
hf]."""

import dataclasses

from repro.models.config import ATTN, MOE, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    vocab=49155,
    d_model=1024,
    n_layers=24,
    d_ff=512,
    n_heads=16,
    n_kv_heads=8,
    layer_pattern=(ATTN,),
    ffn_pattern=(MOE,),
    n_experts=32,
    top_k=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=64,
        n_heads=4, n_kv_heads=2, n_experts=8, top_k=2)
