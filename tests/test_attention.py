"""Blockwise (flash-semantics) attention vs direct softmax attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import attention as A


def _mk(B, S, T, KV, G, D, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D), jnp.float32) * D ** -0.5
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("S,T", [(32, 32), (24, 40), (33, 100)])
def test_blockwise_matches_direct_causal(S, T, window):
    B, KV, G, D = 2, 2, 2, 8
    q, k, v = _mk(B, S, T, KV, G, D)
    qpos = jnp.broadcast_to(jnp.arange(T - S, T), (B, S))  # suffix queries
    kpos = jnp.broadcast_to(jnp.arange(T), (B, T))
    direct = A._attend(q, k, v, qpos, kpos, window)
    old = A.BLOCK_T
    try:
        A.BLOCK_T = 16
        block = A._blockwise_attention(q, k, v, qpos, kpos, window)
    finally:
        A.BLOCK_T = old
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_matches_direct_noncausal():
    B, S, T, KV, G, D = 2, 16, 50, 2, 2, 8
    q, k, v = _mk(B, S, T, KV, G, D)
    direct = A._attend(q, k, v, None, None, 0)
    old_thresh, old_bt = A.FLASH_THRESHOLD, A.BLOCK_T
    try:
        A.FLASH_THRESHOLD, A.BLOCK_T = 1, 16   # force blockwise path
        block = A._attend(q, k, v, None, None, 0)
    finally:
        A.FLASH_THRESHOLD, A.BLOCK_T = old_thresh, old_bt
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=40),
    t_extra=st.integers(min_value=0, max_value=40),
    window=st.sampled_from([0, 3, 16]),
    bt=st.sampled_from([8, 16, 32]),
)
def test_property_blockwise_equivalence(s, t_extra, window, bt):
    t = s + t_extra
    q, k, v = _mk(1, s, t, 1, 2, 4, key=s * 100 + t)
    qpos = jnp.broadcast_to(jnp.arange(t - s, t), (1, s))
    kpos = jnp.broadcast_to(jnp.arange(t), (1, t))
    direct = A._attend(q, k, v, qpos, kpos, window)
    old = A.BLOCK_T
    try:
        A.BLOCK_T = bt
        block = A._blockwise_attention(q, k, v, qpos, kpos, window)
    finally:
        A.BLOCK_T = old
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_grads_finite():
    B, S, T, KV, G, D = 1, 16, 32, 1, 2, 4
    q, k, v = _mk(B, S, T, KV, G, D)
    qpos = jnp.broadcast_to(jnp.arange(T - S, T), (B, S))
    kpos = jnp.broadcast_to(jnp.arange(T), (B, T))
    old = A.BLOCK_T
    try:
        A.BLOCK_T = 8
        g = jax.grad(lambda q_: jnp.sum(
            A._blockwise_attention(q_, k, v, qpos, kpos, 0)
            .astype(jnp.float32)))(q)
    finally:
        A.BLOCK_T = old
    assert bool(jnp.isfinite(g).all())
