"""UVM-style baseline manager (paper Table 1 comparison).

Models the NVIDIA-UVM design points the paper contrasts with SVM:

  * UM (de)allocation in 2 MB **VABlocks** (vs SVM ranges up to 1 GB),
  * migration unit: 64 KB base pages, coalesced up to a VABlock by a
    density/tree prefetcher (contiguous faulting blocks in one batch are
    migrated as one transfer),
  * **fault batching**: up to 256 faults buffered and serviced together
    (vs SVM's immediate single-fault servicing),
  * eviction at VABlock granularity (LRU over blocks).

Exposes the same trace-facing API as SVMManager (`touch`, `advance`,
`writeback`, `pin`, `summary`) so the simulator can drive either.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.costmodel import CostParams, CostVector, MI250X, migration_cost
from repro.core.ranges import AddressSpace, MB
from repro.core.svm import Event

VABLOCK = 2 * MB
BASE_CHUNK = 64 * 1024
MAX_BATCH = 256

BATCH_FIXED_S = 45e-6     # GPU->host interrupt + batch preprocessing
PER_FAULT_S = 2.5e-6      # per-fault decode/dedupe within a batch


class UVMManager:
    def __init__(
        self,
        space: AddressSpace,
        *,
        params: CostParams = MI250X,
        profile: bool = True,
        prefetch: bool = True,
        **_ignored,
    ) -> None:
        self.space = space
        self.params = params
        self.profile = profile
        self.prefetch = prefetch
        self.capacity = space.capacity
        self.free = space.capacity
        # resident VABlocks: block_id -> last-use time (LRU)
        self.resident: OrderedDict[int, float] = OrderedDict()
        self.pinned: set[int] = set()

        self.wall = 0.0
        self.compute_time = 0.0
        self.cost = CostVector()
        self.n_migrations = 0      # transfers (after coalescing)
        self.n_evictions = 0
        self.n_batches = 0
        self.bytes_migrated = 0
        self.bytes_evicted = 0
        self.faults_serviceable = 0
        self.faults_duplicate = 0
        self.trigger_pages: set[int] = set()
        self.events: list[Event] = []
        self.density: list = []
        self._batch: list[int] = []   # pending faulting block ids

    # -------------------------------------------------------------- helpers

    def _blocks_of_range(self, rid: int) -> range:
        r = self.space.ranges[rid]
        return range(r.start // VABLOCK, -(-r.end // VABLOCK))

    # ------------------------------------------------------------------ api

    def touch(self, rid: int, *, bytes_touched: int | None = None,
              concurrency: int = 32, page_hint: int | None = None,
              write: bool = False) -> bool:
        hit = True
        for b in self._blocks_of_range(rid):
            if b in self.resident:
                self.resident.move_to_end(b)
                self.resident[b] = self.wall
            else:
                hit = False
                self._batch.append(b)
                self.faults_serviceable += 1
                self.trigger_pages.add(b * (VABLOCK // 4096))
                self.faults_duplicate += max(0, concurrency // 8)
                if len(self._batch) >= MAX_BATCH:
                    self._service_batch()
        self._service_batch()
        return hit

    def advance(self, seconds: float) -> None:
        self.wall += seconds
        self.compute_time += seconds

    def writeback(self, rid: int) -> None:
        for b in self._blocks_of_range(rid):
            if b in self.resident:
                self._evict(b)

    def pin(self, rid: int) -> None:
        self.touch(rid, concurrency=1)
        for b in self._blocks_of_range(rid):
            self.pinned.add(b)
            self.resident.pop(b, None)  # memory accounting unchanged

    def unpin(self, rid: int) -> None:
        for b in self._blocks_of_range(rid):
            if b in self.pinned:
                self.pinned.discard(b)
                self.resident[b] = self.wall

    # ------------------------------------------------------------ internals

    def _service_batch(self) -> None:
        if not self._batch:
            return
        blocks = sorted(set(self._batch))
        self._batch.clear()
        self.n_batches += 1
        self.wall += BATCH_FIXED_S + PER_FAULT_S * len(blocks)
        # tree/density prefetcher: coalesce contiguous faulting blocks
        groups: list[list[int]] = [[blocks[0]]]
        for b in blocks[1:]:
            if self.prefetch and b == groups[-1][-1] + 1:
                groups[-1].append(b)
            else:
                groups.append([b])
        for g in groups:
            nbytes = len(g) * VABLOCK
            # make room at VABlock granularity (LRU)
            while self.free < nbytes:
                victim = self._lru_victim()
                self._evict(victim)
            mc = migration_cost(nbytes, self.params)
            self.cost.add(mc)
            self.wall += mc.total()
            self.n_migrations += 1
            self.bytes_migrated += nbytes
            for b in g:
                self.resident[b] = self.wall
            self.free -= nbytes
            if self.profile:
                rid = self._rid_of_block(g[0])
                self.events.append(Event(self.wall, "mig", rid,
                                         self.space.ranges[rid].alloc_id,
                                         nbytes))

    def _rid_of_block(self, b: int) -> int:
        addr = min(b * VABLOCK, self.space.ranges[-1].end - 1)
        addr = max(addr, self.space.ranges[0].start)
        return self.space.range_at(addr).rid

    def _lru_victim(self) -> int:
        for b in self.resident:
            if b not in self.pinned:
                return b
        raise RuntimeError("UVM: all resident blocks pinned")

    def _evict(self, b: int) -> None:
        mc = migration_cost(VABLOCK, self.params).total()
        self.cost.alloc += mc
        self.wall += mc
        self.resident.pop(b, None)
        self.free += VABLOCK
        self.n_evictions += 1
        self.bytes_evicted += VABLOCK
        if self.profile:
            rid = self._rid_of_block(b)
            self.events.append(Event(self.wall, "evt", rid,
                                     self.space.ranges[rid].alloc_id, VABLOCK))

    # ------------------------------------------------------------- metrics

    @property
    def faults_total(self) -> int:
        return self.faults_serviceable + self.faults_duplicate

    @property
    def evict_to_mig_ratio(self) -> float:
        return self.n_evictions / self.n_migrations if self.n_migrations else 0.0

    def summary(self) -> dict:
        return {
            "wall_s": self.wall,
            "compute_s": self.compute_time,
            "migrations": self.n_migrations,
            "evictions": self.n_evictions,
            "batches": self.n_batches,
            "evict_to_mig": self.evict_to_mig_ratio,
            "bytes_migrated": self.bytes_migrated,
            "bytes_evicted": self.bytes_evicted,
            "faults_serviceable": self.faults_serviceable,
            "faults_duplicate": self.faults_duplicate,
            "cost_breakdown": self.cost.as_dict(),
            "dos": self.space.dos(),
        }
