"""Validation of the paper's workload taxonomy (Figs. 6–10, §3).

These tests assert the *claims of the paper* against our simulator:
category membership, asymptotes, thrashing onset, fault densities, and the
SVM-aware algorithm wins. Calibration targets are documented in
EXPERIMENTS.md §Validation.
"""

import pytest

from repro.core import GB, dos_sweep, simulate
from repro.core.traces import (
    BFS,
    Conv2d,
    Gesummv,
    Jacobi2d,
    Mvt,
    Sgemm,
    Stream,
    Syr2k,
    make_workload,
)

CAP = 8 * GB


def _sweep(factory, dos):
    rows = dos_sweep(factory, dos, CAP)
    return {round(r["dos"]): r for r in rows}


# ------------------------------------------------------------ categories

def test_category1_stream_declines_moderately():
    r = _sweep(lambda b: Stream(b), [78, 109, 156])
    assert r[109]["norm_perf"] > 0.90
    assert 0.55 < r[156]["norm_perf"] < 0.95
    # no evictions below DOS 100
    assert r[78]["evict_to_mig"] == 0.0


def test_stream_asymptote_half_peak():
    """Paper §3.2: STREAM's performance asymptotically approaches 1/2 as the
    eviction-to-migration ratio approaches 1."""
    r = _sweep(lambda b: Stream(b), [78, 900])
    assert r[900]["norm_perf"] == pytest.approx(0.5, abs=0.06)
    assert r[900]["evict_to_mig"] > 0.85


def test_category2_jacobi_cliff_then_flat():
    """Paper: 'performance decreases to about 40% at DOS=109' then
    'minimally changes thereafter' (asymptote ≈0.36)."""
    r = _sweep(lambda b: Jacobi2d(b), [78, 95, 109, 156, 300])
    assert r[95]["norm_perf"] > 0.95           # no cliff below 100
    assert r[109]["norm_perf"] == pytest.approx(0.40, abs=0.05)
    assert r[156]["norm_perf"] == pytest.approx(r[109]["norm_perf"], abs=0.05)
    assert r[300]["norm_perf"] == pytest.approx(0.37, abs=0.05)


def test_category3_abrupt_mvt_gesummv():
    for cls in (Mvt, Gesummv):
        r = _sweep(lambda b, c=cls: c(b), [78, 95, 109])
        assert r[95]["norm_perf"] > 0.9
        assert r[109]["norm_perf"] < 0.05      # near zero right past 100
        assert r[109]["evict_to_mig"] > 0.9


def test_category3_gradual_sgemm_syr2k():
    for cls in (Sgemm, Syr2k):
        r = _sweep(lambda b, c=cls: c(b), [78, 109, 125, 156])
        # gradual: strictly decreasing, not an instant collapse at 109
        assert 0.3 < r[109]["norm_perf"] < 0.95
        assert r[125]["norm_perf"] < r[109]["norm_perf"]
        assert r[156]["norm_perf"] < 0.35


def test_migration_count_explosion():
    """Paper Fig. 10b: Category III migration counts increase by an order
    of magnitude or more; Category I only linearly."""
    for cls in (Mvt, Gesummv):
        r = _sweep(lambda b, c=cls: c(b), [78, 109])
        assert r[109]["migrations"] > 10 * r[78]["migrations"]
    r = _sweep(lambda b: Sgemm(b), [78, 156])
    assert r[156]["migrations"] > 10 * r[78]["migrations"]
    r = _sweep(lambda b: Stream(b), [78, 156])
    assert r[156]["migrations"] < 3 * r[78]["migrations"]   # linear-ish


def test_sgemm_exponential_past_140():
    r = _sweep(lambda b: Sgemm(b), [109, 125, 140, 156])
    g1 = r[125]["migrations"] / r[109]["migrations"]
    g2 = r[156]["migrations"] / r[140]["migrations"]
    assert g2 > g1  # accelerating growth


def test_evict_to_mig_ratio_shape():
    """Fig. 10a: ratio 0 below DOS 100 (except BFS); jumps to ~1 for
    Category III; grows slowly for Category I."""
    for cls in (Stream, Conv2d, Jacobi2d, Sgemm, Syr2k, Mvt, Gesummv):
        r = _sweep(lambda b, c=cls: c(b), [78])
        assert r[78]["evict_to_mig"] == 0.0, cls.name
    r = _sweep(lambda b: BFS(b), [78])
    assert r[78]["evict_to_mig"] > 0.0       # algorithmic writebacks
    fast = _sweep(lambda b: Gesummv(b), [109])[109]["evict_to_mig"]
    slow = _sweep(lambda b: Stream(b), [109])[109]["evict_to_mig"]
    assert fast > 0.9 > slow


# ---------------------------------------------------------- fault behaviour

def test_fault_density_ordering():
    """Fig. 8: STREAM highest (150–250); Conv2d somewhat lower; Jacobi next;
    SGEMM < 50; GESUMMV ≈ 20; BFS very low."""
    dens = {}
    for name in ("stream", "conv2d", "jacobi2d", "sgemm", "gesummv", "bfs"):
        wl = make_workload(name, int(CAP * 1.09))
        res = simulate(wl, CAP)
        dens[name] = res.summary["mean_fault_density"]
    assert 150 <= dens["stream"] <= 250
    assert dens["conv2d"] < dens["stream"]
    assert dens["jacobi2d"] < dens["conv2d"]
    assert dens["sgemm"] < 50
    assert 10 <= dens["gesummv"] <= 30
    assert dens["bfs"] < 20


def test_duplicate_fault_share():
    """§2.1: duplicate faults represent 97–99 % of all faults for
    high-occupancy streaming kernels."""
    res = simulate(Stream(int(CAP * 0.78)), CAP)
    assert 0.97 <= res.summary["duplicate_share"] <= 0.999


def test_serviceable_faults_per_migration():
    """Fig. 9d-f: ≈2 faulting pages per migration for STREAM; ≈0.05 for
    thrashing GESUMMV (20 migrations per unique faulting page)."""
    res = simulate(Stream(int(CAP * 1.09)), CAP)
    assert res.summary["serviceable_per_migration"] == pytest.approx(2.0, abs=0.5)
    res = simulate(Gesummv(int(CAP * 1.09)), CAP)
    assert res.summary["serviceable_per_migration"] < 0.15


# ---------------------------------------------------------- SVM-aware wins

def test_svm_aware_jacobi():
    """§4.1: SVM-aware Jacobi2d improves DOS=109 performance and the lower
    limit (paper: >2x and 1.5x; serial-fault-service model reproduces the
    direction with ≥1.4x / ≥1.15x — deviation documented in EXPERIMENTS.md)."""
    naive = _sweep(lambda b: Jacobi2d(b), [78, 109, 300])
    aware = _sweep(lambda b: Jacobi2d(b, svm_aware=True), [78, 109, 300])
    assert aware[109]["norm_perf"] / naive[109]["norm_perf"] > 1.4
    assert aware[300]["norm_perf"] / naive[300]["norm_perf"] > 1.15
    assert aware[109]["evictions"] < 0.5 * naive[109]["evictions"]


def test_svm_aware_sgemm():
    """§4.1: SGEMM-svm-aware sustains ≈0.75+ at DOS=156 (orders of magnitude
    over the collapsing naive version) and scales to DOS ≈ 300."""
    naive = _sweep(lambda b: Sgemm(b), [78, 156])
    aware = _sweep(lambda b: Sgemm(b, svm_aware=True), [78, 156, 280])
    assert aware[156]["norm_perf"] > 0.7
    assert aware[156]["norm_perf"] > 3 * naive[156]["norm_perf"]
    assert aware[280]["norm_perf"] > 0.6     # still viable near DOS 300
    assert aware[156]["migrations"] < 0.3 * naive[156]["migrations"]
