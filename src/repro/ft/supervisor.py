"""Fault tolerance for 1000+ node operation.

Three mechanisms, matching what a production pod-scale trainer needs:

  1. **Checkpoint/restart** — the TrainSupervisor drives the step loop with
     periodic async checkpoints and restarts from the latest committed step
     after any failure (simulated here via exception injection; on a real
     cluster the same path handles preemptions/ICI failures, since jax
     computations are functional and the data pipeline is step-addressable).
  2. **Straggler mitigation** — per-step wall times feed a robust z-score
     monitor; hosts that exceed `threshold x median` for `patience`
     consecutive steps are flagged for eviction from the next elastic plan
     (on TPU pods a straggling host slows every collective, so detection is
     global and cheap).
  3. **Elastic re-mesh** — on pod loss, `plan_elastic_remesh` computes the
     survivor mesh (dropping the pod axis entry) and the per-parameter
     resharding plan: ZeRO/FSDP shards owned by the dead pod are recovered
     from the last checkpoint, everything else reshapes in place. Global
     batch is preserved by raising per-pod microbatching.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Iterator

from repro.checkpoint import CheckpointManager
from repro.ft.retry import RetryBudget, RetryPolicy

PyTree = Any


# ------------------------------------------------------------- stragglers

class StragglerMonitor:
    """Per-step wall times feed a median-relative slowness check; each
    host's strike counting runs on a `RetryBudget` (``max_attempts =
    patience``): a slow step spends one attempt, a normal step re-arms,
    and an exhausted budget flags the host for the next elastic plan."""

    def __init__(self, threshold: float = 1.8, patience: int = 3,
                 window: int = 32):
        self.threshold = threshold
        self.patience = patience
        self.window = window
        self.history: dict[int, list[float]] = {}
        self._budgets: dict[int, RetryBudget] = {}

    @property
    def strikes(self) -> dict[int, int]:
        """Consecutive slow-step strikes per host (budget attempts)."""
        return {h: b.attempts for h, b in self._budgets.items()}

    def _budget(self, host: int) -> RetryBudget:
        b = self._budgets.get(host)
        if b is None:
            b = self._budgets[host] = RetryBudget(
                RetryPolicy(max_attempts=max(1, self.patience)))
        return b

    def record(self, host: int, step_time: float) -> None:
        self.history.setdefault(host, []).append(step_time)
        self.history[host] = self.history[host][-self.window:]

    def flagged(self) -> list[int]:
        if len(self.history) < 2:
            return []
        med = statistics.median(
            t for ts in self.history.values() for t in ts)
        out = []
        for host, ts in self.history.items():
            b = self._budget(host)
            if ts and ts[-1] > self.threshold * med:
                b.spend()
            else:
                b.reset()
            if b.exhausted:
                out.append(host)
        return out


# ------------------------------------------------------------ elastic mesh

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    lost_pods: tuple[int, ...]
    microbatch_scale: int          # multiply microbatches to keep batch
    resharding: str                # "restore_from_checkpoint" | "in_place"

    @property
    def surviving_chips(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def plan_elastic_remesh(shape: tuple[int, ...], axes: tuple[str, ...],
                        lost_pods: tuple[int, ...],
                        zero_sharded: bool) -> ElasticPlan:
    """Survivor mesh after losing pods. The pod axis is pure DP(+ZeRO), so
    the program is identical on the survivor mesh; ZeRO state owned by dead
    pods exists only in the checkpoint -> restore path required."""
    if "pod" not in axes:
        raise ValueError("elastic re-mesh requires a pod axis")
    pidx = axes.index("pod")
    pods = shape[pidx]
    survivors = pods - len(lost_pods)
    if survivors < 1:
        raise ValueError("no surviving pods")
    new_shape = list(shape)
    new_shape[pidx] = survivors
    scale = -(-pods // survivors)
    return ElasticPlan(
        old_shape=tuple(shape),
        new_shape=tuple(new_shape),
        axis_names=axes,
        lost_pods=tuple(lost_pods),
        microbatch_scale=scale,
        resharding="restore_from_checkpoint" if zero_sharded else "in_place",
    )


# ------------------------------------------------------------- supervisor

class TrainSupervisor:
    """Runs a step function under checkpoint/restart + straggler watch.

    Restart accounting runs on the shared `RetryBudget`
    (``max_attempts = max_restarts``): every failure spends one attempt
    and its deterministic exponential-backoff delay is ledgered in
    ``budget.backoff_s``; once the budget is exhausted the original
    failure re-raises."""

    def __init__(self, ckpt: CheckpointManager, *, max_restarts: int = 3,
                 retry_policy: RetryPolicy | None = None):
        self.ckpt = ckpt
        self.max_restarts = max_restarts
        if retry_policy is None:
            retry_policy = RetryPolicy(
                max_attempts=max(1, max_restarts), base_delay_s=1.0,
                max_delay_s=60.0)
        self.budget = RetryBudget(retry_policy)
        self.monitor = StragglerMonitor()
        self.log: list[str] = []

    @property
    def restarts(self) -> int:
        return self.budget.attempts

    def run(
        self,
        init_state: PyTree,
        step_fn: Callable[[int, PyTree], PyTree],
        steps: int,
        *,
        failure_injector: Callable[[int], None] | None = None,
    ) -> tuple[int, PyTree]:
        state = init_state
        step = 0
        restored = self.ckpt.restore_latest(init_state)
        if restored is not None:
            step, state = restored
            self.log.append(f"resumed from step {step}")
        while step < steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                t0 = time.time()
                state = step_fn(step, state)
                self.monitor.record(0, time.time() - t0)
                step += 1
                self.ckpt.maybe_save(step, state, blocking=True)
            except Exception as e:  # noqa: BLE001 — restart path
                self.log.append(f"failure at step {step}: {e!r}")
                if self.max_restarts < 1 or self.budget.exhausted:
                    raise
                delay = self.budget.spend()
                self.log.append(
                    f"backoff {delay:g}s "
                    f"({self.budget.remaining} restart(s) left)")
                restored = self.ckpt.restore_latest(init_state)
                if restored is None:
                    state, step = init_state, 0
                else:
                    step, state = restored
                self.log.append(f"restarted from step {step}")
        self.ckpt.wait()
        return step, state
