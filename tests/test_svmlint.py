"""svmlint: framework, per-rule true-positives/negatives, suppressions,
runtime frozen-column audit, live-tree cleanliness, CLI."""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import (
    RULES,
    SUPPRESSION_RULE,
    assert_frozen,
    frozen_violations,
    lint_paths,
    lint_source,
    opcode_universe,
)
from repro.core import MB, AddressSpace, SegmentCache, SVMManager, TraceSession
from repro.core.engine import CompiledTrace

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC_REPRO = os.path.join(REPO, "src", "repro")

# fixture paths that land a snippet inside / outside a rule's scope
CORE = "src/repro/core/fixture.py"
SVM = "src/repro/svm/fixture.py"
LAUNCH = "src/repro/launch/fixture.py"
DATA = "src/repro/data/fixture.py"


def rules_of(findings):
    return [f.rule for f in findings]


# -------------------------------------------------------------- framework

def test_registry_has_the_contract_rules():
    assert {"opcode-exhaustive", "frozen-mutation", "manager-encapsulation",
            "determinism", "counter-pairing", "bounded-retry"} <= set(RULES)
    for rule in RULES.values():
        assert rule.doc and rule.invariant


def test_opcode_universe_matches_engine():
    ops, tags = opcode_universe()
    assert ops == {"OP_TOUCH", "OP_COMPUTE", "OP_WRITEBACK", "OP_PIN",
                   "OP_UNPIN", "OP_SPILL"}
    assert tags == {"touch", "compute", "writeback", "pin", "unpin",
                    "spill", "kernel"}


def test_unknown_rule_name_rejected():
    with pytest.raises(KeyError, match="no-such-rule"):
        lint_source("x = 1", CORE, rules=["no-such-rule"])


# ------------------------------------------------------ opcode-exhaustive

def test_opcode_incomplete_dispatch_flagged():
    findings = lint_source("""
def dispatch(c, mgr):
    if c == OP_WRITEBACK:
        mgr_writeback(c)
    elif c == OP_PIN:
        mgr_pin(c)
""", CORE)
    assert rules_of(findings) == ["opcode-exhaustive"]
    assert "OP_TOUCH" in findings[0].message


def test_opcode_chain_with_rejecting_else_passes():
    assert lint_source("""
def dispatch(c, mgr):
    if c == OP_WRITEBACK:
        mgr_writeback(c)
    elif c == OP_PIN:
        mgr_pin(c)
    else:
        raise ValueError(c)
""", CORE) == []


def test_opcode_chain_with_delegating_else_passes():
    assert lint_source("""
def dispatch(c, mgr):
    if c == OP_TOUCH:
        pass
    elif c == OP_COMPUTE:
        pass
    else:
        exec_boundary(c, mgr)
""", CORE) == []


def test_opcode_full_coverage_passes():
    assert lint_source("""
def dispatch(c):
    if c in (OP_TOUCH, OP_COMPUTE, OP_WRITEBACK):
        pass
    elif c == OP_PIN or c == OP_UNPIN:
        pass
    elif c == OP_SPILL:
        pass
""", CORE) == []


def test_tag_dispatch_missing_kernel_flagged():
    findings = lint_source("""
def lower(op):
    if op[0] == "touch":
        pass
    elif op[0] in ("compute", "writeback", "pin", "unpin", "spill"):
        pass
""", CORE)
    assert rules_of(findings) == ["opcode-exhaustive"]
    assert "kernel" in findings[0].message


def test_non_dispatch_if_chain_ignored():
    # compares against at most one universe member: not a dispatch site
    assert lint_source("""
def f(mode):
    if mode == "fast":
        pass
    elif mode == "touch":
        pass
""", CORE) == []


# -------------------------------------------------------- frozen-mutation

def test_column_subscript_store_flagged():
    findings = lint_source("def f(ct):\n    ct.codes[3] = 7\n", SVM)
    assert rules_of(findings) == ["frozen-mutation"]


def test_column_augassign_and_inplace_method_flagged():
    findings = lint_source("""
def f(ct):
    ct.rids[ct.rids >= 0] += 4
    ct.fargs.fill(0.0)
""", CORE)
    assert rules_of(findings) == ["frozen-mutation", "frozen-mutation"]


def test_numpy_out_into_column_flagged():
    findings = lint_source(
        "def f(ct, x):\n    np.add(x, 1, out=ct.hints)\n", CORE)
    assert rules_of(findings) == ["frozen-mutation"]


def test_writeable_flip_outside_freeze_flagged():
    findings = lint_source(
        "def thaw(ct):\n    ct.codes.flags.writeable = True\n", CORE)
    assert rules_of(findings) == ["frozen-mutation"]


def test_freeze_path_and_builder_init_pass():
    assert lint_source("""
class CompiledTrace:
    def freeze(self):
        self.codes.flags.writeable = False
        return self

class ColumnEmitter:
    def __init__(self):
        self.codes = []
        self.rids = []
""", CORE) == []


def test_local_array_mutation_passes():
    # mutating a *local* copy (the relocate idiom) is fine
    assert lint_source("""
def relocate(ct, delta):
    rids = ct.rids.copy()
    rids[rids >= 0] += delta
    return rids
""", CORE) == []


# -------------------------------------------------- manager-encapsulation

def test_direct_drive_flagged_in_svm():
    findings = lint_source("def f(mgr):\n    mgr.touch(3)\n", SVM)
    assert rules_of(findings) == ["manager-encapsulation"]


def test_aliased_manager_drive_flagged():
    # `m = self.mgr; m.advance(...)` — invisible to the old source grep
    findings = lint_source("""
class Exec:
    def step(self):
        m = self.mgr
        m.advance(1e-3)
""", LAUNCH)
    assert rules_of(findings) == ["manager-encapsulation"]


def test_private_member_access_flagged():
    findings = lint_source(
        "def f(self):\n    return self.mgr._evict(1)\n", SVM)
    assert rules_of(findings) == ["manager-encapsulation"]


def test_readonly_manager_access_passes():
    assert lint_source("""
def report(self):
    return self.mgr.summary(), self.mgr.wall, self.mgr.resident
""", SVM) == []


def test_core_layer_out_of_scope():
    # the engine itself legitimately drives the manager
    assert lint_source("def f(mgr):\n    mgr.touch(3)\n", CORE) == []


# ------------------------------------------------------------ determinism

def test_unseeded_global_rng_flagged():
    findings = lint_source(
        "def f():\n    return np.random.rand(3)\n", DATA)
    assert rules_of(findings) == ["determinism"]


def test_unseeded_default_rng_flagged_seeded_passes():
    assert rules_of(lint_source(
        "rng = np.random.default_rng()\n", DATA)) == ["determinism"]
    assert lint_source("rng = np.random.default_rng(17)\n", DATA) == []


def test_hash_fed_seed_flagged():
    findings = lint_source("""
def f(kind, seed):
    return np.random.default_rng(
        np.random.SeedSequence([seed, hash(kind) % (2 ** 31)]))
""", DATA)
    assert rules_of(findings) == ["determinism"]
    assert "hash()" in findings[0].message


def test_wall_clock_scoped_to_simulation_layers():
    src = "def f():\n    return time.time()\n"
    assert rules_of(lint_source(src, SVM)) == ["determinism"]
    assert rules_of(lint_source(src, CORE)) == ["determinism"]
    # launch/ft time real host work legitimately
    assert lint_source(src, LAUNCH) == []


def test_set_iteration_flagged_sorted_passes():
    assert rules_of(lint_source("""
def keys(pts):
    for k in set(pts):
        emit(k)
""", CORE)) == ["determinism"]
    assert lint_source("""
def keys(pts):
    for k in sorted(set(pts)):
        emit(k)
""", CORE) == []


# -------------------------------------------------------- counter-pairing

def test_unpaired_before_read_flagged():
    findings = lint_source("""
def attribute(session, mgr):
    w0 = mgr.wall
    session.replay("k")
""", SVM)
    assert rules_of(findings) == ["counter-pairing"]
    assert "after" in findings[0].message


def test_unpaired_after_read_flagged():
    findings = lint_source("""
def attribute(session, mgr):
    session.replay("k")
    return mgr.n_evictions
""", SVM)
    assert rules_of(findings) == ["counter-pairing"]
    assert "before" in findings[0].message


def test_paired_reads_pass():
    assert lint_source("""
def attribute(session, mgr):
    w0, m0 = mgr.wall, mgr.n_migrations
    session.replay("k")
    return mgr.wall - w0, mgr.n_migrations - m0
""", SVM) == []


def test_thunk_replay_counts_as_replay():
    findings = lint_source("""
def attributed(self, fn):
    w0 = self.mgr.wall
    fn()
""", SVM)
    assert rules_of(findings) == ["counter-pairing"]


def test_execute_fused_result_is_the_after_snapshot():
    assert lint_source("""
def run_block(mega, mgr, cuts):
    w0 = mgr.wall
    snaps = execute_fused(mega, mgr, cuts)
    return snaps[:, 0] - w0
""", SVM) == []


def test_reads_without_replay_ignored():
    assert lint_source(
        "def report(mgr):\n    return mgr.wall\n", SVM) == []


# ---------------------------------------------------------- bounded-retry

def test_unbounded_swallowing_retry_loop_flagged():
    findings = lint_source("""
def recover(job):
    while True:
        try:
            return job()
        except OSError:
            continue
""", SVM)
    assert rules_of(findings) == ["bounded-retry"]
    assert "repro.ft.retry" in findings[0].message


def test_retry_loop_that_reraises_passes():
    assert lint_source("""
def recover(job):
    while True:
        try:
            return job()
        except OSError:
            log("transient")
            raise
""", SVM) == []


def test_retry_loop_with_attempt_counter_passes():
    assert lint_source("""
def recover(job):
    attempts = 0
    while True:
        try:
            return job()
        except OSError:
            attempts += 1
            if attempts >= 3:
                raise
""", SVM) == []


def test_retry_loop_spending_a_budget_passes():
    assert lint_source("""
def recover(self, job):
    while True:
        try:
            return job()
        except OSError:
            self.budget.spend()
""", SVM) == []


def test_for_loop_retry_passes():
    # a for-loop is bounded by construction; the shared retry_call
    # helper is built on exactly this shape
    assert lint_source("""
def recover(job):
    for _ in range(4):
        try:
            return job()
        except OSError:
            continue
""", SVM) == []


def test_nested_function_loop_is_its_own_scope():
    # the budget name lives in the *inner* function; the outer while
    # has no handler of its own and must not be flagged
    findings = lint_source("""
def outer(jobs):
    while jobs:
        job = jobs.pop()

        def attempt():
            try:
                return job()
            except OSError:
                return None
        attempt()
""", SVM)
    assert findings == []


# --------------------------------------------------------------- hot-loop

ENGINE = "src/repro/core/engine.py"


def test_hot_loop_over_column_flagged():
    findings = lint_source("""
def execute_all(ct, mgr):
    for rid in ct.trid_np:
        mgr_touch(rid)
""", ENGINE, rules=["hot-loop"])
    assert rules_of(findings) == ["hot-loop"]
    assert "trid_np" in findings[0].message


def test_hot_loop_enumerate_zip_tolist_forms_flagged():
    findings = lint_source("""
def _fold_charges(acc, tpos_np, trid_np, fargs):
    for i, rid in enumerate(trid_np):
        acc[rid] += 1
    for p, f in zip(tpos_np, fargs.tolist()):
        acc[p] += f
""", ENGINE, rules=["hot-loop"])
    assert rules_of(findings) == ["hot-loop", "hot-loop"]


def test_hot_loop_outside_execute_fold_functions_passes():
    # sequential reference oracles iterate columns by design
    assert lint_source("""
def _phase_a_lrf(mgr, tpos, trid, tab):
    for i, rid in enumerate(trid):
        mgr_probe(rid)
""", ENGINE, rules=["hot-loop"]) == []


def test_hot_loop_range_and_non_column_iters_pass():
    # index loops over miss/victim selections are O(misses), not O(ops)
    assert lint_source("""
def _fold_evictions(acc, m_nev, starts, ec_v):
    for j in range(int(m_nev.max())):
        acc += ec_v[starts + j]
    sel = np.nonzero(m_nev)[0]
    for i in sel.tolist():
        acc[i] += 1
""", ENGINE, rules=["hot-loop"]) == []


def test_hot_loop_outside_engine_passes():
    assert lint_source("""
def execute_all(ct, mgr):
    for rid in ct.trid_np:
        mgr_touch(rid)
""", CORE, rules=["hot-loop"]) == []


def test_hot_loop_suppressible_with_reason():
    assert lint_source("""
def execute_cold(ct, mgr):
    for rid in ct.trid_np:  # svmlint: disable=hot-loop -- cold error path
        mgr_touch(rid)
""", ENGINE, rules=["hot-loop"]) == []


# ------------------------------------------------------------ suppressions

def test_suppression_with_reason_silences():
    assert lint_source("""
def f():
    return time.time()  # svmlint: disable=determinism -- host-side timer
""", SVM) == []


def test_own_line_suppression_covers_next_line():
    assert lint_source("""
def f():
    # svmlint: disable=determinism -- host-side timer
    return time.time()
""", SVM) == []


def test_bare_suppression_is_itself_a_finding():
    findings = lint_source("""
def f():
    return time.time()  # svmlint: disable=determinism
""", SVM)
    assert rules_of(findings) == [SUPPRESSION_RULE]


def test_suppression_of_other_rule_does_not_silence():
    findings = lint_source("""
def f():
    return time.time()  # svmlint: disable=frozen-mutation -- wrong rule
""", SVM)
    assert rules_of(findings) == ["determinism"]


def test_disable_all_with_reason_silences_everything():
    assert lint_source("""
def f(mgr):
    mgr.touch(3)  # svmlint: disable=all -- fixture exercising the raw API
""", SVM) == []


# ------------------------------------------- runtime frozen-column audit

def _session(n=8, cap=64 * MB, align=2 * MB):
    space = AddressSpace(cap, alignment=align)
    for i in range(n):
        space.alloc(align, f"a{i}")
    return TraceSession(SVMManager(space, profile=False))


def _segment(sess, rids):
    for rid in rids:
        sess.touch(rid, concurrency=8)
    sess.compute(1e-4)
    return sess.seal()


def test_sealed_concat_and_relocated_traces_are_frozen():
    sess = _session()
    a = _segment(sess, (0, 1, 2))
    b = _segment(sess, (3, 4))
    for name, ct in [("sealed", a), ("relocated", a.relocate(3)),
                     ("concat", CompiledTrace.concat([a, b])),
                     ("copy", a.copy())]:
        assert frozen_violations(ct) == [], name
        assert_frozen(ct, where=name)


def test_batch_relocate_outputs_are_frozen():
    sess = _session()
    proto = _segment(sess, (0, 1))
    cache = SegmentCache()
    cache.put("tok", 0, proto)
    for ct in cache.batch_relocate("tok", [0, 2, 4]):
        assert frozen_violations(ct) == []


def test_unfrozen_trace_fails_the_audit():
    sess = _session()
    ct = _segment(sess, (0, 1))
    thawed = dataclasses.replace(ct, codes=ct.codes.copy())  # writeable
    assert frozen_violations(thawed) == \
        ["codes: writeable=True after freeze"]
    with pytest.raises(AssertionError, match="codes"):
        assert_frozen(thawed, where="thawed")


# --------------------------------------------------- live tree + CLI

def test_live_src_repro_tree_is_clean():
    findings = lint_paths([SRC_REPRO])
    assert findings == [], "\n".join(f.format() for f in findings)


def _cli(*args):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "svmlint.py"), *args],
        capture_output=True, text=True)


def test_cli_list_rules():
    res = _cli("--list-rules")
    assert res.returncode == 0
    for name in RULES:
        assert name in res.stdout


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "src" / "repro" / "svm" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(mgr):\n    mgr.touch(3)\n")
    res = _cli(str(bad))
    assert res.returncode == 1
    assert "[manager-encapsulation]" in res.stdout
    ok = tmp_path / "src" / "repro" / "svm" / "ok.py"
    ok.write_text("def f(mgr):\n    return mgr.wall\n")
    assert _cli(str(ok)).returncode == 0
    assert _cli("--rules", "no-such-rule", str(ok)).returncode == 2
