"""Multi-tenant serving over one shared SVM pool: 8 concurrent decode
requests of two (reduced) architectures contend for a device pool that
holds barely more than one model, under each scheduling policy.

  * fifo       — admit everything, round-robin: the paper's thrashing
                 pathology multiplied by N tenants.
  * admission  — cap admitted working-set bytes at the pool watermark;
                 later arrivals queue.
  * svm_aware  — admission + per-request hot-leaf pinning + same-arch
                 token batching (shared compiled-segment replays).

Same-architecture requests replay one shared compiled per-token segment
(relocated to each tenant's range offsets) — the `shared` column counts
those cross-request replays.

    PYTHONPATH=src python examples/serve_multitenant.py
"""

import dataclasses

import jax

from repro.configs import get_reduced
from repro.models import init_params
from repro.svm import ModelSpec, PoolScheduler, make_requests


def tiny(arch: str, n_layers: int, d_model: int, d_ff: int):
    cfg = dataclasses.replace(get_reduced(arch), n_layers=n_layers,
                              d_model=d_model, d_ff=d_ff)
    return init_params(cfg, jax.random.PRNGKey(0))


def main() -> None:
    specs = [
        ModelSpec.from_params("gemma3-1b", tiny("gemma3-1b", 6, 128, 512),
                              batch=4),
        ModelSpec.from_params("granite-3-2b",
                              tiny("granite-3-2b", 8, 192, 768), batch=4),
    ]
    # pool: slightly smaller than the larger model — the big arch is
    # individually oversubscribed (svm_aware's pinning regime), small-arch
    # pairs fit, and the full 8-request mix offers ~450 % DOS
    cap = int(max(s.total_bytes for s in specs) * 0.9)
    offered = sum(specs[i % 2].total_bytes for i in range(8))
    print(f"pool {cap / 1e6:.1f}MB; 8 requests "
          f"({specs[0].total_bytes / 1e6:.1f}MB gemma-ish / "
          f"{specs[1].total_bytes / 1e6:.1f}MB granite-ish), "
          f"offered DOS {offered / cap * 100:.0f}%\n")

    print(f"  {'policy':10s} {'p50':>8s} {'p99':>8s} {'tok/s':>7s} "
          f"{'ev/tok':>7s} {'e2m':>5s} {'hit%':>5s} {'shared':>6s}")
    rows = []
    for policy in ("fifo", "admission", "svm_aware"):
        sched = PoolScheduler(cap, policy=policy, pin_frac=0.4)
        reqs = make_requests(specs, 8, seed=3, mean_interarrival_s=0.01,
                             tokens=16, spec_choice="roundrobin")
        r = sched.run(reqs)
        rows.append(r)
        print(f"  {policy:10s} {r['latency_p50_s'] * 1e3:7.1f}ms "
              f"{r['latency_p99_s'] * 1e3:7.1f}ms {r['agg_tok_s']:7.0f} "
              f"{r['evictions_per_token']:7.2f} {r['evict_to_mig']:5.2f} "
              f"{r['segment_hit_rate'] * 100:5.1f} "
              f"{r['segment_shared_hits']:6d}")

    fifo, aware = rows[0], rows[-1]
    print(f"\nsvm_aware vs fifo: "
          f"{fifo['evictions_per_token'] / aware['evictions_per_token']:.2f}x "
          f"fewer evictions/token, "
          f"{fifo['latency_p99_s'] / aware['latency_p99_s']:.2f}x lower "
          f"p99 latency (admission keeps the pool below the thrashing "
          f"cliff; pinning + shared segment replays do the rest)")


if __name__ == "__main__":
    main()
