from repro.ft.supervisor import (
    ElasticPlan,
    StragglerMonitor,
    TrainSupervisor,
    plan_elastic_remesh,
)

__all__ = ["TrainSupervisor", "StragglerMonitor", "plan_elastic_remesh",
           "ElasticPlan"]
