"""Parallel scenario-sweep runner with a content-keyed result cache.

The paper's headline figures (6–10) are grids over
(workload × DOS × policy × §4.2 driver variant).  Points are independent,
so the runner fans them out across a ``ProcessPoolExecutor`` and memoises
each point's result row on disk, keyed by the *content* of the scenario:
the point spec, the cost-model parameters, and a digest of the simulator
sources.  Re-running a figure suite after a code change recomputes only
what the change invalidates; re-running unchanged figures is pure cache
hits.

Points are plain data (workload *name* + kwargs, resolved via
`repro.core.traces.make_workload` inside the worker), so they pickle
cleanly and hash stably.

Compiled-trace sharing: a workload's lowered op columns depend only on
(workload, total_bytes, wl_kwargs, capacity, base) — not on the policy /
variant / manager axes — so `trace_key` derives a `TraceKey` per point and
`run_sweep` groups pending points by it.  Each worker process receives
whole groups and compiles each distinct trace once (into the in-process
`repro.core.engine.TRACE_CACHE` LRU), replaying it across its group's
points.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Iterable, Sequence

from repro.core.costmodel import CostParams, MI250X
from repro.core.ranges import DEFAULT_BASE as BASE

_CODE_DIGEST: str | None = None


def _code_digest() -> str:
    """Digest of the simulator sources: part of every cache key, so cached
    rows invalidate when the model code changes."""
    global _CODE_DIGEST
    if _CODE_DIGEST is None:
        h = hashlib.sha256()
        core = os.path.dirname(os.path.abspath(__file__))
        for fn in sorted(os.listdir(core)):
            if fn.endswith(".py"):
                with open(os.path.join(core, fn), "rb") as f:
                    h.update(f.read())
        _CODE_DIGEST = h.hexdigest()[:16]
    return _CODE_DIGEST


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One scenario: a workload instance against one driver configuration.

    ``wl_kwargs``/``mgr_kwargs`` are sorted ``(key, value)`` tuples so the
    point is hashable and its JSON form is canonical.  ``zero_copy`` is a
    tuple of allocation names, or the sentinel ``"biggest"`` (resolved in
    the worker to the workload's largest allocation).  ``manager`` selects
    the driver model: ``"svm"`` (default) or ``"uvm"`` (Table-1
    baseline).  ``measured_pin`` > 0 turns on measured prefetching
    (docs/prefetching.md): the measured hot set, byte-bounded to that
    fraction of capacity, is pinned before the trace runs."""

    workload: str
    total_bytes: int
    capacity: int
    policy: str = "lrf"
    wl_kwargs: tuple = ()
    mgr_kwargs: tuple = ()
    zero_copy: tuple | str = ()
    engine: str = "batched"
    profile: bool = False
    manager: str = "svm"
    measured_pin: float = 0.0

    @classmethod
    def make(cls, workload: str, total_bytes: int, capacity: int, *,
             policy: str = "lrf", wl_kwargs: dict | None = None,
             mgr_kwargs: dict | None = None,
             zero_copy: tuple | str = (), engine: str = "batched",
             profile: bool = False, manager: str = "svm",
             measured_pin: float = 0.0) -> "SweepPoint":
        """Build a point from plain dict kwargs, owning the sorted-tuple
        normalisation so every call site produces identical cache keys."""
        return cls(workload=workload, total_bytes=int(total_bytes),
                   capacity=capacity, policy=policy,
                   wl_kwargs=tuple(sorted((wl_kwargs or {}).items())),
                   mgr_kwargs=tuple(sorted((mgr_kwargs or {}).items())),
                   zero_copy=zero_copy, engine=engine, profile=profile,
                   manager=manager, measured_pin=float(measured_pin))

    def key(self, params: CostParams) -> str:
        blob = json.dumps(
            [dataclasses.astuple(self), dataclasses.astuple(params),
             _code_digest()],
            sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()


def _managers() -> dict:
    from repro.core.svm import SVMManager
    from repro.core.uvm import UVMManager
    return {"svm": SVMManager, "uvm": UVMManager}


class _ManagerMap:
    """Lazy name -> manager-class map (avoids import cycles at load)."""

    def __getitem__(self, name: str):
        try:
            return _managers()[name]
        except KeyError:
            raise ValueError(f"unknown manager {name!r}; "
                             f"available: {sorted(_managers())}") from None


MANAGERS = _ManagerMap()


def trace_key(point: SweepPoint, base: int = BASE,
              max_ops: int | None = None) -> tuple:
    """TraceKey: the fields that fully determine a point's lowered trace.

    Policy / variant / manager / profile axes deliberately excluded —
    points differing only in those replay one compiled trace."""
    return (point.workload, int(point.total_bytes), point.wl_kwargs,
            point.capacity, base, max_ops)


def hotset_grid(total_bytes: int, capacities: Sequence[int], *,
                policies: Sequence[str] = ("lrf",),
                modes: Sequence[str] = ("static", "dynamic",
                                        "oscillating"),
                ops: int = 4096, seed: int = 0,
                measured_pins: Sequence[float] = (0.0,),
                **hot_kwargs) -> "list[SweepPoint]":
    """Scenario grid over the synthetic hot-set adversaries
    (`repro.core.traces.HotSet`): mode × capacity × eviction policy
    (× measured-prefetch fraction when ``measured_pins`` lists more than
    the off point — 0.0 is the paper's aggressive default, > 0 pins the
    measured hot set up-front, docs/prefetching.md).

    Each mode shares one `trace_key` per capacity-independent axis, so
    `run_sweep` compiles three traces and replays them across the whole
    grid — the cheap way to stress phase-change behaviour alongside the
    Table-2 suite."""
    return [
        SweepPoint.make("hotset", total_bytes, cap, policy=pol,
                        wl_kwargs={"mode": mode, "ops": ops, "seed": seed,
                                   **hot_kwargs},
                        measured_pin=mp)
        for mode in modes for cap in capacities for pol in policies
        for mp in measured_pins
    ]


def run_point(point: SweepPoint, params: CostParams = MI250X, *,
              trace_cache=True) -> dict:
    """Execute one sweep point; returns the flat result row.

    ``trace_cache``: True (default) memoises the compiled trace in the
    process-wide `repro.core.engine.TRACE_CACHE` under `trace_key(point)`;
    pass a `TraceCache` to use a private cache, or False to recompile."""
    from repro.core.simulator import simulate
    from repro.core.traces import make_workload

    cache = key = None
    if trace_cache is not False and point.engine == "batched":
        from repro.core.engine import TRACE_CACHE
        cache = TRACE_CACHE if trace_cache is True else trace_cache
        key = trace_key(point)
    # strings pass through to simulate untupled: "biggest" resolves there
    # off the same build used to run; any other string raises there
    # (tuple() would silently split a bare name into characters)
    zero_copy = point.zero_copy
    if not isinstance(zero_copy, str):
        zero_copy = tuple(zero_copy)
    res = simulate(
        make_workload(point.workload, point.total_bytes,
                      **dict(point.wl_kwargs)),
        point.capacity,
        base=BASE,
        policy=point.policy,
        params=params,
        profile=point.profile,
        engine=point.engine,
        manager_cls=MANAGERS[point.manager],
        zero_copy_alloc_names=zero_copy,
        trace_cache=cache,
        trace_key=key,
        measured_pin=point.measured_pin,
        **dict(point.mgr_kwargs),
    )
    return res.row()


def _run_group_job(args: tuple) -> list[tuple[int, dict]]:
    """Worker job: one TraceKey group — the trace is compiled once into
    the worker's in-process LRU and replayed across the group's points."""
    items, params = args
    return [(i, run_point(p, params)) for i, p in items]


def run_sweep(
    points: Sequence[SweepPoint] | Iterable[SweepPoint],
    *,
    jobs: int | None = 0,
    params: CostParams = MI250X,
    cache_dir: str | None = None,
    stats: dict | None = None,
) -> list[dict]:
    """Run a grid of sweep points, in order-preserving fashion.

    ``jobs``: 0/1 = serial in-process, None = one worker per CPU, N = N
    worker processes.  Pool *infrastructure* failures (restricted
    sandboxes: fork/pipe/import errors, broken pools) fall back to serial
    execution; a point that raises inside a worker propagates its own
    exception either way.  With ``cache_dir`` set, each point's row is
    cached on disk under its content key.  Pass a dict as ``stats`` to
    receive {"cached": n, "computed": m, "trace_groups": g}.

    Scheduling is **grid-aware**: pending points are grouped by
    `trace_key` and dispatched group-wise, so a worker compiles each
    distinct trace once and replays it across that group's
    policy/variant/manager points (serial execution walks the same
    grouped order and shares through the in-process LRU likewise).
    Groups larger than an even per-worker share are split so sharing
    never reduces fan-out below the worker count.
    """
    points = list(points)
    rows: list[dict | None] = [None] * len(points)

    pending: list[tuple[int, SweepPoint]] = []
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        for i, p in enumerate(points):
            path = os.path.join(cache_dir, p.key(params) + ".json")
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        rows[i] = json.load(f)
                    continue
                except (OSError, ValueError):
                    pass
            pending.append((i, p))
    else:
        pending = list(enumerate(points))

    # group by TraceKey: one compile per distinct trace per worker
    groups: dict[tuple, list[tuple[int, SweepPoint]]] = {}
    for i, p in pending:
        groups.setdefault(trace_key(p), []).append((i, p))
    grouped = list(groups.values())

    if stats is not None:
        stats["cached"] = len(points) - len(pending)
        stats["computed"] = len(pending)
        stats["trace_groups"] = len(grouped)

    if pending:
        results: list[tuple[int, dict]] | None = None
        n_jobs = os.cpu_count() if jobs is None else jobs
        if n_jobs and n_jobs > 1 and len(pending) > 1:
            from concurrent.futures import ProcessPoolExecutor
            from concurrent.futures.process import BrokenProcessPool
            # split groups into dispatch units so trace sharing never caps
            # parallelism below the worker count: a split group recompiles
            # once per extra worker (milliseconds on the columnar tier) in
            # exchange for full execution fan-out
            per_unit = max(1, -(-len(pending) // n_jobs))
            units = [g[k:k + per_unit] for g in grouped
                     for k in range(0, len(g), per_unit)]
            # longest-unit-first dispatch: pool.map hands units out in
            # order, so a big group scheduled last would serialise the
            # tail of the sweep behind one worker
            units.sort(key=len, reverse=True)
            pool = None
            try:
                pool = ProcessPoolExecutor(
                    max_workers=min(n_jobs, len(units)))
            except (OSError, ImportError):
                pool = None        # sandbox without fork/pipe support
            if pool is not None:
                try:
                    with pool:
                        results = [r for chunk in pool.map(
                            _run_group_job,
                            [(u, params) for u in units])
                            for r in chunk]
                except BrokenProcessPool:
                    # workers died (OOM kill, hard crash); a point's own
                    # exception propagates unmodified instead
                    import sys
                    print("run_sweep: worker pool died, rerunning "
                          f"{len(pending)} pending points serially",
                          file=sys.stderr)
                    results = None
        if results is None:
            results = [(i, run_point(p, params))
                       for g in grouped for i, p in g]
        for i, row in results:
            rows[i] = row
            if cache_dir:
                path = os.path.join(cache_dir,
                                    points[i].key(params) + ".json")
                try:
                    with open(path, "w") as f:
                        json.dump(row, f)
                except OSError:
                    pass
    return rows  # type: ignore[return-value]
