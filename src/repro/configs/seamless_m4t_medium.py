"""seamless-m4t-medium: enc-dec, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206 — realised as 12 encoder layers + 12 decoder layers (each
decoder layer = self-attn + cross-attn + FFN, encoded as a 2-entry pattern
period, so n_layers=24 pattern entries = 12 logical decoder layers; see
DESIGN.md). The speech frontend is a STUB: input_specs provides precomputed
frame embeddings [arXiv:2308.11596; hf]."""

import dataclasses

from repro.models.config import ATTN, CROSS, MLP, NONE, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    vocab=256206,
    d_model=1024,
    n_layers=24,                       # (attn, cross) x 12 logical layers
    d_ff=4096,
    n_heads=16,
    n_kv_heads=16,
    layer_pattern=(ATTN, CROSS),
    ffn_pattern=(NONE, MLP),
    encoder_layers=12,
    encoder_frames=1024,
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, vocab=512, d_model=64, n_layers=4, d_ff=128,
        n_heads=4, n_kv_heads=4, encoder_layers=2, encoder_frames=16)
