"""Enforce the committed BENCH_engine.json speedup floors.

CI runs this right after the bench smoke: if any gated ratio regressed
below its floor, the job fails.  Floors are committed here (not read from
the JSON) so a regression can't weaken its own gate.

Usage:  python benchmarks/check_gates.py [path/to/BENCH_engine.json]
"""

from __future__ import annotations

import json
import os
import sys

# committed floors: gate key in BENCH_engine.json -> minimum ratio
FLOORS = {
    "gate_stream147_speedup": 10.0,     # batched vs scalar, stream DOS-147
    "gate_variant_min_speedup": 5.0,    # §4.2 variant / UVM rows
    "gate_compile_min_speedup": 5.0,    # columnar vs generator lowering
    "gate_serving_decode_speedup": 5.0,  # session decode replay vs scalar
    # multi-tenant scheduler: svm_aware evictions/token reduction vs the
    # fifo thrashing baseline on the oversubscribed 8-request mix
    # (deterministic simulation, measured ~2.0x)
    "gate_sched_evict_reduction": 1.5,
    # measured working-set admission (docs/prefetching.md): peak
    # concurrently active tenants under admit_by="measured" vs plan-bytes
    # admission on the dense+MoE 8-request mix, zeroed unless
    # evictions/token stays no worse — admitting more tenants by
    # thrashing harder must trip the gate (deterministic, measured 3.0x)
    "gate_measured_admission": 1.2,
    # fused round replay: one concatenated execute_fused pass per
    # scheduler round vs per-token reference replay, 512-request burst
    # mix over a pool with real tenant concurrency (measured ~4x)
    "gate_sched_fused_speedup": 3.0,
    # chaos retention: aggregate decode throughput under the default
    # seeded FaultPlan vs the clean run of the same 64-request mix
    # (deterministic simulation; retries/crash recovery cost sim wall)
    "gate_sched_chaos_retention": 0.5,
    # vectorized scheduler at serving scale: sustained replayed ops/s of
    # the single-pass vectorized tier on the 1024-request / ~2.3M-op
    # burst schedule (absolute host-throughput floor; measured ~4-5M
    # ops/s on the reference box, ~2x the PR 8 per-request-loop tier)
    "gate_sched_scale_ops_per_s": 2.5e6,
    # ...and its speedup over the per-token reference loop on the same
    # schedule (machine-independent ratio; measured ~6x)
    "gate_sched_scale_speedup": 3.0,
}


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "BENCH_engine.json")
    with open(path) as f:
        bench = json.load(f)
    failures = []
    for key, floor in FLOORS.items():
        val = bench.get(key)

        def fmt(v: float) -> str:
            # throughput gates carry absolute ops/s; the rest are ratios
            return f"{v / 1e6:.2f}M ops/s" if key.endswith("_ops_per_s") \
                else f"{v:.2f}x"

        if val is None:
            failures.append(f"{key}: missing from {path}")
        elif val < floor:
            failures.append(
                f"{key}: {fmt(val)} < committed floor {fmt(floor)}")
        else:
            print(f"OK  {key}: {fmt(val)} >= {fmt(floor)}")
    if failures:
        for msg in failures:
            print(f"FAIL {msg}", file=sys.stderr)
        return 1
    print("all bench gates at or above their committed floors")
    return 0


if __name__ == "__main__":
    sys.exit(main())
