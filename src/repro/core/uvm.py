"""UVM-style baseline manager (paper Table 1 comparison).

Models the NVIDIA-UVM design points the paper contrasts with SVM:

  * UM (de)allocation in 2 MB **VABlocks** (vs SVM ranges up to 1 GB),
  * migration unit: 64 KB base pages, coalesced up to a VABlock by a
    density/tree prefetcher (contiguous faulting blocks in one batch are
    migrated as one transfer),
  * **fault batching**: up to ``MAX_BATCH`` faults buffered **across ops**
    and serviced together (vs SVM's immediate single-fault servicing).
    The buffer flushes when it reaches ``MAX_BATCH`` distinct blocks, when
    the pending blocks no longer fit in free device memory (capacity
    pressure), and at every driver synchronisation point: ``advance``
    (kernel compute), ``writeback``, ``pin``, or an explicit ``flush()``
    (the simulator flushes once at end of trace).  ``BATCH_FIXED_S`` is
    therefore charged per *batch*, not per faulting touch.  A touch on a
    block already sitting in the buffer is dismissed as a duplicate fault
    (the fault CAM dedupes it) — cf. Chien et al., *Performance Evaluation
    of Advanced Features in CUDA Unified Memory*.
  * eviction at VABlock granularity (LRU over blocks), with **dirtiness
    tracking**: evicting a clean block is an unmap (page-table work only,
    no copy, no bytes moved), only dirty blocks (touched with
    ``write=True``) pay the full device→host transfer.  Algorithmic
    device→host copies issued via ``writeback`` are booked as writebacks
    (``n_writebacks`` / ``bytes_writeback`` / ``writeback_cost_total``),
    not as eviction overhead.

Exposes the same trace-facing API as SVMManager (`touch`, `advance`,
`writeback`, `pin`, `summary`) so the simulator can drive either.  The
compiled-trace engine (`repro.core.engine`) has a batched interpreter for
this manager with byte-identical `summary()` output.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.costmodel import CostParams, CostVector, MI250X, migration_cost
from repro.core.ranges import AddressSpace, MB
from repro.core.svm import Event

VABLOCK = 2 * MB
BASE_CHUNK = 64 * 1024
MAX_BATCH = 256

BATCH_FIXED_S = 45e-6     # GPU->host interrupt + batch preprocessing
PER_FAULT_S = 2.5e-6      # per-fault decode/dedupe within a batch


class UVMManager:
    """The NVIDIA-UM baseline (Table 1): VABlock-granular demand paging
    with cross-op fault batching (CAM dedupe, serviced at driver sync
    points), dirtiness-tracked LRU eviction, and writeback accounting —
    the comparison design point for the paper's SVM range machinery."""

    def __init__(
        self,
        space: AddressSpace,
        *,
        params: CostParams = MI250X,
        profile: bool = True,
        prefetch: bool = True,
        **_ignored,
    ) -> None:
        self.space = space
        self.params = params
        self.profile = profile
        self.prefetch = prefetch
        self.capacity = space.capacity
        self.free = space.capacity
        # resident VABlocks: block_id -> last-use time (LRU)
        self.resident: OrderedDict[int, float] = OrderedDict()
        self.pinned: set[int] = set()
        self.dirty: set[int] = set()      # written since migration

        self.wall = 0.0
        self.compute_time = 0.0
        self.cost = CostVector()
        self.n_migrations = 0      # transfers (after coalescing)
        self.n_evictions = 0
        self.n_writebacks = 0
        self.n_batches = 0
        self.bytes_migrated = 0
        self.bytes_evicted = 0
        self.bytes_writeback = 0
        self.evict_cost_total = 0.0
        self.writeback_cost_total = 0.0
        self.faults_serviceable = 0
        self.faults_duplicate = 0
        self.trigger_pages: set[int] = set()
        self.events: list[Event] = []
        self.density: list = []
        # pending faulting block ids, insertion-ordered, CAM-deduped
        self._pending: OrderedDict[int, None] = OrderedDict()
        # one VABlock's migration cost is a constant of `params`
        self._mc_block = migration_cost(VABLOCK, params)
        self._mc_block_total = self._mc_block.total()

    # -------------------------------------------------------------- helpers

    def _blocks_of_range(self, rid: int) -> range:
        r = self.space.ranges[rid]
        return range(r.start // VABLOCK, -(-r.end // VABLOCK))

    # ------------------------------------------------------------------ api

    def touch(self, rid: int, *, bytes_touched: int | None = None,
              concurrency: int = 32, page_hint: int | None = None,
              write: bool = False) -> bool:
        hit = True
        blocks = self._blocks_of_range(rid)
        for b in blocks:
            if b in self.resident:
                self.resident.move_to_end(b)
                self.resident[b] = self.wall
            elif b in self._pending:
                # already buffered: the fault CAM dedupes it
                hit = False
                self.faults_duplicate += 1
            else:
                hit = False
                self._pending[b] = None
                self.faults_serviceable += 1
                self.trigger_pages.add(b * (VABLOCK // 4096))
                self.faults_duplicate += max(0, concurrency // 8)
                if (len(self._pending) >= MAX_BATCH
                        or len(self._pending) * VABLOCK >= self.free):
                    self._service_batch()
        if write:
            self.dirty.update(blocks)
        return hit

    def advance(self, seconds: float) -> None:
        self.flush()     # kernel-boundary sync: service buffered faults
        self.wall += seconds
        self.compute_time += seconds

    def flush(self) -> None:
        """Service any buffered faults (driver synchronisation point)."""
        self._service_batch()

    def writeback(self, rid: int) -> None:
        """Algorithmic device→host copy (e.g. BFS frontier output): a full
        transfer per resident block, booked as writeback — not eviction."""
        self.flush()
        for b in self._blocks_of_range(rid):
            if b in self.resident:
                self._writeback_block(b)

    def pin(self, rid: int) -> None:
        self.touch(rid, concurrency=1)
        self.flush()     # blocks must be resident before they leave the LRU
        for b in self._blocks_of_range(rid):
            self.pinned.add(b)
            self.resident.pop(b, None)  # memory accounting unchanged

    def unpin(self, rid: int) -> None:
        for b in self._blocks_of_range(rid):
            if b in self.pinned:
                self.pinned.discard(b)
                self.resident[b] = self.wall

    # ------------------------------------------------------------ internals

    def _service_batch(self) -> None:
        if not self._pending:
            return
        blocks = sorted(self._pending)
        self._pending.clear()
        self.n_batches += 1
        self.wall += BATCH_FIXED_S + PER_FAULT_S * len(blocks)
        # tree/density prefetcher: coalesce contiguous faulting blocks
        groups: list[list[int]] = [[blocks[0]]]
        for b in blocks[1:]:
            if self.prefetch and b == groups[-1][-1] + 1:
                groups[-1].append(b)
            else:
                groups.append([b])
        for g in groups:
            nbytes = len(g) * VABLOCK
            # make room at VABlock granularity (LRU)
            while self.free < nbytes:
                victim = self._lru_victim()
                self._evict(victim)
            mc = migration_cost(nbytes, self.params)
            self.cost.add(mc)
            self.wall += mc.total()
            self.n_migrations += 1
            self.bytes_migrated += nbytes
            for b in g:
                self.resident[b] = self.wall
            self.free -= nbytes
            if self.profile:
                rid = self._rid_of_block(g[0])
                self.events.append(Event(self.wall, "mig", rid,
                                         self.space.ranges[rid].alloc_id,
                                         nbytes))

    def _rid_of_block(self, b: int) -> int:
        addr = min(b * VABLOCK, self.space.ranges[-1].end - 1)
        addr = max(addr, self.space.ranges[0].start)
        return self.space.range_at(addr).rid

    def _lru_victim(self) -> int:
        for b in self.resident:
            if b not in self.pinned:
                return b
        raise RuntimeError("UVM: all resident blocks pinned")

    def _evict(self, b: int) -> None:
        """LRU capacity eviction: dirty blocks pay the full device→host
        transfer (charged to `alloc`, mirroring SVM's eviction booking);
        clean blocks are dropped with page-table unmap work only — no copy,
        no bytes counted."""
        if b in self.dirty:
            w = self._mc_block_total
            self.cost.alloc += w
            self.evict_cost_total += w
            self.bytes_evicted += VABLOCK
            self.dirty.discard(b)
        else:
            w = self._mc_block.cpu_unmap
            self.cost.cpu_unmap += w
        self.wall += w
        self.resident.pop(b, None)
        self.free += VABLOCK
        self.n_evictions += 1
        if self.profile:
            rid = self._rid_of_block(b)
            self.events.append(Event(self.wall, "evt", rid,
                                     self.space.ranges[rid].alloc_id, VABLOCK))

    def _writeback_block(self, b: int) -> None:
        """Device→host transfer of one block on behalf of the application;
        the block is dropped after the copy (its data now lives on the
        host).  Booked per cost term (a real five-phase transfer) and in
        the writeback counters."""
        w = self._mc_block_total
        self.cost.add(self._mc_block)
        self.writeback_cost_total += w
        self.wall += w
        self.resident.pop(b, None)
        self.dirty.discard(b)
        self.free += VABLOCK
        self.n_writebacks += 1
        self.bytes_writeback += VABLOCK
        if self.profile:
            rid = self._rid_of_block(b)
            self.events.append(Event(self.wall, "wb", rid,
                                     self.space.ranges[rid].alloc_id, VABLOCK))

    # ------------------------------------------------------------- metrics

    @property
    def faults_total(self) -> int:
        return self.faults_serviceable + self.faults_duplicate

    @property
    def evict_to_mig_ratio(self) -> float:
        return self.n_evictions / self.n_migrations if self.n_migrations else 0.0

    def summary(self) -> dict:
        return {
            "wall_s": self.wall,
            "compute_s": self.compute_time,
            "migrations": self.n_migrations,
            "evictions": self.n_evictions,
            "writebacks": self.n_writebacks,
            "batches": self.n_batches,
            "evict_to_mig": self.evict_to_mig_ratio,
            "bytes_migrated": self.bytes_migrated,
            "bytes_evicted": self.bytes_evicted,
            "bytes_writeback": self.bytes_writeback,
            "faults_serviceable": self.faults_serviceable,
            "faults_duplicate": self.faults_duplicate,
            "cost_breakdown": self.cost.as_dict(),
            "dos": self.space.dos(),
        }
