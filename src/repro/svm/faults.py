"""Deterministic fault injection for the serving stack (chaos layer).

The paper's central finding — aggressive prefetch + eviction silently
degrades into thrashing under oversubscription — is exactly the failure
mode a production pool must *survive at runtime*.  This module supplies
the hazards; `PoolScheduler` supplies the recovery (docs/robustness.md).

A `FaultPlan` is a frozen, seeded schedule of `FaultEvent`s keyed by the
**global decoded-token counter** (the scheduler's deterministic progress
clock — never the host clock), covering four hazard classes:

  * ``capacity_loss`` / ``capacity_restore`` — a co-tenant grabs (or
    returns) pool bytes mid-run; applied via the public
    `SVMManager.resize_capacity` hook, forcing emergency eviction.
  * ``migration_fault`` — the next decoded token's migration raises
    `MigrationError` for the first ``fail_attempts`` attempts; recovered
    by the shared bounded-retry utility (`repro.ft.retry`), backoff
    charged to the simulated clock.
  * ``slow_page`` / ``slow_page_end`` — a window of multiplicative
    migration-cost perturbation (UVM studies report order-of-magnitude
    migration-latency variance).
  * ``crash`` — the next decoding request dies mid-decode; recovered by
    eagerly draining its ranges and resuming from its `TraceSession`
    carried state.

The `FaultInjector` is pure bookkeeping: it consumes the plan against
the token counter and hands events back to the scheduler, which applies
every one of them through *public* manager/scheduler hooks only — this
module never drives a manager and is svmlint-clean by construction.
Same plan + same request mix ⇒ bit-identical runs.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

#: hazard vocabulary; "env" kinds perturb the environment, "token" kinds
#: target the next decoded token
ENV_KINDS = ("capacity_loss", "capacity_restore",
             "slow_page", "slow_page_end")
TOKEN_KINDS = ("migration_fault", "crash")
HAZARD_KINDS = ENV_KINDS + TOKEN_KINDS


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled hazard.

    ``at_tokens`` — fire once the global decoded-token counter reaches
    this value.  ``frac`` — capacity fraction of the *original* pool
    (capacity events) or migration-cost multiplier (slow-page events).
    ``fail_attempts`` — how many consecutive attempts the armed
    migration fault kills (recoverable while < the retry budget)."""

    at_tokens: int
    kind: str
    frac: float = 1.0
    fail_attempts: int = 1

    def __post_init__(self) -> None:
        if self.kind not in HAZARD_KINDS:
            raise ValueError(f"unknown hazard kind {self.kind!r}; "
                             f"available: {HAZARD_KINDS}")
        if self.at_tokens < 0:
            raise ValueError("at_tokens must be >= 0")
        if self.frac <= 0.0:
            raise ValueError("frac must be positive")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, seeded hazard schedule (see module docstring)."""

    events: tuple[FaultEvent, ...]
    seed: int = 0
    name: str = "custom"

    @classmethod
    def default(cls, seed: int = 0, *, n_requests: int = 64,
                tokens: int = 32, intensity: float = 1.0) -> "FaultPlan":
        """The default chaos mix over an ``n_requests × tokens`` run:
        one transient capacity dip (lose 35 % of the pool for ~15 % of
        the run), one 3× slow-page window (~10 % of the run), a handful
        of recoverable migration faults, and one mid-decode crash.
        Event positions are drawn from ``default_rng(seed)``; everything
        lands in the first 85 % of the token horizon so the whole plan
        is guaranteed to fire."""
        horizon = max(int(n_requests * tokens), 8)
        rng = np.random.default_rng(seed)

        def at(lo: float, hi: float) -> int:
            return int(horizon * (lo + (hi - lo) * float(rng.random())))

        events = []
        t_cap = at(0.15, 0.25)
        events.append(FaultEvent(t_cap, "capacity_loss", frac=0.65))
        events.append(FaultEvent(t_cap + max(1, int(horizon * 0.15)),
                                 "capacity_restore", frac=1.0))
        t_slow = at(0.45, 0.55)
        events.append(FaultEvent(t_slow, "slow_page", frac=3.0))
        events.append(FaultEvent(t_slow + max(1, int(horizon * 0.10)),
                                 "slow_page_end"))
        n_mf = max(1, int(round(3 * intensity)))
        for t in sorted(int(v) for v in
                        rng.integers(1, int(horizon * 0.85), size=n_mf)):
            events.append(FaultEvent(t, "migration_fault",
                                     fail_attempts=2))
        events.append(FaultEvent(at(0.55, 0.75), "crash"))
        events.sort(key=lambda e: (e.at_tokens, e.kind))
        return cls(events=tuple(events), seed=seed, name="default")


class FaultInjector:
    """Consumes a `FaultPlan` against the scheduler's token counter.

    Pure bookkeeping — the scheduler applies each returned event through
    public hooks.  Environment events (capacity, slow-page) drain
    eagerly via `due_env`; token-targeted events (migration fault,
    crash) pop **one per decoded token** via `pop_token_event`, so a
    burst of same-position token events lands on consecutive tokens
    instead of collapsing onto one."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        ordered = sorted(plan.events, key=lambda e: (e.at_tokens, e.kind))
        self._env = [e for e in ordered if e.kind in ENV_KINDS]
        self._tok = [e for e in ordered if e.kind in TOKEN_KINDS]
        self._env_idx = 0
        self._tok_idx = 0
        self.applied: list[FaultEvent] = []

    # ------------------------------------------------------------ queries

    @property
    def remaining(self) -> int:
        return (len(self._env) - self._env_idx) \
            + (len(self._tok) - self._tok_idx)

    def next_at(self) -> float:
        """Token position of the earliest unapplied event (``inf`` when
        the plan is drained) — the scheduler's fused-round lookahead."""
        nxt = math.inf
        if self._env_idx < len(self._env):
            nxt = min(nxt, self._env[self._env_idx].at_tokens)
        if self._tok_idx < len(self._tok):
            nxt = min(nxt, self._tok[self._tok_idx].at_tokens)
        return nxt

    # ------------------------------------------------------------ pumping

    def due_env(self, tokens: int) -> list[FaultEvent]:
        """Pop every environment event due at ``tokens``."""
        out = []
        while self._env_idx < len(self._env) and \
                self._env[self._env_idx].at_tokens <= tokens:
            ev = self._env[self._env_idx]
            self._env_idx += 1
            self.applied.append(ev)
            out.append(ev)
        return out

    def pop_token_event(self, tokens: int) -> FaultEvent | None:
        """Pop at most one token-targeted event due at ``tokens``."""
        if self._tok_idx < len(self._tok) and \
                self._tok[self._tok_idx].at_tokens <= tokens:
            ev = self._tok[self._tok_idx]
            self._tok_idx += 1
            self.applied.append(ev)
            return ev
        return None

    def stats(self) -> dict:
        return {
            "plan": self.plan.name,
            "seed": self.plan.seed,
            "events_total": len(self.plan.events),
            "events_applied": len(self.applied),
            "events_remaining": self.remaining,
        }
