"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; artifacts land in
results/bench/*.json. Additionally summarises the dry-run/roofline sweeps
when their JSONL outputs exist."""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_figs  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def roofline_rows():
    """Summarise the dry-run roofline sweep (if present)."""
    path = os.path.join(RESULTS, "roofline_results.jsonl")
    if not os.path.exists(path):
        return [("roofline_sweep", 0.0, "missing_run_dryrun_first")]
    from repro.launch.roofline import roofline_terms
    rows = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "ok":
                continue
            t = roofline_terms(r, 256)
            rows.append((
                f"roofline_{r['arch']}_{r['shape']}",
                r.get("wall_s", 0.0) * 1e6,
                f"dom={t['dominant']}_frac={t['roofline_fraction']:.3f}",
            ))
    return rows or [("roofline_sweep", 0.0, "no_ok_rows")]


def main() -> None:
    print("name,us_per_call,derived")
    for fn in paper_figs.ALL:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}",
                  flush=True)
    for name, us, derived in roofline_rows():
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
