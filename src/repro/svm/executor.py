"""Streaming executor: serve a model whose weights exceed the HBM budget.

Weights live host-side (numpy); a fixed-size device pool holds the resident
ranges. Each layer's weight fetch drives the SVMManager (faults -> range
migrations -> LRF/Clock/LRU evictions, with the paper's cost model supplying
the simulated clock), while the math itself runs for real, so correctness
and policy behaviour are validated together.

Streaming modes map the paper's findings onto serving:
  * naive        — demand-fetch in layer order; under oversubscription LRF
                   evicts the *earliest-fetched* layers, which are exactly
                   the ones the next token needs first: the decode loop is
                   Jacobi2d's cyclic-traversal pathology (Category II/III).
  * svm_aware    — pin the hottest leaves (embeddings + head: touched twice
                   per token) and prefetch the next layer overlapped with
                   compute (paper §4.1 pinning + §4.2 parallel eviction).
  * zero_copy    — leave designated cold leaves host-resident at remote-
                   access cost (paper §4.2).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostParams, TPU_V5E_HOST
from repro.svm.planner import ParamRanges, plan_param_ranges

PyTree = Any

PEAK_FLOPS = 197e12 * 0.4     # assumed achievable serving compute rate


class StreamingExecutor:
    def __init__(self, params: PyTree, hbm_budget: int, *,
                 policy: str = "lrf",
                 cost_params: CostParams = TPU_V5E_HOST,
                 parallel_evict: bool = False,
                 prefetch: bool = False,
                 pin: tuple[str, ...] = (),
                 zero_copy: tuple[str, ...] = (),
                 concurrency: int = 64):
        self.host_params = jax.tree.map(np.asarray, params)
        self.plan: ParamRanges = plan_param_ranges(params, hbm_budget)
        self.mgr = self.plan.manager(policy=policy, params=cost_params,
                                     parallel_evict=parallel_evict)
        self.prefetch = prefetch
        self.concurrency = concurrency
        self._device: dict[str, jnp.ndarray] = {}
        self._flat = dict(self._leaves(self.host_params))
        for pat in zero_copy:
            for path, rids in self.plan.leaf_ranges.items():
                if pat in path:
                    aid = self.plan.space.ranges[rids[0]].alloc_id
                    self.mgr.set_zero_copy(aid)
        for pat in pin:
            for path, rids in self.plan.leaf_ranges.items():
                if pat in path:
                    for rid in rids:
                        self.mgr.pin(rid)
        # compute-time ledger (simulated clock shares the SVM manager wall)
        self.compute_flops = 0.0

    @staticmethod
    def _leaves(tree: PyTree):
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
            path = "/".join(
                getattr(k, "key", getattr(k, "name", str(k))) for k in kp)
            yield path, leaf

    # ----------------------------------------------------------- fetching

    def fetch(self, path: str) -> jnp.ndarray:
        """Touch a leaf's ranges (demand paging) and return the tensor."""
        resident_before = True
        for rid in self.plan.leaf_ranges[path]:
            hit = self.mgr.touch(rid, concurrency=self.concurrency)
            resident_before &= hit
        if not resident_before or path not in self._device:
            self._device[path] = jnp.asarray(self._flat[path])
        self._drop_evicted()
        return self._device[path]

    def prefetch_leaf(self, path: str, overlap_s: float) -> None:
        """Issue next-layer migrations overlapped with current compute
        (paper §4.2 'parallel implementation'): up to `overlap_s` of the
        migration cost is hidden."""
        w0 = self.mgr.wall
        for rid in self.plan.leaf_ranges[path]:
            self.mgr.touch(rid, concurrency=self.concurrency)
        hidden = min(self.mgr.wall - w0, overlap_s)
        self.mgr.wall -= hidden
        self._drop_evicted()

    def _drop_evicted(self) -> None:
        # leaves with any non-resident, non-zero-copy range fall out of pool
        for path, rids in self.plan.leaf_ranges.items():
            if path in self._device:
                aid = self.plan.space.ranges[rids[0]].alloc_id
                if aid in self.mgr.zero_copy_allocs:
                    continue
                if not all(r in self.mgr.resident for r in rids):
                    del self._device[path]

    def charge_compute(self, flops: float) -> None:
        self.compute_flops += flops
        self.mgr.advance(flops / PEAK_FLOPS)

    # ------------------------------------------------------------ metrics

    def metrics(self) -> dict:
        s = self.mgr.summary()
        s["dos"] = self.plan.dos()
        s["compute_flops"] = self.compute_flops
        return s


def run_layer_stream(
    executor: StreamingExecutor,
    layer_paths: list[list[str]],
    apply_layer: Callable[[int, dict[str, jnp.ndarray]], float],
    *,
    steps: int = 1,
) -> dict:
    """Drive a layer-ordered streaming pass `steps` times (decode loop).

    `layer_paths[i]` lists the param-leaf paths layer i needs;
    `apply_layer(i, tensors)` runs the math and returns its FLOPs.
    """
    n = len(layer_paths)
    for _ in range(steps):
        for i in range(n):
            tensors = {p: executor.fetch(p) for p in layer_paths[i]}
            flops = apply_layer(i, tensors)
            if executor.prefetch and i + 1 < n:
                est = flops / PEAK_FLOPS
                for p in layer_paths[i + 1]:
                    executor.prefetch_leaf(p, est)
            executor.charge_compute(flops)
    return executor.metrics()
