"""Golden equivalence: the compiled-trace engine vs the scalar op loop.

The engine's contract is *byte-identical* `summary()` output — every float
(wall, cost terms, fault densities) compared with ``==``, no tolerances —
for every Table-2 workload at DOS 78/109/147 under all four eviction
policies, plus the §4.2 driver variants and the op-for-op manager end
state (residency, free bytes, queue order, profile events).  The full
variant × policy × DOS cross-product (defer / previct / zero-copy / UVM)
lives in tests/test_engine_variants.py."""

import pytest

from repro.core import GB, MB, SweepPoint, run_point, run_sweep, simulate
from repro.core.engine import compile_trace, compile_workload, execute_compiled
from repro.core.ranges import AddressSpace
from repro.core.svm import SVMManager
from repro.core.simulator import apply_trace
from repro.core.traces import WORKLOADS, Jacobi2d, Sgemm, make_workload

CAP = 4 * GB
DOS_POINTS = (78, 109, 147)
POLICIES = ("lrf", "lru", "clock", "random")


def _pair(workload, policy="lrf", profile=False, cap=CAP, **kw):
    scalar = simulate(workload(), cap, policy=policy, profile=profile,
                      engine="scalar", **kw)
    batched = simulate(workload(), cap, policy=policy, profile=profile,
                       engine="batched", **kw)
    return scalar, batched


def _assert_equiv(scalar, batched, profile=False):
    assert scalar.summary == batched.summary
    ms, mb = scalar.manager, batched.manager
    assert ms.resident == mb.resident
    assert ms.free == mb.free
    assert ms.pinned == mb.pinned
    qs = getattr(ms.policy, "_q", getattr(ms.policy, "_order", None))
    qb = getattr(mb.policy, "_q", getattr(mb.policy, "_order", None))
    if qs is not None:
        assert list(qs) == list(qb)          # victim order
    if profile:
        assert ms.events == mb.events
        assert ms.density == mb.density


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_golden_summary_identical(name, policy):
    for dos in DOS_POINTS:
        scalar, batched = _pair(
            lambda: make_workload(name, int(CAP * dos / 100)), policy)
        _assert_equiv(scalar, batched)


@pytest.mark.parametrize("name", ("stream", "jacobi2d", "sgemm", "gesummv"))
def test_golden_profile_events_identical(name):
    scalar, batched = _pair(
        lambda: make_workload(name, int(CAP * 1.09)), profile=True)
    _assert_equiv(scalar, batched, profile=True)
    # LRF queue timestamps are patched to the exact scalar walls
    assert list(scalar.manager.policy._q.items()) == \
        list(batched.manager.policy._q.items())


@pytest.mark.parametrize("cls,aware", [(Jacobi2d, False), (Jacobi2d, True),
                                       (Sgemm, False), (Sgemm, True)])
def test_golden_svm_aware_variants(cls, aware):
    """pin ops (sgemm) and reverse traversal (jacobi2d) stay equivalent."""
    scalar, batched = _pair(lambda: cls(int(CAP * 1.25), svm_aware=aware))
    _assert_equiv(scalar, batched)


@pytest.mark.parametrize("kw", [
    {"parallel_evict": True},
    {"zero_copy_alloc_names": ("b",)},     # in-span zero-copy fast path
    {"defer_granule": 2 * MB, "defer_k": 3},       # batched since PR 2
    {"previct_watermark": 0.1},                    # batched since PR 2
])
def test_golden_driver_variants(kw):
    scalar, batched = _pair(
        lambda: make_workload("stream", int(CAP * 1.25)), **kw)
    _assert_equiv(scalar, batched)


def test_golden_fine_grained_ranges():
    """Many-range spaces (the engine microbenchmark shape) stay exact."""
    for name, dos in (("stream", 147), ("gesummv", 125)):
        space_a = AddressSpace(CAP, base=175 * MB, alignment=4 * MB)
        space_b = AddressSpace(CAP, base=175 * MB, alignment=4 * MB)
        wa = make_workload(name, int(CAP * dos / 100))
        wb = make_workload(name, int(CAP * dos / 100))
        wa.build(space_a)
        wb.build(space_b)
        ma = SVMManager(space_a, profile=True)
        apply_trace(ma, wa.trace(space_a))
        mb = SVMManager(space_b, profile=True)
        execute_compiled(compile_workload(wb, space_b), mb)
        assert ma.summary() == mb.summary()
        assert ma.events == mb.events
        assert ma.resident == mb.resident and ma.free == mb.free


@pytest.mark.parametrize("policy", ("lrf", "lru"))
def test_device_full_error_leaves_scalar_consistent_state(policy):
    """A mid-span 'device full of pinned ranges' error must surface with
    the same partial manager state as the scalar path."""
    def build():
        space = AddressSpace(8 * MB, base=0, alignment=2 * MB)
        a = space.alloc(4 * MB, "a")
        b = space.alloc(4 * MB, "b")
        space.alloc(6 * MB, "c")
        mgr = SVMManager(space, policy=policy, profile=False)
        for alloc in (a, b):
            for r in space.ranges_of(alloc):
                mgr.pin(r.rid)
        hits = [("touch", 0, 8, 0)] * 60        # span above FAST_SPAN_MIN
        fatal_rid = space.ranges_of(2)[0].rid
        return space, mgr, hits + [("touch", fatal_rid, 8, 0)]

    space_s, mgr_s, ops = build()
    with pytest.raises(RuntimeError, match="device full"):
        apply_trace(mgr_s, iter(ops))
    space_e, mgr_e, ops = build()
    with pytest.raises(RuntimeError, match="device full"):
        execute_compiled(compile_trace(iter(ops)), mgr_e)
    assert mgr_s.free == mgr_e.free
    assert mgr_s.resident == mgr_e.resident
    assert mgr_s.summary() == mgr_e.summary()


def test_golden_max_ops_truncation():
    scalar, batched = _pair(
        lambda: make_workload("stream", int(CAP * 1.47)), max_ops=17)
    _assert_equiv(scalar, batched)


def test_compiled_trace_reexecutes_identically():
    """One lowering, many executions (the sweep amortisation contract)."""
    space = AddressSpace(CAP, base=175 * MB)
    wl = make_workload("jacobi2d", int(CAP * 1.25))
    wl.build(space)
    ct = compile_trace(wl.trace(space))
    runs = []
    for _ in range(2):
        mgr = SVMManager(space, profile=False)
        execute_compiled(ct, mgr)
        runs.append(mgr.summary())
    assert runs[0] == runs[1]


def test_sweep_runner_matches_serial_and_caches(tmp_path):
    points = [SweepPoint(workload="stream",
                         total_bytes=int(CAP * d / 100), capacity=CAP)
              for d in (78, 125)]
    serial = [run_point(p) for p in points]
    cached1 = run_sweep(points, jobs=0, cache_dir=str(tmp_path))
    cached2 = run_sweep(points, jobs=0, cache_dir=str(tmp_path))
    assert serial == cached1 == cached2
    assert len(list(tmp_path.glob("*.json"))) == len(points)


def test_dos_sweep_spec_matches_callable():
    from repro.core import dos_sweep
    from repro.core.traces import Jacobi2d as J
    grid = (78, 109)
    by_callable = dos_sweep(lambda b: J(b, svm_aware=True), grid, CAP)
    by_spec = dos_sweep(("jacobi2d", {"svm_aware": True}), grid, CAP)
    assert by_callable == by_spec


def test_sweep_point_zero_copy_biggest_resolves():
    row = run_point(SweepPoint(workload="gesummv",
                               total_bytes=int(CAP * 1.25), capacity=CAP,
                               zero_copy="biggest"))
    direct = simulate(make_workload("gesummv", int(CAP * 1.25)), CAP,
                      profile=False, zero_copy_alloc_names=("A",)).row()
    assert row == direct
