"""Eviction policies over SVM ranges.

The paper's SVM uses **Least Recently Faulted (LRF)**: the victim is the
range whose last *serviceable fault* (≈ migration time) is oldest. Crucially
LRF never observes on-device reuse — a range that is being intensely read by
the kernel keeps its stale fault timestamp, which is the root cause of the
premature-eviction pathology for Category-III workloads (§3.2, §4.2).

Alternatives implemented for §4.2 ("Eviction Policy") and beyond-paper
comparisons:
  * LRU    — oracle-ish: victim is least recently *touched* (the paper deems
             true LRU too costly on hardware; we provide it as an upper bound).
  * CLOCK  — hot/cold second-chance bits, settable cheaply device-side; the
             paper's suggested practical middle ground.
  * RANDOM — baseline control.
"""

from __future__ import annotations

import random
from collections import OrderedDict


class EvictionPolicy:
    """Tracks candidate (resident, evictable) ranges and picks victims."""

    name = "base"

    def insert(self, rid: int, t: float) -> None:
        raise NotImplementedError

    def remove(self, rid: int) -> None:
        raise NotImplementedError

    def on_fault(self, rid: int, t: float) -> None:
        """A serviceable fault was recorded for a resident range."""

    def on_touch(self, rid: int, t: float) -> None:
        """The kernel touched a resident range (invisible to real LRF)."""

    def victim(self) -> int:
        raise NotImplementedError

    def __contains__(self, rid: int) -> bool:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class LRF(EvictionPolicy):
    """Least Recently Faulted — the paper's SVM policy (§2.2).

    Timestamps update only on serviceable faults. Since a serviceable fault
    immediately precedes the range's migration, LRF degenerates to FIFO in
    migration order, which is exactly the pathology the paper analyses.
    """

    name = "lrf"

    def __init__(self) -> None:
        self._q: OrderedDict[int, float] = OrderedDict()

    def insert(self, rid: int, t: float) -> None:
        self._q.pop(rid, None)
        self._q[rid] = t

    def remove(self, rid: int) -> None:
        self._q.pop(rid, None)

    def on_fault(self, rid: int, t: float) -> None:
        if rid in self._q:
            self._q.move_to_end(rid)
            self._q[rid] = t

    def victim(self) -> int:
        return next(iter(self._q))

    def __contains__(self, rid: int) -> bool:
        return rid in self._q

    def __len__(self) -> int:
        return len(self._q)


class LRU(EvictionPolicy):
    """Least Recently Used — observes device-side touches (upper bound)."""

    name = "lru"

    def __init__(self) -> None:
        self._q: OrderedDict[int, float] = OrderedDict()

    def insert(self, rid: int, t: float) -> None:
        self._q.pop(rid, None)
        self._q[rid] = t

    def remove(self, rid: int) -> None:
        self._q.pop(rid, None)

    def on_fault(self, rid: int, t: float) -> None:
        self.on_touch(rid, t)

    def on_touch(self, rid: int, t: float) -> None:
        if rid in self._q:
            self._q.move_to_end(rid)
            self._q[rid] = t

    def victim(self) -> int:
        return next(iter(self._q))

    def __contains__(self, rid: int) -> bool:
        return rid in self._q

    def __len__(self) -> int:
        return len(self._q)


class Clock(EvictionPolicy):
    """Second-chance CLOCK over ranges (paper §4.2's practical suggestion).

    Touches set a per-range reference bit (device-side metadata copy, no
    host round-trip). The victim scan clears bits until it finds a cold
    range.
    """

    name = "clock"

    def __init__(self) -> None:
        self._order: OrderedDict[int, bool] = OrderedDict()  # rid -> refbit

    def insert(self, rid: int, t: float) -> None:
        self._order.pop(rid, None)
        self._order[rid] = False

    def remove(self, rid: int) -> None:
        self._order.pop(rid, None)

    def on_fault(self, rid: int, t: float) -> None:
        self.on_touch(rid, t)

    def on_touch(self, rid: int, t: float) -> None:
        if rid in self._order:
            self._order[rid] = True

    def victim(self) -> int:
        # sweep: clear hot bits, giving each a second chance
        while True:
            rid, hot = next(iter(self._order.items()))
            if not hot:
                return rid
            self._order[rid] = False
            self._order.move_to_end(rid)

    def __contains__(self, rid: int) -> bool:
        return rid in self._order

    def __len__(self) -> int:
        return len(self._order)


class RandomPolicy(EvictionPolicy):
    name = "random"

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)
        self._set: dict[int, None] = {}

    def insert(self, rid: int, t: float) -> None:
        self._set[rid] = None

    def remove(self, rid: int) -> None:
        self._set.pop(rid, None)

    def victim(self) -> int:
        return self._rng.choice(list(self._set))

    def __contains__(self, rid: int) -> bool:
        return rid in self._set

    def __len__(self) -> int:
        return len(self._set)


POLICIES = {p.name: p for p in (LRF, LRU, Clock, RandomPolicy)}


def make_policy(name: str) -> EvictionPolicy:
    """A fresh eviction-policy instance by name (lrf/lru/clock/random)."""
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r}; "
                         f"available: {sorted(POLICIES)}") from None
