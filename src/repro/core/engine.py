"""Compiled-trace engine — the fast execution tier for the simulators.

`apply_trace` walks a workload trace one op at a time through
`SVMManager.touch`, paying full Python dispatch (dataclass construction,
method calls, attribute chasing) on every op.  Reproducing one paper figure
sweeps the Table-2 suite across DOS points × policies × §4.2 variants, so
that per-op loop dominates `benchmarks/run.py` wall time.

This module lowers a trace **once** into flat NumPy op arrays
(opcode / rid / concurrency / page-hint / float-arg columns) and executes
them with a batched interpreter:

  * **Columnar compile tier**: Table-2 workloads construct the op columns
    *directly* (`Workload.emit_columns` via `ColumnEmitter` —
    `np.repeat`/`np.tile`/`np.arange` over range-id arrays, no per-op
    generator tuples); `compile_workload` dispatches to it and falls back
    to generator lowering (`compile_trace`) for custom workloads or
    ``max_ops`` truncation.  Compiled traces are immutable after build
    (`CompiledTrace.freeze`) and shared **across sweep points** through an
    in-process LRU (`TraceCache` / the module-level ``TRACE_CACHE``): each
    worker compiles each distinct trace once and replays it across its
    policy / variant / manager points.

  * **Phase A** (structure): a lean, integer-only loop over the touch ops
    of a span determines hits, misses, and the exact victim sequence,
    mutating the live policy/residency state.  Resident hits — the paper's
    97–99 % duplicate/hit common case — cost one set lookup.
  * **Phase B** (accounting): all per-migration float work (five-term cost
    model, wall trajectory, duplicate-fault synthesis, trigger pages,
    profile events) is done vectorised with NumPy.  Sequential float
    accumulation order is preserved bit-for-bit via ``np.cumsum`` (an exact
    left-to-right fold) seeded with the manager's current accumulator
    values, so `summary()` is **byte-identical** to the scalar path.
  * Every §4.2 driver variant runs on the fast tier: deferred granularity
    (``defer_granule``/``defer_k``, per-range fault counters and
    granule-sized non-resident migrations), background pre-eviction
    (``previct_watermark``/``previct_overlap``, folded into the wall
    trajectory and cost ledger at the exact scalar add positions), and
    zero-copy allocations (remote-access costs vectorised in-span instead
    of breaking spans at every zero-copy touch).
  * `UVMManager` runs on its own batched interpreter
    (`repro.core.engine_uvm`): the same `execute_compiled` entry point
    dispatches on manager type.  Unknown manager types replay op-for-op.
  * Boundary ops (writeback / pin / unpin / spill) drop to the scalar
    manager path, op for op.
  * The runtime layer (streaming executor, activation offload, serving
    launcher) drives the engine through `TraceSession`: ops are recorded
    incrementally into the same columns, compiled in segments, and
    replayed against *resumable* manager state — a decode loop compiles
    its per-token trace once and replays it every token.

Equivalence guarantee: for any trace and any manager configuration,
executing the compiled trace leaves the manager with the same `summary()`,
counters, residency set, free bytes, eviction order, and (under `profile`)
the same `events`/`density` lists as `apply_trace`.  Two tolerated
deviations: (1) the *stored* (never read) float timestamps inside LRF/LRU
policy queues are patched to the correct wall values at span flush for all
surviving entries; (2) eviction listeners / `eviction_epoch` fire at span
flush rather than at each eviction's wall time — end-of-run totals are
identical, but a listener sampling `mgr.wall` mid-run sees the span-end
clock (drive the manager via `touch()` for per-eviction timing, as the
streaming executor does).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import weakref
from collections import OrderedDict
from typing import Iterable, Sequence

import numpy as np

from repro.core.costmodel import (
    CostParams,
    eviction_cost,
    migration_cost,
    zerocopy_cost,
)
from repro.core.policies import LRF, LRU
from repro.core.ranges import PAGE, AddressSpace
from repro.core.svm import DensitySample, Event, SVMManager
from repro.core.uvm import UVMManager

ENGINE_VERSION = "4"

OP_TOUCH = 0
OP_COMPUTE = 1
OP_WRITEBACK = 2
OP_PIN = 3
OP_UNPIN = 4
# spill-until-free boundary op (runtime layer): drain policy victims via
# `SVMManager.spill_oldest(overlap=farg)` until `free >= hint` bytes —
# the eager-spill loop of the activation-offload scheduler, as an op
OP_SPILL = 5

#: trace-op tag -> opcode; the single source of truth for the op
#: vocabulary.  svmlint's opcode-exhaustiveness rule derives its universe
#: from this table (plus the lowering-only "kernel" marker), so growing
#: it flags every dispatch chain that has not learned the new op.
OP_TAGS = {
    "touch": OP_TOUCH,
    "compute": OP_COMPUTE,
    "writeback": OP_WRITEBACK,
    "pin": OP_PIN,
    "unpin": OP_UNPIN,
    "spill": OP_SPILL,
}

# spans shorter than this run through the scalar manager path: the NumPy
# batch setup would cost more than it saves
FAST_SPAN_MIN = 48

_EMPTY_I = np.zeros(0, dtype=np.int64)


@dataclasses.dataclass
class CompiledTrace:
    """A workload trace lowered to flat op columns (lowered once, executed
    many times — e.g. across the policies × variants axes of a sweep)."""

    codes: np.ndarray      # int8   — OP_* opcode per op
    rids: np.ndarray       # int64  — range id (-1 where n/a)
    concs: np.ndarray      # int64  — touch concurrency
    hints: np.ndarray      # int64  — touch page hint
    fargs: np.ndarray      # float64 — compute seconds
    boundaries: np.ndarray  # int64 — indices of writeback/pin/unpin ops
    touch_pos_np: np.ndarray
    touch_rid_np: np.ndarray
    n_ops: int             # source ops consumed (incl. kernel markers)
    # op-index boundaries of the source segments when this trace was
    # built by `concat` (len = n segments + 1); None for plain traces
    seg_bounds: np.ndarray | None = None
    # per-span slices + uniqueness flags, memoised across executions
    span_cache: dict = dataclasses.field(default_factory=dict)
    # lazy python-list mirrors of the touch stream (Phase A iterates
    # lists); built on first execution, not at compile time — a cached
    # trace shared across sweep points converts once
    _touch_pos: list | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _touch_rid: list | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def touch_pos(self) -> list:
        if self._touch_pos is None:
            self._touch_pos = self.touch_pos_np.tolist()
        return self._touch_pos

    @property
    def touch_rid(self) -> list:
        if self._touch_rid is None:
            self._touch_rid = self.touch_rid_np.tolist()
        return self._touch_rid

    def __len__(self) -> int:
        return len(self.codes)

    def freeze(self) -> "CompiledTrace":
        """Mark the op columns immutable.  A frozen trace is safe to share
        across sweep points (and cache cross-point): execution only reads
        the columns; the `span_cache` memo stays mutable by design."""
        for arr in (self.codes, self.rids, self.concs, self.hints,
                    self.fargs, self.boundaries, self.touch_pos_np,
                    self.touch_rid_np):
            arr.flags.writeable = False
        if self.seg_bounds is not None:
            self.seg_bounds.flags.writeable = False
        return self

    def copy(self) -> "CompiledTrace":
        """Cheap copy: shares the (immutable) op columns, private
        `span_cache` — for callers that want memo isolation (e.g. driving
        one trace from multiple threads)."""
        return dataclasses.replace(self, span_cache={})

    def relocate(self, delta: int) -> "CompiledTrace":
        """A copy of this trace with every range id shifted by ``delta``.

        Rebases a segment recorded against one block of ranges onto a
        congruent block elsewhere in the same address space (two requests
        of the same architecture planned at different offsets into a
        shared pool): only the rid columns are rewritten — opcodes,
        concurrencies, hints, and float args are shared with the source.
        The caller owns the congruence precondition (same per-op relative
        rid layout; `repro.svm.scheduler` checks plan geometry before
        relocating)."""
        if delta == 0:
            return self.copy()
        rids = self.rids.copy()
        rids[rids >= 0] += delta
        return dataclasses.replace(
            self, rids=rids, touch_rid_np=self.touch_rid_np + delta,
            span_cache={}, _touch_rid=None,
        ).freeze()

    @staticmethod
    def concat(segments: "Sequence[CompiledTrace]") -> "CompiledTrace":
        """One mega-trace = the given segments back-to-back, with the
        per-segment op boundaries recorded in ``seg_bounds``.

        This is the fused-round primitive: a scheduler round's relocated
        per-token segments stitch into a single op-column trace that the
        batched interpreter executes in one pass, and `execute_fused`
        samples the manager counters at each ``seg_bounds`` cut to
        attribute costs back per segment.  Executing the concatenation is
        bit-identical to executing the segments back-to-back (the
        `TraceSession` resumability guarantee), so no recompilation or
        re-derivation happens here — columns concatenate, and the
        derived touch/boundary indices shift by each segment's offset."""
        if not segments:
            raise ValueError("CompiledTrace.concat: no segments")
        offs = np.concatenate(
            ([0], np.cumsum([len(s) for s in segments]))).astype(np.int64)
        return CompiledTrace(
            codes=np.concatenate([s.codes for s in segments]),
            rids=np.concatenate([s.rids for s in segments]),
            concs=np.concatenate([s.concs for s in segments]),
            hints=np.concatenate([s.hints for s in segments]),
            fargs=np.concatenate([s.fargs for s in segments]),
            boundaries=np.concatenate(
                [s.boundaries + o for s, o in zip(segments, offs)]),
            touch_pos_np=np.concatenate(
                [s.touch_pos_np + o for s, o in zip(segments, offs)]),
            touch_rid_np=np.concatenate(
                [s.touch_rid_np for s in segments]),
            n_ops=sum(s.n_ops for s in segments),
            seg_bounds=offs,
        ).freeze()

    def tile(self, reps: int) -> "CompiledTrace":
        """``reps`` copies of this trace back-to-back — ``concat([self] *
        reps)`` without materialising the intermediate list of segment
        references, built from whole-column ``np.tile`` ops.

        This is the multi-round fused primitive: a scheduler window of
        ``reps`` identical rounds replays one round's mega-trace tiled,
        with ``seg_bounds`` repeated at per-copy offsets so cut sampling
        still attributes per original segment per round.  Executing the
        tiling is bit-identical to executing the trace ``reps`` times
        back-to-back (the session resumability guarantee)."""
        if reps < 1:
            raise ValueError("CompiledTrace.tile: reps must be >= 1")
        if reps == 1:
            return self
        n = len(self.codes)
        offs = np.arange(reps, dtype=np.int64) * n
        bounds = self.seg_bounds
        if bounds is None:
            bounds = np.array([0, n], dtype=np.int64)
        # tiled bounds: each copy contributes its interior cuts shifted by
        # its offset; the shared endpoints collapse (copy k's end == copy
        # k+1's start), giving len = reps * (len(bounds) - 1) + 1
        tiled_bounds = np.concatenate(
            [(bounds[:-1][None, :] + offs[:, None]).ravel(),
             [n * reps]]).astype(np.int64)
        out = CompiledTrace(
            codes=np.tile(self.codes, reps),
            rids=np.tile(self.rids, reps),
            concs=np.tile(self.concs, reps),
            hints=np.tile(self.hints, reps),
            fargs=np.tile(self.fargs, reps),
            boundaries=(self.boundaries[None, :] + offs[:, None]).ravel(),
            touch_pos_np=(self.touch_pos_np[None, :]
                          + offs[:, None]).ravel(),
            touch_rid_np=np.tile(self.touch_rid_np, reps),
            n_ops=self.n_ops * reps,
            seg_bounds=tiled_bounds,
        ).freeze()
        # seed the whole-trace span memo from the source's structure:
        # tiling introduces no new rids, so the unique-rid set and each
        # rid's first touch ordinal are the source's (first copy), and
        # repeats make the stream trivially non-unique.  Saves an
        # O(N log N) `np.unique` over the tiled stream — windows are
        # executed once, so nothing would amortise it.  The seeds key on
        # zc_key=None; a zero-copy execution misses them and recomputes.
        if len(self.boundaries) == 0 and len(out.touch_rid_np):
            n_out = len(out.codes)
            out.span_cache[(0, n_out, None)] = [
                None, None, out.touch_pos_np, out.touch_rid_np,
                False, _EMPTY_I, _EMPTY_I]
            u, first_idx = np.unique(self.touch_rid_np, return_index=True)
            out.span_cache[("uniq", 0, n_out, None)] = (
                u, u.tolist(), first_idx)
        return out

    def span(self, s: int, e: int, zc_mask=None, zc_key=None):
        """Touch-stream slice for ops [s, e): a mutable cache cell
        ``[pos_list, rid_list, pos_np, rid_np, rids_unique, zc_pos_np,
        zc_rid_np]``.  Touches on zero-copy ranges (``zc_mask`` indexed by
        rid; ``zc_key`` identifies the zero-copy configuration for
        caching) are split out of the policy-visible stream.  Cached —
        compiled traces are executed many times (policy/variant axes of a
        sweep).  The Python-list mirrors (slots 0/1) materialise lazily
        via `span_lists` — only the sequential Phase-A fallbacks read
        them, and a multi-round window span can hold millions of touches
        the vectorised paths never iterate."""
        key = (s, e, zc_key)
        cached = self.span_cache.get(key)
        if cached is None:
            lo, hi = np.searchsorted(self.touch_pos_np, (s, e))
            pos_np = self.touch_pos_np[lo:hi]
            rid_np = self.touch_rid_np[lo:hi]
            zc_pos = zc_rid = _EMPTY_I
            if zc_mask is not None and len(rid_np):
                zsel = zc_mask[rid_np]
                if zsel.any():
                    zc_pos = pos_np[zsel]
                    zc_rid = rid_np[zsel]
                    keep = ~zsel
                    pos_np = pos_np[keep]
                    rid_np = rid_np[keep]
            uniq = len(np.unique(rid_np)) == len(rid_np)
            cached = [None, None, pos_np, rid_np, uniq, zc_pos, zc_rid]
            self.span_cache[key] = cached
        return cached

    def span_lists(self, s: int, e: int, zc_key=None) -> tuple[list, list]:
        """The (pos_list, rid_list) mirrors of a cached `span` entry,
        materialised on first use and memoised in the cache cell."""
        cached = self.span_cache[(s, e, zc_key)]
        if cached[0] is None:
            cached[0] = cached[2].tolist()
            cached[1] = cached[3].tolist()
        return cached[0], cached[1]

    def touch_columns(self) -> tuple[np.ndarray, np.ndarray]:
        """The whole-trace (op position, rid) touch columns — the access
        log the hot-set estimator (`repro.svm.hotset`) profiles.  The
        returned arrays are the trace's own (frozen) columns; callers
        must treat them as read-only."""
        return self.touch_pos_np, self.touch_rid_np

    def touch_counts(self, minlength: int = 0) -> np.ndarray:
        """Per-rid touch counts over the whole trace, as one `bincount`
        pass over the rid column (index = absolute rid)."""
        if not len(self.touch_rid_np):
            return np.zeros(minlength, dtype=np.int64)
        return np.bincount(self.touch_rid_np, minlength=minlength)


def compile_trace(trace: Iterable, max_ops: int | None = None) -> CompiledTrace:
    """Lower a lazy op trace into flat columns.

    Kernel markers are consumed (they count toward ``max_ops``, matching
    `apply_trace`) but not materialised.
    """
    if max_ops is not None:
        trace = itertools.islice(trace, max_ops)
    codes: list[int] = []
    rids: list[int] = []
    concs: list[int] = []
    hints: list[int] = []
    fargs: list[float] = []
    n_src = 0
    for op in trace:
        n_src += 1
        tag = op[0]
        if tag == "touch":
            codes.append(OP_TOUCH)
            rids.append(op[1])
            concs.append(op[2])
            hints.append(op[3] or 0)
            fargs.append(0.0)
        elif tag == "compute":
            codes.append(OP_COMPUTE)
            rids.append(-1)
            concs.append(0)
            hints.append(0)
            fargs.append(op[1])
        elif tag == "kernel":
            continue
        elif tag == "writeback":
            codes.append(OP_WRITEBACK)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        elif tag == "pin":
            codes.append(OP_PIN)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        elif tag == "unpin":
            codes.append(OP_UNPIN)
            rids.append(op[1])
            concs.append(0)
            hints.append(0)
            fargs.append(0.0)
        elif tag == "spill":
            codes.append(OP_SPILL)
            rids.append(-1)
            concs.append(0)
            hints.append(op[1])        # bytes that must become free
            fargs.append(op[2])        # overlap fraction
        else:
            raise ValueError(f"unknown trace op {tag!r}")
    return compiled_from_columns(
        np.array(codes, dtype=np.int8),
        np.array(rids, dtype=np.int64),
        np.array(concs, dtype=np.int64),
        np.array(hints, dtype=np.int64),
        np.array(fargs, dtype=np.float64),
        n_src,
    )


def compiled_from_columns(codes: np.ndarray, rids: np.ndarray,
                          concs: np.ndarray, hints: np.ndarray,
                          fargs: np.ndarray, n_ops: int) -> CompiledTrace:
    """Assemble (and freeze) a CompiledTrace from flat op columns — the
    shared tail of generator lowering and columnar emission."""
    touch_mask = codes == OP_TOUCH
    touch_pos_np = np.nonzero(touch_mask)[0]
    touch_rid_np = rids[touch_mask]
    return CompiledTrace(
        codes=codes,
        rids=rids,
        concs=concs,
        hints=hints,
        fargs=fargs,
        boundaries=np.nonzero(codes >= OP_WRITEBACK)[0],
        touch_pos_np=touch_pos_np,
        touch_rid_np=touch_rid_np,
        n_ops=n_ops,
    ).freeze()


_NEG1_I = np.array([-1], dtype=np.int64)   # shared compute-op rid chunk


class ColumnEmitter:
    """Builds the flat op columns directly — the columnar compile tier.

    Table-2 workloads describe their access patterns as vectorised blocks
    (`touches` over a rid array, per-row touch×k+compute `rows`, …)
    instead of yielding per-op generator tuples; `finish()` assembles the
    blocks into a CompiledTrace.  Op-for-op identical to lowering the
    workload's `trace()` generator through `compile_trace` (golden-tested
    in tests/test_columnar_traces.py).

    Hot-loop cost model: *uniform* blocks (`touches`/`compute`/`pins` —
    one opcode/concurrency/hint/farg for the whole block, the shape of
    the per-wave loops) append four Python scalars and a rid array;
    columns for a run of consecutive uniform blocks are materialised with
    one `np.repeat` per column at `finish()`.  Interleaved blocks
    (`rows`, `touch_writeback`) are prebuilt per call."""

    def __init__(self):
        # uniform-block descriptors (parallel lists)
        self._u_code: list[int] = []
        self._u_conc: list[int] = []
        self._u_hint: list[int] = []
        self._u_farg: list[float] = []
        self._u_len: list[int] = []
        self._u_rids: list[np.ndarray] = []
        # ordered assembly plan: ("u", uniform idx) | ("p", 5 columns)
        self._parts: list[tuple] = []
        self.n_ops = 0        # source ops, incl. kernel markers

    def kernel(self) -> None:
        """Kernel-boundary marker: consumed, not materialised (matches
        `compile_trace`), but counted toward ``n_ops``."""
        self.n_ops += 1

    def _uniform(self, code: int, rids: np.ndarray, conc: int, hint: int,
                 farg: float, n: int) -> None:
        self._parts.append(("u", len(self._u_len)))
        self._u_code.append(code)
        self._u_conc.append(conc)
        self._u_hint.append(hint)
        self._u_farg.append(farg)
        self._u_len.append(n)
        self._u_rids.append(rids)
        self.n_ops += n

    def touches(self, rids, conc: int, hint: int = 0) -> None:
        rids = np.asarray(rids, dtype=np.int64)
        if len(rids):
            self._uniform(OP_TOUCH, rids, conc, hint, 0.0, len(rids))

    def compute(self, seconds: float) -> None:
        self._uniform(OP_COMPUTE, _NEG1_I, 0, 0, seconds, 1)

    def pins(self, rids) -> None:
        rids = np.asarray(rids, dtype=np.int64)
        if len(rids):
            self._uniform(OP_PIN, rids, 0, 0, 0.0, len(rids))

    def raw(self, codes, rids, concs, hints, fargs) -> None:
        """Prebuilt column block (already dtype-correct: int8 / int64 ×3 /
        float64) — for fully vectorised irregular patterns.  The arrays
        remain the caller's: `finish` copies them if they would otherwise
        be frozen into the trace."""
        self._parts.append(("p", (codes, rids, concs, hints, fargs), False))
        self.n_ops += len(codes)

    def rows(self, rid_cols, conc: int, fargs, hint: int = 0) -> None:
        """Per-row interleave: k touches (the columns of ``rid_cols``,
        one row per iteration) followed by one compute of ``fargs[i]``."""
        rid_cols = np.asarray(rid_cols, dtype=np.int64)
        n, k = rid_cols.shape
        if n == 0:
            return
        codes = np.full(k + 1, OP_TOUCH, dtype=np.int8)
        codes[k] = OP_COMPUTE
        rids = np.empty((n, k + 1), dtype=np.int64)
        rids[:, :k] = rid_cols
        rids[:, k] = -1
        concs = np.full(k + 1, conc, dtype=np.int64)
        concs[k] = 0
        hints = np.full(k + 1, hint, dtype=np.int64)
        hints[k] = 0
        f = np.zeros((n, k + 1))
        f[:, k] = fargs
        self._parts.append(("p", (np.tile(codes, n), rids.ravel(),
                                  np.tile(concs, n), np.tile(hints, n),
                                  f.ravel()), True))
        self.n_ops += n * (k + 1)

    def touch_writeback(self, rids, conc: int, hint: int = 0) -> None:
        """Per-rid (touch, writeback) pairs — the BFS frontier pattern."""
        rids = np.asarray(rids, dtype=np.int64)
        n = len(rids)
        if n == 0:
            return
        codes = np.empty(2 * n, dtype=np.int8)
        codes[0::2] = OP_TOUCH
        codes[1::2] = OP_WRITEBACK
        concs = np.zeros(2 * n, dtype=np.int64)
        concs[0::2] = conc
        hints = np.zeros(2 * n, dtype=np.int64)
        hints[0::2] = hint
        self._parts.append(("p", (codes, np.repeat(rids, 2), concs, hints,
                                  np.zeros(2 * n)), True))
        self.n_ops += 2 * n

    def _uniform_seg(self, i0: int, i1: int) -> tuple:
        """Materialise uniform blocks [i0, i1) — one repeat per column."""
        lens = np.asarray(self._u_len[i0:i1])
        return (
            np.repeat(np.array(self._u_code[i0:i1], dtype=np.int8), lens),
            (self._u_rids[i0] if i1 - i0 == 1
             else np.concatenate(self._u_rids[i0:i1])),
            np.repeat(np.array(self._u_conc[i0:i1], dtype=np.int64), lens),
            np.repeat(np.array(self._u_hint[i0:i1], dtype=np.int64), lens),
            np.repeat(np.asarray(self._u_farg[i0:i1]), lens),
        )

    def finish(self) -> CompiledTrace:
        segs: list[tuple] = []
        owned = False      # does the last seg own (all of) its arrays?
        parts = self._parts
        i = 0
        while i < len(parts):
            part = parts[i]
            if part[0] == "p":
                segs.append(part[1])
                owned = part[2]
                i += 1
                continue
            j = i
            while j < len(parts) and parts[j][0] == "u":
                j += 1
            i0, i1 = part[1], parts[j - 1][1] + 1
            owned = i1 - i0 > 1    # single block: rid col is the caller's
            segs.append(self._uniform_seg(i0, i1))
            i = j
        if not segs:
            cols = (np.zeros(0, dtype=np.int8), _EMPTY_I.copy(),
                    _EMPTY_I.copy(), _EMPTY_I.copy(), np.zeros(0))
        elif len(segs) == 1:
            # freeze must not flip writeable on caller-held arrays
            cols = segs[0] if owned else tuple(c.copy() for c in segs[0])
        else:
            cols = tuple(np.concatenate([s[c] for s in segs])
                         for c in range(5))
        return compiled_from_columns(*cols, self.n_ops)


class TraceCache:
    """Small in-process LRU of compiled traces.

    Keys are caller-defined (see `repro.core.sweep.trace_key`: the workload
    spec + address-space geometry that fully determine the trace).  Entries
    are frozen CompiledTraces, safe to replay across the policy / variant /
    manager points of a sweep.

    Memory: a live entry pins its op columns *and* its execution memos
    (lazy touch-list mirrors, span cache) — tens of MB for a fine-grained
    million-op trace.  Grid-aware scheduling replays a trace's points
    back-to-back, so a handful of slots suffices; size the LRU to one
    grid's working set and `clear()` to release everything."""

    def __init__(self, maxsize: int = 16):
        self.maxsize = maxsize
        self._d: "OrderedDict[object, CompiledTrace]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key) -> CompiledTrace | None:
        ct = self._d.get(key)
        if ct is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return ct

    def put(self, key, ct: CompiledTrace) -> None:
        self._d[key] = ct
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0

    def __len__(self) -> int:
        return len(self._d)


# process-wide default: one per sweep worker, shared by every run_point
TRACE_CACHE = TraceCache()


def compile_workload(workload, space: AddressSpace,
                     max_ops: int | None = None, *,
                     cache: TraceCache | None = None, key=None,
                     columnar: bool = True) -> CompiledTrace:
    """Lower a workload's trace, preferring the columnar tier.

    Table-2 workloads construct the flat op columns directly
    (``emit_columns`` — `np.repeat`/`np.tile`/`np.arange` over range-id
    arrays, no per-op generator tuples); custom workloads, and ``max_ops``
    truncations (which count kernel markers op-for-op), lower the
    generator through `compile_trace`.  With ``cache`` and ``key`` set the
    compiled trace is memoised so sweep points sharing a workload spec
    compile once and replay (`repro.core.sweep.trace_key`)."""
    if cache is not None and key is not None and max_ops is None:
        ct = cache.get(key)
        if ct is None:
            ct = _compile_uncached(workload, space, max_ops, columnar)
            cache.put(key, ct)
        return ct
    return _compile_uncached(workload, space, max_ops, columnar)


def _compile_uncached(workload, space, max_ops, columnar) -> CompiledTrace:
    emit = getattr(workload, "emit_columns", None) if columnar else None
    if emit is not None and max_ops is None:
        return emit(space)
    return compile_trace(workload.trace(space), max_ops=max_ops)


# ------------------------------------------------------------- trace session

class SegmentCache:
    """Keyed LRU of compiled segments **shared across sessions** bound to
    one manager — the cross-request analogue of the cross-point
    `TRACE_CACHE`.

    Entries are stored as ``key -> (rid_base, CompiledTrace)``, where
    ``rid_base`` is the first range id of the block the recording session
    was planned against.  A session looking up the same key from a
    different base receives the segment **relocated** by the rid delta
    (`CompiledTrace.relocate` — one vectorised add over the rid columns
    instead of a re-record + re-compile), which is how N same-architecture
    serving requests planned at different offsets into one shared pool
    replay a single compiled per-token segment.

    Sharing is only sound between congruent rid blocks (identical per-op
    relative layout); publishers guarantee that by keying on the
    architecture *and* its plan geometry (see
    `repro.svm.scheduler.PoolScheduler`)."""

    def __init__(self, cache_size: int = 256):
        self.cache_size = cache_size
        self._segments: "OrderedDict[object, tuple[int, CompiledTrace]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.relocations = 0
        self.concats = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._segments)

    def get(self, key, rid_base: int) -> CompiledTrace | None:
        """Cached segment for ``key`` rebased to ``rid_base`` (LRU
        refreshed), or None."""
        ent = self._segments.get(key)
        if ent is None:
            self.misses += 1
            return None
        self._segments.move_to_end(key)
        self.hits += 1
        base0, ct = ent
        if base0 == rid_base:
            return ct
        self.relocations += 1
        return ct.relocate(rid_base - base0)

    def batch_relocate(self, key,
                       rid_bases: Sequence[int]) -> list[CompiledTrace] | None:
        """One segment for ``key``, rebased to *each* of ``rid_bases`` —
        a whole scheduler round's worth of same-architecture lookups in a
        single cache probe.  Counter contract matches the sequential
        `get` chain exactly: one miss when the key is absent (the caller
        records once and retries for the rest), else one hit per
        requested base and one relocation per base that differs from the
        recorded prototype's."""
        ent = self._segments.get(key)
        if ent is None:
            self.misses += 1
            return None
        self._segments.move_to_end(key)
        self.hits += len(rid_bases)
        base0, ct = ent
        out = []
        for base in rid_bases:
            if base == base0:
                out.append(ct)
            else:
                self.relocations += 1
                out.append(ct.relocate(base - base0))
        return out

    def concat(self, segments: Sequence[CompiledTrace]) -> CompiledTrace:
        """Stitch relocated segments into one fused-round mega-trace
        (`CompiledTrace.concat`), counting the build for `stats()` —
        schedulers memoise the result per block, so ``shared_concats``
        measures distinct round shapes, not rounds."""
        self.concats += 1
        return CompiledTrace.concat(segments)

    def put(self, key, rid_base: int, ct: CompiledTrace) -> None:
        self._segments[key] = (rid_base, ct)
        self._segments.move_to_end(key)
        while len(self._segments) > self.cache_size:
            self._segments.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._segments.clear()

    def stats(self) -> dict:
        return {"shared_segments": len(self._segments),
                "shared_lookup_hits": self.hits,
                "shared_lookup_misses": self.misses,
                "shared_relocations": self.relocations,
                "shared_concats": self.concats,
                "shared_evictions": self.evictions}


class TraceSession:
    """Record → compile → replay API for the runtime layer.

    Where `compile_workload` lowers a *complete* trace up front, a session
    records ops **incrementally** into the flat `OP_*` columns, compiles
    them into frozen `CompiledTrace` *segments*, and replays each segment
    against the live manager.  The manager's residency, policy queues,
    ledgers, and clock carry across segment replays — executing segments
    back-to-back is bit-identical to executing their concatenation (every
    accumulator fold is seeded from the manager's current value) — so a
    replay *resumes* where the previous one stopped.

    Segments sealed under a key land in a per-session LRU, which is what
    makes a decode loop cheap: the per-token layer-fetch trace records and
    compiles **once** (first token) and replays as a compiled segment every
    later token (`run`; hits/misses counted).  Sessions bound to one
    manager can additionally share a `SegmentCache` (``shared_cache=``):
    on a local miss, `run` consults the shared cache and — when the hit
    was recorded by a session planned at a different offset into the
    space — relocates the segment to this session's ``rid_base``, so N
    same-architecture serving requests replay a single compiled trace.

    ``scalar=True`` replays segments op-for-op through the manager's own
    `touch`/`advance`/… methods (`_replay`) instead of the batched
    interpreter — the imperative reference path, used by the golden
    equivalence tests.  Both modes execute the *same* recorded op sequence,
    and the engine's equivalence guarantee makes their `summary()` output
    byte-identical.

    Op vocabulary = `apply_trace`'s, plus ``spill(need_bytes, overlap)``
    (`OP_SPILL`): drain `spill_oldest(overlap=…)` victims until ``free >=
    need_bytes`` — the runtime layer's eager-spill loop as an op.  `OP_SPILL`
    is SVM-only (the UVM interpreter rejects it).
    """

    def __init__(self, mgr, *, scalar: bool = False, cache_size: int = 64,
                 shared_cache: SegmentCache | None = None,
                 rid_base: int = 0):
        self.mgr = mgr
        self.scalar = scalar
        self.cache_size = cache_size
        # cross-session segment sharing (multi-tenant serving): `run`
        # consults the shared cache on a local miss, relocating the hit
        # to this session's rid base; fresh seals are published back
        self.shared_cache = shared_cache
        self.rid_base = rid_base
        self._codes: list[int] = []
        self._rids: list[int] = []
        self._concs: list[int] = []
        self._hints: list[int] = []
        self._fargs: list[float] = []
        self._n_src = 0
        self._segments: "OrderedDict[object, CompiledTrace]" = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0
        self.shared_hits = 0
        self.segments_sealed = 0
        self.segments_replayed = 0
        self.ops_recorded = 0
        self.ops_replayed = 0

    # ------------------------------------------------------------ recording

    @property
    def pending(self) -> int:
        """Ops recorded but not yet sealed into a segment."""
        return len(self._codes)

    def _op(self, code: int, rid: int, conc: int, hint: int,
            farg: float) -> None:
        self._codes.append(code)
        self._rids.append(rid)
        self._concs.append(conc)
        self._hints.append(hint)
        self._fargs.append(farg)
        self._n_src += 1
        self.ops_recorded += 1

    def touch(self, rid: int, *, concurrency: int = 32,
              page_hint: int = 0) -> None:
        self._op(OP_TOUCH, rid, concurrency, page_hint or 0, 0.0)

    def compute(self, seconds: float) -> None:
        self._op(OP_COMPUTE, -1, 0, 0, seconds)

    def writeback(self, rid: int) -> None:
        self._op(OP_WRITEBACK, rid, 0, 0, 0.0)

    def pin(self, rid: int) -> None:
        self._op(OP_PIN, rid, 0, 0, 0.0)

    def unpin(self, rid: int) -> None:
        self._op(OP_UNPIN, rid, 0, 0, 0.0)

    def spill(self, need_bytes: int, *, overlap: float = 0.0) -> None:
        """Record an eager-spill boundary: at replay, policy victims are
        pre-evicted (`spill_oldest(overlap=…)`) until ``free >=
        need_bytes`` or nothing is evictable."""
        self._op(OP_SPILL, -1, 0, int(need_bytes), overlap)

    def kernel(self) -> None:
        """Kernel-boundary marker: consumed, not materialised (matches
        `compile_trace`), but counted toward the segment's ``n_ops``."""
        self._n_src += 1

    def record(self, ops: Iterable) -> None:
        """Record a batch of `apply_trace`-vocabulary op tuples."""
        for op in ops:
            tag = op[0]
            if tag == "touch":
                self.touch(op[1], concurrency=op[2], page_hint=op[3])
            elif tag == "compute":
                self.compute(op[1])
            elif tag == "kernel":
                self.kernel()
            elif tag == "writeback":
                self.writeback(op[1])
            elif tag == "pin":
                self.pin(op[1])
            elif tag == "unpin":
                self.unpin(op[1])
            elif tag == "spill":
                self.spill(op[1], overlap=op[2])
            else:
                raise ValueError(f"unknown trace op {tag!r}")

    # ------------------------------------------------------ compile / replay

    def seal(self, key=None) -> CompiledTrace:
        """Compile the pending ops into a frozen segment (and clear the
        recording buffer).  With ``key`` the segment enters the session's
        LRU for later `run`/`get` replays."""
        ct = compiled_from_columns(
            np.array(self._codes, dtype=np.int8),
            np.array(self._rids, dtype=np.int64),
            np.array(self._concs, dtype=np.int64),
            np.array(self._hints, dtype=np.int64),
            np.array(self._fargs, dtype=np.float64),
            self._n_src,
        )
        self._codes = []
        self._rids = []
        self._concs = []
        self._hints = []
        self._fargs = []
        self._n_src = 0
        self.segments_sealed += 1
        if key is not None:
            self._cache_put(key, ct)
        return ct

    def _cache_put(self, key, ct: CompiledTrace) -> None:
        """Insert into the session LRU, trimming to ``cache_size``."""
        self._segments[key] = ct
        self._segments.move_to_end(key)
        while len(self._segments) > self.cache_size:
            self._segments.popitem(last=False)

    def get(self, key) -> CompiledTrace | None:
        """Cached segment for ``key`` (LRU-refreshed), or None."""
        ct = self._segments.get(key)
        if ct is not None:
            self._segments.move_to_end(key)
        return ct

    def replay(self, ct: CompiledTrace) -> None:
        """Execute one compiled segment against the manager, resuming from
        its current state."""
        if self.scalar:
            _replay(ct, self.mgr, 0, len(ct))
        else:
            execute_compiled(ct, self.mgr)
        self.segments_replayed += 1
        self.ops_replayed += len(ct)

    def replay_scalar(self, ct: CompiledTrace) -> None:
        """Golden op-for-op replay of one segment, regardless of the
        session's mode.  The chaos layer routes fault-armed tokens here:
        an armed `MigrationError` must surface at the *exact* faulting op
        with the manager untouched past it, which the scalar dispatch
        guarantees unconditionally (the batched tier only guarantees it
        on the snapshot/restore path).  Byte-identical to `replay` when
        nothing raises, by the engine's equivalence contract."""
        _replay(ct, self.mgr, 0, len(ct))
        self.segments_replayed += 1
        self.ops_replayed += len(ct)

    def flush(self, key=None) -> CompiledTrace | None:
        """Seal the pending ops and replay them immediately.  Returns the
        segment (cached under ``key`` if given), or None when nothing was
        pending."""
        if not self._codes and self._n_src == 0:
            return None
        ct = self.seal(key)
        self.replay(ct)
        return ct

    def fetch(self, key, record_fn) -> CompiledTrace:
        """Resolve ``key`` to a compiled segment without executing it:
        local LRU hit, shared-cache relocation, or — on the first
        encounter — record via ``record_fn(session)``, seal, cache, and
        publish.  `run` is fetch + replay; the fused scheduler fetches
        every segment of a round up front and replays their concatenation
        in one batched pass.  Requires an empty recording buffer."""
        if self._codes or self._n_src:   # incl. pending kernel markers
            raise RuntimeError(
                f"TraceSession.fetch({key!r}): {self.pending} recorded "
                "ops pending; flush() them before running a segment")
        ct = self.get(key)
        if ct is None and self.shared_cache is not None:
            ct = self.shared_cache.get(key, self.rid_base)
            if ct is not None:
                # adopt into the local LRU: later tokens replay without
                # another shared lookup (or relocation)
                self.shared_hits += 1
                self._cache_put(key, ct)
        elif ct is not None:
            self.cache_hits += 1
        if ct is None:
            self.cache_misses += 1
            record_fn(self)
            ct = self.seal(key)
            if self.shared_cache is not None:
                self.shared_cache.put(key, self.rid_base, ct)
        return ct

    def run(self, key, record_fn) -> CompiledTrace:
        """The decode-loop primitive: replay the compiled segment cached
        under ``key``, or — on the first encounter — record it via
        ``record_fn(session)``, seal, cache, and replay."""
        ct = self.fetch(key, record_fn)
        self.replay(ct)
        return ct

    def stats(self) -> dict:
        return {
            "segments_sealed": self.segments_sealed,
            "segments_replayed": self.segments_replayed,
            "segment_cache_hits": self.cache_hits,
            "segment_cache_misses": self.cache_misses,
            "segment_shared_hits": self.shared_hits,
            "ops_recorded": self.ops_recorded,
            "ops_replayed": self.ops_replayed,
        }


# --------------------------------------------------------------- cost tables

# per-AddressSpace static tables, shared by every execution over that space
_SPACE_TABLES: "weakref.WeakKeyDictionary[AddressSpace, dict]" = \
    weakref.WeakKeyDictionary()


def _params_tables(size_arr: np.ndarray, params: CostParams,
                   xcost: dict | None = None,
                   zcc: dict | None = None) -> dict:
    usz = np.unique(size_arr)
    # migration_cost is a pure function of (size, params): memoised
    # values are bit-identical to what the scalar path computes fresh
    mcs = [migration_cost(int(s), params) for s in usz.tolist()]
    return {
        "usz": usz,
        "terms": np.array([[m.cpu_unmap, m.sdma_setup, m.alloc,
                            m.cpu_update, m.misc] for m in mcs]),
        "ecs": np.array([eviction_cost(int(s), params)
                         for s in usz.tolist()]),
        "sizeidx": np.searchsorted(usz, size_arr),
        # off-table sizes (deferred granules) and zero-copy touch costs:
        # pure (size, params) memos, carried across table growth
        "xcost": xcost if xcost is not None else {},
        "zcc": zcc if zcc is not None else {},
    }


# one-entry identity memo over (space, params, n_ranges): scheduler rounds
# call `_tables` once per span with the same space and params, so the
# common case skips the weak-dict probe and the params-keyed dict hashes
# entirely.  Holds only a weakref to the space (the strong tables live in
# `_SPACE_TABLES`), so it cannot extend any space's lifetime.
_TABLES_LAST: tuple | None = None


def _tables(space: AddressSpace, params: CostParams) -> dict:
    global _TABLES_LAST
    last = _TABLES_LAST
    n = len(space.ranges)
    if (last is not None and last[0]() is space and last[1] is params
            and last[2] == n):
        return last[3]
    tab = _SPACE_TABLES.get(space)
    if tab is None:
        size_arr = np.array([r.end - r.start for r in space.ranges],
                            dtype=np.int64)
        tab = {
            "n_ranges": n,
            "sizes": size_arr.tolist(),
            "size_arr": size_arr,
            "alloc_ids": [r.alloc_id for r in space.ranges],
            "pages": np.array([r.start // PAGE for r in space.ranges],
                              dtype=np.int64),
            "params": {},
            "merged": {},
        }
        _SPACE_TABLES[space] = tab
    elif tab["n_ranges"] != n:
        # the space only ever *grows* (AddressSpace.alloc extends the
        # range list), so admissions mid-run extend the static columns
        # with the new tail instead of rebuilding O(n_ranges) tables
        new = space.ranges[tab["n_ranges"]:]
        tail = np.array([r.end - r.start for r in new], dtype=np.int64)
        tab["n_ranges"] = n
        tab["size_arr"] = np.concatenate([tab["size_arr"], tail])
        tab["sizes"].extend(tail.tolist())
        tab["alloc_ids"].extend(r.alloc_id for r in new)
        tab["pages"] = np.concatenate(
            [tab["pages"],
             np.array([r.start // PAGE for r in new], dtype=np.int64)])
        tab.pop("zc_masks", None)      # stale length
        for p, pp in tab["params"].items():
            tab["params"][p] = _params_tables(
                tab["size_arr"], p, pp["xcost"], pp["zcc"])
        tab["merged"].clear()
    merged = tab["merged"].get(params)
    if merged is None:
        per_params = tab["params"].get(params)
        if per_params is None:
            per_params = _params_tables(tab["size_arr"], params)
            tab["params"][params] = per_params
        merged = {**tab, **per_params}
        tab["merged"][params] = merged
    _TABLES_LAST = (weakref.ref(space), params, n, merged)
    return merged


def _terms_for_sizes(tab: dict, m_nb: np.ndarray,
                     params: CostParams) -> np.ndarray:
    """(len(m_nb), 5) cost terms for arbitrary per-miss byte counts —
    deferred-granularity migrations are granule-sized, off the range-size
    table.  Memoised per unique size, bit-identical to the scalar path's
    fresh `migration_cost` calls."""
    xc = tab["xcost"]
    usz2, inv = np.unique(m_nb, return_inverse=True)
    tarr = np.empty((len(usz2), 5))
    for j, sz in enumerate(usz2.tolist()):
        t = xc.get(sz)
        if t is None:
            m = migration_cost(sz, params)
            t = (m.cpu_unmap, m.sdma_setup, m.alloc, m.cpu_update, m.misc)
            xc[sz] = t
        tarr[j] = t
    return tarr[inv]


def _zc_costs(tab: dict, zc_sizes: np.ndarray,
              params: CostParams) -> np.ndarray:
    zcc = tab["zcc"]
    usz, inv = np.unique(zc_sizes, return_inverse=True)
    carr = np.empty(len(usz))
    for j, sz in enumerate(usz.tolist()):
        c = zcc.get(sz)
        if c is None:
            c = zerocopy_cost(sz, params)
            zcc[sz] = c
        carr[j] = c
    return carr[inv]


# ----------------------------------------------------------------- execution

def execute_compiled(ct: CompiledTrace, mgr) -> None:
    """Apply a compiled trace to a manager; equivalent to `apply_trace`.

    Dispatches on the manager type: `SVMManager` and `UVMManager` execute
    on their batched interpreters; any other manager replays op-for-op
    through its own `touch`/`advance`/... methods."""
    if type(mgr) is SVMManager:
        _execute_svm(ct, mgr)
    elif type(mgr) is UVMManager:
        from repro.core.engine_uvm import execute_compiled_uvm
        execute_compiled_uvm(ct, mgr)
    else:
        _replay(ct, mgr, 0, len(ct))


def _zc_setup(mgr: SVMManager) -> tuple:
    """(zc_mask, zc_key) for the manager's zero-copy configuration —
    the per-execution preamble shared by `_execute_svm`/`execute_fused`."""
    zc_mask = zc_key = None
    if mgr.zero_copy_allocs:
        key = frozenset(mgr.zero_copy_allocs)
        tab = _SPACE_TABLES.get(mgr.space)
        masks = tab.setdefault("zc_masks", {}) if tab is not None else {}
        zc_mask = masks.get(key)
        if zc_mask is None:
            aid_arr = np.array([r.alloc_id for r in mgr.space.ranges])
            zc_mask = np.isin(aid_arr, list(key))
            masks[key] = zc_mask
        if zc_mask.any():
            zc_key = key
        else:
            zc_mask = None
    return zc_mask, zc_key


def _execute_svm(ct: CompiledTrace, mgr: SVMManager) -> None:
    zc_mask, zc_key = _zc_setup(mgr)
    pos = 0
    for b in ct.boundaries.tolist():
        _run_span(ct, mgr, pos, b, zc_mask, zc_key)
        _exec_boundary(ct, mgr, b)
        pos = b + 1
    _run_span(ct, mgr, pos, len(ct), zc_mask, zc_key)


def _read_counters(mgr, out: np.ndarray, ci: int) -> None:
    out[ci, 0] = mgr.wall
    out[ci, 1] = mgr.n_migrations
    out[ci, 2] = mgr.n_evictions
    out[ci, 3] = mgr.bytes_migrated
    out[ci, 4] = mgr.bytes_evicted


def execute_fused(ct: CompiledTrace, mgr, cuts) -> np.ndarray:
    """Execute ``ct`` exactly like `execute_compiled`, additionally
    snapshotting the five attribution counters — wall clock, migrations,
    evictions, bytes migrated, bytes evicted — after each op index in
    ``cuts`` (sorted, ascending; typically a concatenated round's
    ``seg_bounds[1:]``).  Returns a ``(len(cuts), 5)`` float64 array.

    This is the fused-round entry point: a scheduler replays a whole
    round's concatenated segments in **one** batched-interpreter pass and
    attributes per-request deltas from the cut snapshots instead of N
    manager round-trips.  The snapshots are byte-identical to reading the
    manager between back-to-back `execute_compiled` calls at the same
    boundaries: mid-span wall values come from the same exact `np.cumsum`
    trajectory Phase B folds the wall with, and the count/byte columns
    are integer prefix sums of Phase A's miss/victim streams.  SVM-only
    (the UVM interpreter has no span sampling)."""
    if type(mgr) is not SVMManager:
        raise TypeError("execute_fused requires an SVMManager, got "
                        f"{type(mgr).__name__}")
    cuts = np.asarray(cuts, dtype=np.int64)
    out = np.empty((len(cuts), 5))
    zc_mask, zc_key = _zc_setup(mgr)
    pos = 0
    ci = 0
    for b in ct.boundaries.tolist():
        ci = _run_span_sampled(ct, mgr, pos, b, zc_mask, zc_key,
                               cuts, out, ci)
        _exec_boundary(ct, mgr, b)
        pos = b + 1
    ci = _run_span_sampled(ct, mgr, pos, len(ct), zc_mask, zc_key,
                           cuts, out, ci)
    while ci < len(cuts):          # cuts at (or past) the trace end
        _read_counters(mgr, out, ci)
        ci += 1
    return out


def _run_span_sampled(ct, mgr, s, e, zc_mask, zc_key, cuts, out, ci) -> int:
    """`_run_span` plus counter snapshots at the ``cuts`` that land in
    ``(s, e]`` (cuts ≤ s read the live manager directly — state is
    current there).  Returns the index of the first unconsumed cut."""
    n_cuts = len(cuts)
    while ci < n_cuts and cuts[ci] <= s:
        _read_counters(mgr, out, ci)
        ci += 1
    if e <= s:
        return ci
    hi = ci
    while hi < n_cuts and cuts[hi] <= e:
        hi += 1
    if hi == ci:                   # no cuts in this span
        _run_span(ct, mgr, s, e, zc_mask, zc_key)
        return ci
    if e - s < FAST_SPAN_MIN:
        # short span: scalar replay split at the cut points — exact
        p = s
        for j in range(ci, hi):
            c = int(cuts[j])
            _replay(ct, mgr, p, c)
            _read_counters(mgr, out, j)
            p = c
        _replay(ct, mgr, p, e)
        return hi
    pre = (mgr.wall, mgr.n_migrations, mgr.n_evictions,
           mgr.bytes_migrated, mgr.bytes_evicted)
    tab, struct, zc_pos, zc_rid = _span_phase_a(ct, mgr, s, e,
                                                zc_mask, zc_key)
    op_end = _phase_b(ct, mgr, s, e, tab, struct, zc_pos, zc_rid, zc_key)
    _sample_cuts(tab, struct, pre, op_end, cuts[ci:hi], out[ci:hi], s)
    return hi


def _sample_cuts(tab, st: "SpanStruct", pre, op_end, cuts, out, s) -> None:
    """Counter snapshots at in-span cut positions, from Phase B's wall
    trajectory and integer prefix sums over Phase A's miss/victim
    streams.  ``op_end[k]`` is the wall after relative op ``k`` — the
    same float the scalar path's accumulator holds there — and every
    count/byte column is an exact integer cumsum, so each sampled row
    is byte-identical to a live manager read at that op boundary."""
    out[:, 0] = op_end[cuts - s - 1]
    m_pos = np.asarray(st.m_pos, dtype=np.int64)
    M = len(m_pos)
    if M == 0:
        out[:, 1:] = pre[1:]
        return
    ks = np.searchsorted(m_pos, cuts, side="left")
    out[:, 1] = pre[1] + ks
    nev = np.asarray(st.nev, dtype=np.int64)
    vend = np.concatenate(([0], np.cumsum(nev)))
    if st.m_nbytes is not None:
        m_nb = np.abs(np.asarray(st.m_nbytes, dtype=np.int64))
    else:
        m_nb = tab["size_arr"][np.asarray(st.m_rid, dtype=np.int64)]
    cmb = np.concatenate(([0], np.cumsum(m_nb)))
    out[:, 3] = pre[3] + cmb[ks]
    if len(st.victims):
        v_sz = tab["size_arr"][np.asarray(st.victims, dtype=np.int64)]
        cvb = np.concatenate(([0], np.cumsum(v_sz)))
    else:
        cvb = np.zeros(1, dtype=np.int64)
    ev = vend[ks]
    ev_bytes = cvb[ev]
    if st.pv_counts is not None:
        pvc_cum = np.concatenate(
            ([0], np.cumsum(np.asarray(st.pv_counts, dtype=np.int64))))
        if st.pv_victims:
            pv_sz = tab["size_arr"][np.asarray(st.pv_victims,
                                               dtype=np.int64)]
            pvb_cum = np.concatenate(([0], np.cumsum(pv_sz)))
        else:
            pvb_cum = np.zeros(1, dtype=np.int64)
        ev = ev + pvc_cum[ks]
        ev_bytes = ev_bytes + pvb_cum[pvc_cum[ks]]
    out[:, 2] = pre[2] + ev
    out[:, 4] = pre[4] + ev_bytes


def _exec_boundary(ct: CompiledTrace, mgr, k: int) -> None:
    code = ct.codes[k]
    rid = int(ct.rids[k])
    if code == OP_WRITEBACK:
        mgr.writeback(rid)
    elif code == OP_PIN:
        mgr.pin(rid)
    elif code == OP_UNPIN:
        mgr.unpin(rid)
    elif code == OP_SPILL:
        need = int(ct.hints[k])
        overlap = float(ct.fargs[k])
        while mgr.free < need and mgr.spill_oldest(overlap=overlap) \
                is not None:
            pass
    else:
        raise ValueError(f"opcode {int(code)} is not a boundary op")


def _replay(ct: CompiledTrace, mgr, s: int, e: int) -> None:
    """Scalar fallback: dispatch ops one by one through the manager."""
    codes = ct.codes
    rids = ct.rids
    for k in range(s, e):
        code = codes[k]
        if code == OP_TOUCH:
            mgr.touch(int(rids[k]), concurrency=int(ct.concs[k]),
                      page_hint=int(ct.hints[k]))
        elif code == OP_COMPUTE:
            mgr.advance(float(ct.fargs[k]))
        else:
            _exec_boundary(ct, mgr, k)


@dataclasses.dataclass
class SpanStruct:
    """Phase-A output for one span: the structural facts Phase B turns
    into float accounting."""

    m_pos: list | np.ndarray        # op index per miss
    m_rid: list | np.ndarray        # rid per miss
    nev: np.ndarray                 # blocking evictions per miss
    victims: list                   # blocking victims, flattened in order
    lastpos: dict | None = None     # LRU: rid -> last touch op index
    # per-miss migrated bytes, None = full range sizes; a NEGATIVE entry
    # is a deferred granule migration (the range did not become resident)
    m_nbytes: list | None = None
    pv_counts: list | None = None   # pre-evictions per miss; None = none
    pv_victims: list | None = None  # pre-eviction victims, flattened


def _run_span(ct: CompiledTrace, mgr, s: int, e: int,
              zc_mask, zc_key) -> None:
    if e <= s:
        return
    if e - s < FAST_SPAN_MIN:
        _replay(ct, mgr, s, e)
        return
    tab, struct, zc_pos, zc_rid = _span_phase_a(ct, mgr, s, e,
                                                zc_mask, zc_key)
    _phase_b(ct, mgr, s, e, tab, struct, zc_pos, zc_rid, zc_key)


def _span_phase_a(ct: CompiledTrace, mgr, s: int, e: int, zc_mask, zc_key):
    """Phase-A dispatch for one vectorisable span: resolve the span's
    hit/miss/victim structure (mutating residency/policy state) and hand
    back everything Phase B needs.  Returns (tab, struct, zc_pos, zc_rid).
    """
    _, _, tpos_np, trid_np, uniq, zc_pos, zc_rid = \
        ct.span(s, e, zc_mask, zc_key)
    tab = _tables(mgr.space, mgr.params)
    defer_on = bool(mgr.defer_granule) and mgr.defer_k > 0
    pw = mgr.previct_watermark
    struct = None
    if type(mgr.policy) is LRF and len(trid_np) and not defer_on:
        # vectorised LRF fast paths.  The span's unique-rid structure is
        # static per (s, e, zc_key), so it memoises in the span cache;
        # only the residency probe runs per execution.
        ukey = ("uniq", s, e, zc_key)
        uc = ct.span_cache.get(ukey)
        if uc is None:
            u, first_idx = np.unique(trid_np, return_index=True)
            uc = (u, u.tolist(), first_idx)
            ct.span_cache[ukey] = uc
        u, u_list, first_idx = uc
        resident = mgr.resident
        mask = None
        if len(u_list) > 256:
            # wide spans: a residency bitmap beats per-rid set probes
            mask = np.zeros(tab["n_ranges"], dtype=bool)
            if resident:
                mask[np.fromiter(resident, dtype=np.int64,
                                 count=len(resident))] = True
            miss_u = ~mask[u]
        else:
            miss_u = np.fromiter((r not in resident for r in u_list),
                                 dtype=bool, count=len(u_list))
        need = int(tab["size_arr"][u[miss_u]].sum())
        if need <= mgr.free and (
                pw <= 0.0 or need == 0
                or mgr.free - need >= pw * mgr.capacity):
            # no eviction possible — and, under a pre-eviction watermark,
            # free stays above the watermark at every prefix (free only
            # shrinks, monotonically, to its final value), so no previcts
            # fire either: misses are exactly the first touches of the
            # non-resident ranges, hits are LRF no-ops.  Sound with pinned
            # ranges too: pinned ⊆ resident (pin migrates first; every
            # eviction path picks victims from the policy queue, which
            # excludes pinned), so no miss rid is ever pinned and the
            # queue inserts match `_phase_a_lrf` exactly.
            struct = _phase_a_lrf_noevict(
                mgr, tpos_np, trid_np, first_idx[miss_u], need)
        elif pw <= 0.0 and not mgr.pinned:
            if mask is None:
                mask = np.zeros(tab["n_ranges"], dtype=bool)
                if resident:
                    mask[np.fromiter(resident, dtype=np.int64,
                                     count=len(resident))] = True
            # eviction-pressure span: solve the FIFO dynamics in closed
            # form under the every-touch-misses hypothesis and validate it
            # vectorised (holds for linear streaming AND full thrash);
            # falls back to the sequential loop on mixed hit/miss spans
            prev = None
            if not uniq:
                prev = ct.span_cache.get(("prev", s, e, zc_key))
                if prev is None:
                    order = np.argsort(trid_np, kind="stable")
                    srid = trid_np[order]
                    prev = np.full(len(trid_np), -1, dtype=np.int64)
                    same = srid[1:] == srid[:-1]
                    prev[order[1:][same]] = order[:-1][same]
                    ct.span_cache[("prev", s, e, zc_key)] = prev
            struct = _phase_a_lrf_streaming(
                mgr, tpos_np, ct.span_lists(s, e, zc_key)[1], trid_np,
                tab, mask, prev)
        elif pw <= 0.0:
            # pinned span under eviction pressure: sorted-array sweep
            # over the miss stream (closed-form FIFO eviction counts via
            # cumsum + searchsorted); returns None — falling through to
            # the sequential heap walk — when a victim re-touch or
            # this-span eviction demand breaks its preconditions
            struct = _phase_a_lrf_sweep(
                mgr, tpos_np, u, first_idx, miss_u, tab)
    if struct is None:
        # the sequential passes mutate live state as they go; snapshot so
        # a mid-span device-full error can be replayed through the scalar
        # path, which raises with fully consistent partial manager state
        tpos, trid = ct.span_lists(s, e, zc_key)
        snap = _snapshot(mgr)
        try:
            if defer_on or pw > 0.0:
                struct = _phase_a_var(mgr, tpos, trid, tab)
            elif type(mgr.policy) is LRF:
                if mgr.pinned:
                    # pinned span under eviction pressure (the no-evict
                    # fast path above handles the hit-dominated steady
                    # state); the heap variant skips hit runs instead of
                    # walking every touch
                    struct = _phase_a_lrf_runs(ct, mgr, s, e, zc_key,
                                               tpos_np, trid_np, tab)
                else:
                    struct = _phase_a_lrf(mgr, tpos, trid, tab)
            else:
                struct = _phase_a_generic(mgr, tpos, trid, tab)
        except RuntimeError:
            _restore(mgr, snap)
            _replay(ct, mgr, s, e)    # re-raises at the same op, scalar
            raise                     # unreachable: replay must raise too
    return tab, struct, zc_pos, zc_rid


# ------------------------------------------------------ phase A — structure

def _snapshot(mgr):
    policy = mgr.policy
    q = getattr(policy, "_q", None)
    if q is not None:
        pstate = ("q", list(q.items()))
    elif getattr(policy, "_order", None) is not None:
        pstate = ("order", list(policy._order.items()))
    elif getattr(policy, "_set", None) is not None:
        pstate = ("set", list(policy._set), policy._rng.getstate())
    else:
        import copy
        pstate = ("deep", copy.deepcopy(policy))
    return set(mgr.resident), mgr.free, dict(mgr._defer_count), pstate


def _restore(mgr, snap):
    resident, free, defer_count, pstate = snap
    mgr.resident.clear()
    mgr.resident.update(resident)
    mgr.free = free
    mgr._defer_count.clear()
    mgr._defer_count.update(defer_count)
    policy = mgr.policy
    if pstate[0] == "q":
        policy._q.clear()
        policy._q.update(pstate[1])
    elif pstate[0] == "order":
        policy._order.clear()
        policy._order.update(pstate[1])
    elif pstate[0] == "set":
        policy._set.clear()
        policy._set.update((r, None) for r in pstate[1])
        policy._rng.setstate(pstate[2])
    else:
        mgr.policy = pstate[1]


def _phase_a_lrf_noevict(mgr, tpos_np, trid_np, miss_first_idx, need):
    """Vectorised Phase A for LRF spans that cannot evict (the touched
    working set fits in free bytes): misses are the first occurrences of
    non-resident rids, in touch order; every other touch is a hit, which
    LRF ignores by construction."""
    idx = np.sort(miss_first_idx)
    m_rid = trid_np[idx]
    m_pos = tpos_np[idx]
    rid_list = m_rid.tolist()
    mgr.free -= need
    mgr.resident.update(rid_list)
    q = mgr.policy._q
    for rid in rid_list:
        q[rid] = 0.0
    return SpanStruct(m_pos, m_rid, np.zeros(len(idx), dtype=np.int64), [])


def _phase_a_lrf_streaming(mgr, tpos_np, trid, trid_np, tab, mask, prev):
    """Closed-form Phase A for all-miss spans under LRF.

    Hypothesis: every touch in the span is a miss.  LRF then degenerates
    to FIFO, the victim stream is exactly [current queue] + [migrated
    ranges, in touch order], and each migration's eviction count falls out
    of one ``searchsorted`` over the two byte cumsums.  The hypothesis is
    then validated vectorised — every re-touch (``prev``) and every
    initially-resident touch must have been evicted before its hit check —
    covering both linear streaming (Category I) and full cyclic thrash
    (Categories II/III at high DOS).  Returns None (no state mutated) when
    the span actually contains hits or would exhaust evictable ranges.
    """
    q = mgr.policy._q
    sizes_arr = tab["size_arr"]
    n = len(trid_np)
    n_q0 = len(q)
    if n_q0:
        cand = np.concatenate([np.fromiter(q, dtype=np.int64, count=n_q0),
                               trid_np])
    else:
        cand = trid_np
    cv = np.concatenate(([0], np.cumsum(sizes_arr[cand])))
    cs = np.cumsum(sizes_arr[trid_np])
    e_arr = np.searchsorted(cv, cs - mgr.free, side="left")
    if (e_arr > n_q0 + np.arange(n)).any():
        return None        # would need to evict not-yet-migrated ranges
    # eviction frontier *before* each touch's hit check
    e_prev = np.empty(n, dtype=np.int64)
    e_prev[0] = 0
    e_prev[1:] = e_arr[:-1]
    if prev is not None:
        nf = prev >= 0
        if nf.any() and (n_q0 + prev[nf] >= e_prev[nf]).any():
            return None    # a re-touched range would still be resident
    if n_q0:
        r0 = mask[trid_np]
        if prev is not None:
            r0 &= prev < 0
        ks = np.nonzero(r0)[0]
        if len(ks):
            q0pos = {rid: i for i, rid in enumerate(q)}
            for k, e in zip(ks.tolist(), e_prev[ks].tolist()):
                p = q0pos.get(trid[k])
                if p is None or p >= e:
                    return None   # an initially-resident touch would hit

    n_evt = int(e_arr[-1])
    victims = cand[:n_evt].tolist()
    nev = e_arr.copy()
    nev[1:] -= e_arr[:-1]

    # state update: the survivors are exactly cand[n_evt:], in order;
    # surviving pre-existing queue entries keep their timestamps
    mgr.free = int(mgr.free + int(cv[n_evt]) - int(cs[-1]))
    old_items = list(q.items())[n_evt:] if n_evt < n_q0 else []
    q.clear()
    for rid, t in old_items:
        q[rid] = t
    for rid in trid[max(n_evt - n_q0, 0):]:
        q[rid] = 0.0
    resident = mgr.resident
    resident.clear()
    resident.update(q)
    return SpanStruct(tpos_np, trid_np, nev, victims)


def _phase_a_lrf(mgr, tpos, trid, tab):
    """Integer-only hit/miss/victim resolution for the default LRF policy.

    Operates directly on the live policy queue (an OrderedDict whose key
    order IS the FIFO victim order); float timestamps are patched in
    phase B.  A miss rid is never queued (queue ⊆ resident), so insertion
    is a plain assignment.
    """
    q = mgr.policy._q
    popitem = q.popitem
    resident = mgr.resident
    res_add = resident.add
    res_disc = resident.discard
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    mp = miss_pos.append
    ma = miss_rid.append
    na = vends.append
    va = victims.append
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            continue
        nbytes = sizes[rid]
        while free < nbytes:
            if not q:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim, _ = popitem(False)
            res_disc(victim)
            free += sizes[victim]
            va(victim)
            n_victims += 1
        free -= nbytes
        res_add(rid)
        if rid not in pinned:
            q[rid] = 0.0
        mp(tpos[i])
        ma(rid)
        na(n_victims)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return SpanStruct(miss_pos, miss_rid, nev, victims)


def _phase_a_lrf_sweep(mgr, tpos_np, u, first_idx, miss_u, tab):
    """Sorted-array Phase A for pinned LRF spans under eviction pressure.

    When no evicted victim is touched anywhere in the span, the miss
    stream is exactly the first touches of the non-resident rids in
    ordinal order, and the victim stream is a prefix of the policy
    queue's FIFO order — so the per-miss eviction counts solve in closed
    form: with ``D[j]`` the cumulative miss bytes beyond the initial
    free pool and ``Vcum`` the queue's cumulative victim sizes, miss
    ``j`` needs the smallest ``k`` with ``Vcum[k-1] >= D[j]`` victims
    (`searchsorted`), which reproduces the scalar ``while free < nbytes``
    loop integer-exactly.  Sound with pinned ranges for the same reason
    as the no-evict path: pinned ⊆ resident, so no miss rid is pinned
    and every queue insert matches `_phase_a_lrf`.

    Returns None — callers fall through to the heap walk — when the
    span's own eviction demand reaches past the initial queue (a rid
    missed in-span would become a victim) or any victim has an in-span
    touch (its eviction would turn a later hit into a miss).
    """
    size_arr = tab["size_arr"]
    fi = first_idx[miss_u]
    order = np.argsort(fi)
    fi = fi[order]
    mrid = u[miss_u][order]
    if not len(mrid):
        return SpanStruct([], [], _EMPTY_I, [])
    D = np.cumsum(size_arr[mrid]) - mgr.free
    q = mgr.policy._q
    L = len(q)
    if int(D[-1]) > 0:
        if L == 0:
            return None                      # device full: heap path raises
        vq = np.fromiter(q.keys(), dtype=np.int64, count=L)
        Vcum = np.cumsum(size_arr[vq])
        kl = int(np.searchsorted(Vcum, D[-1], side="left")) + 1
        if kl > L:
            return None                      # demand reaches this span's misses
        # victim re-touch check: u is sorted, so one searchsorted probe
        vk = vq[:kl]
        hit = np.searchsorted(u, vk)
        if np.any((hit < len(u)) & (u[np.minimum(hit, len(u) - 1)] == vk)):
            return None
        K = np.where(D > 0, np.searchsorted(Vcum, D, side="left") + 1, 0)
        victims = vk.tolist()
        freed = int(Vcum[kl - 1])
    else:
        K = np.zeros(len(mrid), dtype=np.int64)
        victims = []
        freed = 0
    resident = mgr.resident
    for v in victims:
        del q[v]
    resident.difference_update(victims)
    mlist = mrid.tolist()
    resident.update(mlist)
    for rid in mlist:
        q[rid] = 0.0
    mgr.free = freed - int(D[-1])
    nev = np.diff(K, prepend=0)
    return SpanStruct(tpos_np[fi].tolist(), mlist, nev, victims)


def _phase_a_lrf_runs(ct, mgr, s, e, zc_key, tpos_np, trid_np, tab):
    """Heap-of-next-touches Phase A for LRF spans with pinned ranges.

    `_phase_a_lrf` walks every touch; on scheduler spans with pinned hot
    leaves almost all touches are hits, and an LRF hit is a no-op.  This
    variant visits only the misses: a min-heap keyed by span-local touch
    ordinal holds, for each non-resident rid with a future touch, its
    next touch.  A pop is always a miss (rids become resident only via
    pops, victims are re-pushed at their next future touch), and pops are
    strictly increasing in ordinal, so the miss/victim stream — and every
    state mutation — is identical to the sequential walk.
    """
    n = len(trid_np)
    if n == 0:
        return SpanStruct([], [], _EMPTY_I, [])
    key = ("runs", s, e, zc_key)
    positions = ct.span_cache.get(key)
    if positions is None:         # rid -> ascending touch ordinals
        order = np.argsort(trid_np, kind="stable")
        srid = trid_np[order]
        bounds = np.concatenate(
            ([0], np.nonzero(srid[1:] != srid[:-1])[0] + 1, [n]))
        positions = {int(srid[a]): order[a:b]
                     for a, b in zip(bounds[:-1], bounds[1:])}
        ct.span_cache[key] = positions
    resident = mgr.resident
    heap = [(int(fi[0]), rid) for rid, fi in positions.items()
            if rid not in resident]
    heapq.heapify(heap)
    q = mgr.policy._q
    popitem = q.popitem
    res_add = resident.add
    res_disc = resident.discard
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    n_victims = 0
    while heap:
        i, rid = heapq.heappop(heap)
        nbytes = sizes[rid]
        while free < nbytes:
            if not q:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim, _ = popitem(False)
            res_disc(victim)
            free += sizes[victim]
            victims.append(victim)
            n_victims += 1
            vpos = positions.get(victim)
            if vpos is not None:
                k = int(np.searchsorted(vpos, i, side="right"))
                if k < len(vpos):
                    heapq.heappush(heap, (int(vpos[k]), victim))
        free -= nbytes
        res_add(rid)
        if rid not in pinned:
            q[rid] = 0.0
        miss_pos.append(int(tpos_np[i]))
        miss_rid.append(rid)
        vends.append(n_victims)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return SpanStruct(miss_pos, miss_rid, nev, victims)


def _phase_a_generic(mgr, tpos, trid, tab):
    """Policy-agnostic structure pass: same call sequence as the scalar path
    (victim → remove → insert), so stateful policies (CLOCK second-chance
    sweeps, RANDOM rng draws) stay in lockstep."""
    policy = mgr.policy
    on_touch = policy.on_touch
    track = isinstance(policy, LRU)
    lastpos: dict[int, int] = {}
    resident = mgr.resident
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            on_touch(rid, 0.0)
            if track:
                lastpos[rid] = tpos[i]
            continue
        nbytes = sizes[rid]
        while free < nbytes:
            if len(policy) == 0:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim = policy.victim()
            policy.remove(victim)
            resident.discard(victim)
            free += sizes[victim]
            victims.append(victim)
            n_victims += 1
        free -= nbytes
        resident.add(rid)
        if rid not in pinned:
            policy.insert(rid, 0.0)
            if track:
                lastpos[rid] = tpos[i]
        miss_pos.append(tpos[i])
        miss_rid.append(rid)
        vends.append(n_victims)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return SpanStruct(miss_pos, miss_rid, nev, victims,
                      lastpos if track else None)


def _phase_a_var(mgr, tpos, trid, tab):
    """Sequential Phase A for the §4.2 driver variants: deferred
    granularity (the first ``defer_k - 1`` faults on a range migrate only
    a granule and leave it non-resident) and background pre-eviction below
    the free-space watermark (victims drained off the critical path after
    each migration).  LRF drives its queue directly; other policies go
    through the scalar call sequence so stateful policies stay in
    lockstep."""
    if type(mgr.policy) is LRF:
        return _phase_a_var_lrf(mgr, tpos, trid, tab)
    return _phase_a_var_generic(mgr, tpos, trid, tab)


def _phase_a_var_lrf(mgr, tpos, trid, tab):
    q = mgr.policy._q
    popitem = q.popitem
    resident = mgr.resident
    res_add = resident.add
    res_disc = resident.discard
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    defer_g = mgr.defer_granule or 0
    defer_k = mgr.defer_k
    defer_on = bool(defer_g) and defer_k > 0
    dcount = mgr._defer_count
    dget = dcount.get
    pw_on = mgr.previct_watermark > 0.0
    target = mgr.previct_watermark * mgr.capacity
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    m_nb: list[int] = []
    vend_pairs: list[tuple[int, int]] = []   # (miss idx, cum victims)
    victims: list[int] = []
    pv_counts: list[int] = []
    pv_victims: list[int] = []
    mp = miss_pos.append
    ma = miss_rid.append
    nba = m_nb.append
    vp = vend_pairs.append
    va = victims.append
    pca = pv_counts.append
    pva = pv_victims.append
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            continue
        nbytes = sizes[rid]
        full = True
        if defer_on:
            c = dget(rid, 0) + 1
            dcount[rid] = c
            if c < defer_k:
                if defer_g < nbytes:
                    nbytes = defer_g
                full = False
            else:
                dcount.pop(rid, None)
        v0 = n_victims
        while free < nbytes:
            if not q:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim, _ = popitem(False)
            res_disc(victim)
            free += sizes[victim]
            va(victim)
            n_victims += 1
        if full:
            free -= nbytes
            res_add(rid)
            if rid not in pinned:
                q[rid] = 0.0
            nba(nbytes)
        else:
            nba(-nbytes)        # deferred granule: not resident
        mp(tpos[i])
        ma(rid)
        if n_victims != v0:
            vp((len(miss_pos) - 1, n_victims))
        if pw_on:
            pvn = 0
            while free < target and q:
                victim, _ = popitem(False)
                res_disc(victim)
                free += sizes[victim]
                pva(victim)
                pvn += 1
            pca(pvn)
    mgr.free = free
    nev = _nev_from_pairs(vend_pairs, len(miss_pos))
    return SpanStruct(miss_pos, miss_rid, nev, victims, None,
                      m_nb if defer_on else None,
                      pv_counts if pw_on else None,
                      pv_victims if pw_on else None)


def _phase_a_var_generic(mgr, tpos, trid, tab):
    policy = mgr.policy
    on_touch = policy.on_touch
    track = isinstance(policy, LRU)
    lastpos: dict[int, int] = {}
    resident = mgr.resident
    pinned = mgr.pinned
    sizes = tab["sizes"]
    free = mgr.free
    defer_g = mgr.defer_granule or 0
    defer_k = mgr.defer_k
    defer_on = bool(defer_g) and defer_k > 0
    dcount = mgr._defer_count
    pw_on = mgr.previct_watermark > 0.0
    target = mgr.previct_watermark * mgr.capacity
    miss_pos: list[int] = []
    miss_rid: list[int] = []
    m_nb: list[int] = []
    vends: list[int] = []
    victims: list[int] = []
    pv_counts: list[int] = []
    pv_victims: list[int] = []
    n_victims = 0
    for i, rid in enumerate(trid):
        if rid in resident:
            on_touch(rid, 0.0)
            if track:
                lastpos[rid] = tpos[i]
            continue
        nbytes = sizes[rid]
        full = True
        if defer_on:
            c = dcount.get(rid, 0) + 1
            dcount[rid] = c
            if c < defer_k:
                if defer_g < nbytes:
                    nbytes = defer_g
                full = False
            else:
                dcount.pop(rid, None)
        while free < nbytes:
            if len(policy) == 0:
                raise RuntimeError(
                    "SVM: device full of pinned/unevictable ranges "
                    f"(free={free}, need more; pinned={len(pinned)})")
            victim = policy.victim()
            policy.remove(victim)
            resident.discard(victim)
            free += sizes[victim]
            victims.append(victim)
            n_victims += 1
        if full:
            free -= nbytes
            resident.add(rid)
            if rid not in pinned:
                policy.insert(rid, 0.0)
                if track:
                    lastpos[rid] = tpos[i]
        miss_pos.append(tpos[i])
        miss_rid.append(rid)
        m_nb.append(nbytes if full else -nbytes)
        vends.append(n_victims)
        if pw_on:
            pvn = 0
            while free < target and len(policy) > 0:
                victim = policy.victim()
                policy.remove(victim)
                resident.discard(victim)
                free += sizes[victim]
                pv_victims.append(victim)
                pvn += 1
            pv_counts.append(pvn)
    mgr.free = free
    nev = np.diff(np.array(vends, dtype=np.int64), prepend=0)
    return SpanStruct(miss_pos, miss_rid, nev, victims,
                      lastpos if track else None,
                      m_nb if defer_on else None,
                      pv_counts if pw_on else None,
                      pv_victims if pw_on else None)


def _nev_from_pairs(vend_pairs, n_miss):
    """Dense per-miss blocking-eviction counts from the sparse
    (miss index, cumulative victims) pairs recorded in Phase A."""
    nev = np.zeros(n_miss, dtype=np.int64)
    if vend_pairs:
        idxs = [p[0] for p in vend_pairs]
        cums = np.array([p[1] for p in vend_pairs], dtype=np.int64)
        nev[idxs] = np.diff(cums, prepend=0)
    return nev


# ----------------------------------------------------- phase B — accounting

def _fold_evictions(acc, m_nev, starts, ec_v) -> None:
    """Fold each miss's blocking-eviction costs into its ``acc`` entry,
    preserving the scalar path's per-eviction left-to-right add order.

    Sweeps the eviction *ordinal* (all first evictions, then all
    seconds, ...) so each accumulator sees the same add chain as the
    scalar `+=` loop, vectorised across misses — one pass total for the
    dominant single-eviction case.  When only a few deep eviction chains
    remain (a capacity shrink blocking one miss on many victims), each
    survivor finishes with one exact sequential ``np.cumsum`` fold seeded
    from its current value instead of one vector pass per remaining
    ordinal — bit-identical, O(chains) numpy calls instead of
    O(max depth)."""
    if not len(ec_v):
        return
    sel = np.nonzero(m_nev > 0)[0]
    nmax = int(m_nev.max())
    j = 0
    while j < nmax:
        if j:
            sel = sel[m_nev[sel] > j]
            if not len(sel):
                return
            if len(sel) * 8 <= nmax - j:
                for i in sel.tolist():
                    st = int(starts[i]) + j
                    en = st + int(m_nev[i]) - j
                    acc[i] = np.cumsum(
                        np.concatenate(([acc[i]], ec_v[st:en])))[-1]
                return
        acc[sel] += ec_v[starts[sel] + j]
        j += 1


def _phase_b(ct, mgr, s, e, tab, st: SpanStruct, zc_pos, zc_rid,
             zc_key=None) -> np.ndarray:
    """Float accounting for one span.  Returns the per-op wall trajectory
    ``op_end`` (``op_end[k]`` = mgr.wall after relative op ``k``) so the
    fused-round path can sample mid-span cut points exactly."""
    if (len(zc_pos) == 0 and st.m_nbytes is None
            and (st.pv_counts is None or not any(st.pv_counts))):
        return _phase_b_fast(ct, mgr, s, e, tab, st.m_pos, st.m_rid,
                             st.nev, st.victims, st.lastpos)
    return _phase_b_general(ct, mgr, s, e, tab, st, zc_pos, zc_rid, zc_key)


def _phase_b_fast(ct, mgr, s, e, tab, miss_pos, miss_rid, nev, victims,
                  lastpos):
    """Vectorised, bit-exact float accounting for one plain span (full-range
    migrations, no pre-evictions, no zero-copy touches).

    Every accumulator fold is seeded with the manager's current value and
    realised with ``np.cumsum`` (an exact sequential fold), so the result
    equals the scalar path's `+=` chain bit for bit.
    """
    fargs = ct.fargs[s:e]
    M = len(miss_pos)
    cost = mgr.cost
    if M == 0:
        traj = np.cumsum(np.concatenate(([mgr.wall], fargs)))
        mgr.wall = float(traj[-1])
        mgr.compute_time = float(
            np.cumsum(np.concatenate(([mgr.compute_time], fargs)))[-1])
        if lastpos:
            q = getattr(mgr.policy, "_q", None)
            if q is not None:
                for rid, k in lastpos.items():
                    if rid in q:
                        q[rid] = float(traj[k - s + 1])
        return traj[1:]

    m_pos = np.asarray(miss_pos, dtype=np.int64)
    m_rid = np.asarray(miss_rid, dtype=np.int64)
    m_nev = np.asarray(nev, dtype=np.int64)
    v_rid = np.asarray(victims, dtype=np.int64)
    miss_rid_l = miss_rid.tolist() if isinstance(miss_rid, np.ndarray) \
        else miss_rid
    sizeidx = tab["sizeidx"]
    terms = tab["terms"][sizeidx[m_rid]]            # (M, 5)
    t1, t2, t3, t4, t5 = terms.T
    ec_v = tab["ecs"][sizeidx[v_rid]] if len(v_rid) else np.zeros(0)

    # fold eviction costs into each migration's alloc term in the scalar
    # path's per-eviction add order (`_fold_evictions`)
    alloc = t3.copy()
    ends = np.cumsum(m_nev)
    starts = ends - m_nev
    _fold_evictions(alloc, m_nev, starts, ec_v)
    total = (((t1 + t2) + alloc) + t4) + t5

    if mgr.parallel_evict:
        # §4.2 parallel implementation: overlap evictions with the blocked
        # migration (plus lock/rollback overhead)
        base = (((t1 + t2) + t3) + t4) + t5
        evw = np.zeros(M)
        _fold_evictions(evw, m_nev, starts, ec_v)
        total = np.where(m_nev > 0, np.maximum(base, evw) + 5e-6, base)

    # wall trajectory over the whole span (compute ops interleave misses;
    # hit ops contribute +0.0, which is add-identity for finite wall)
    deltas = fargs.copy()
    rel_pos = m_pos - s
    deltas[rel_pos] = total
    traj = np.cumsum(np.concatenate(([mgr.wall], deltas)))
    mgr.wall = float(traj[-1])
    mgr.compute_time = float(
        np.cumsum(np.concatenate(([mgr.compute_time], fargs)))[-1])

    # five-term cost ledger: one stacked exact fold, seeded with the
    # current accumulator values
    ledger = np.empty((M + 1, 5))
    ledger[0] = (cost.cpu_unmap, cost.sdma_setup, cost.alloc,
                 cost.cpu_update, cost.misc)
    ledger[1:, 0] = t1
    ledger[1:, 1] = t2
    ledger[1:, 2] = alloc
    ledger[1:, 3] = t4
    ledger[1:, 4] = t5
    (cost.cpu_unmap, cost.sdma_setup, cost.alloc, cost.cpu_update,
     cost.misc) = np.cumsum(ledger, axis=0)[-1].tolist()
    if len(ec_v):
        mgr.evict_cost_total = float(
            np.cumsum(np.concatenate(([mgr.evict_cost_total], ec_v)))[-1])

    # counters
    nmig0 = mgr.n_migrations
    mgr.n_migrations = nmig0 + M
    mgr.n_evictions += len(victims)
    msz = tab["size_arr"][m_rid]
    mgr.bytes_migrated += int(msz.sum())
    if len(v_rid):
        mgr.bytes_evicted += int(tab["size_arr"][v_rid].sum())
    mgr.faults_serviceable += M

    # duplicate faults: same deterministic jitter as SVMManager._noise
    dup = _synth_dup(ct, mgr, m_pos, nmig0, M)

    # trigger pages
    conc_m = ct.concs[m_pos]
    trig = tab["pages"][m_rid] + ct.hints[m_pos]
    high = conc_m >= 32
    if high.any():
        mgr.trigger_pages.update(
            np.concatenate([trig, trig[high] + 1]).tolist())
    else:
        mgr.trigger_pages.update(trig.tolist())

    # eviction notification (push-based listeners + epoch, fired at flush)
    if victims:
        mgr.eviction_epoch += len(victims)
        if mgr._evict_listeners:
            for v in victims:
                for cb in mgr._evict_listeners:
                    cb(v)

    # patch the (write-only) policy timestamps of surviving queue entries
    q = getattr(mgr.policy, "_q", None)
    if q is not None:
        if lastpos is None:           # LRF: inserts happen only on misses
            wall_at = traj[rel_pos + 1].tolist()
            for rid, w in zip(miss_rid_l, wall_at):
                if rid in q:
                    q[rid] = w
        else:
            for rid, k in lastpos.items():
                if rid in q:
                    q[rid] = float(traj[k - s + 1])

    if mgr.profile:
        _emit_profile(ct, mgr, s, tab, traj, m_pos, miss_rid_l, starts, ends,
                      victims, dup, trig)
    return traj[1:]


def _synth_dup(ct, mgr, m_pos, nmig0, M):
    """Duplicate-fault synthesis: same deterministic jitter stream as
    `SVMManager._noise`, vectorised over the span's migrations."""
    conc_m = ct.concs[m_pos]
    kk = np.arange(nmig0 + 1, nmig0 + M + 1, dtype=np.uint64)
    h = (kk * np.uint64(2654435761)
         + np.uint64((mgr._seed * 97) & 0xFFFFFFFF)) & np.uint64(0xFFFFFFFF)
    noise = 0.8 + 0.4 * (h.astype(np.float64) / float(0xFFFFFFFF))
    dup = (conc_m * noise).astype(np.int64) - 1
    np.clip(dup, 0, None, out=dup)
    mgr.faults_duplicate += int(dup.sum())
    return dup


def _phase_b_general(ct, mgr, s, e, tab, st: SpanStruct,
                     zc_pos, zc_rid, zc_key=None) -> None:
    """Bit-exact accounting for variant spans: deferred-granularity
    migrations (per-miss byte counts, non-resident granule copies),
    background pre-evictions (their `alloc`/wall contributions land at the
    exact scalar add positions via an expanded trajectory), and zero-copy
    touches (remote-access wall deltas + `zc` events in-span)."""
    fargs = ct.fargs[s:e]
    n_span = e - s
    cost = mgr.cost
    M = len(st.m_pos)
    Z = len(zc_pos)
    pvc = (np.asarray(st.pv_counts, dtype=np.int64)
           if st.pv_counts is not None else np.zeros(M, dtype=np.int64))
    P = int(pvc.sum()) if M else 0

    deltas = fargs.copy()
    if Z:
        zc_sizes = tab["size_arr"][zc_rid]
        zkey = ("zcc", int(zc_pos[0]), int(zc_pos[-1]), Z, zc_key,
                mgr.params)
        zcc = ct.span_cache.get(zkey)
        if zcc is None:       # pure function of the zc touch stream
            zcc = _zc_costs(tab, zc_sizes, mgr.params)
            ct.span_cache[zkey] = zcc
        deltas[zc_pos - s] = zcc

    if M:
        m_pos = np.asarray(st.m_pos, dtype=np.int64)
        m_rid = np.asarray(st.m_rid, dtype=np.int64)
        m_nev = np.asarray(st.nev, dtype=np.int64)
        v_rid = np.asarray(st.victims, dtype=np.int64)
        m_rel = m_pos - s
        sizeidx = tab["sizeidx"]
        if st.m_nbytes is None:
            m_nb = tab["size_arr"][m_rid]
            res_mask = None
            terms = tab["terms"][sizeidx[m_rid]]
        else:
            m_nb = np.asarray(st.m_nbytes, dtype=np.int64)
            res_mask = m_nb > 0
            np.abs(m_nb, out=m_nb)
            terms = _terms_for_sizes(tab, m_nb, mgr.params)
        t1, t2, t3, t4, t5 = terms.T
        ec_v = tab["ecs"][sizeidx[v_rid]] if len(v_rid) else np.zeros(0)

        alloc = t3.copy()
        ends = np.cumsum(m_nev)
        starts = ends - m_nev
        _fold_evictions(alloc, m_nev, starts, ec_v)
        total = (((t1 + t2) + alloc) + t4) + t5

        if mgr.parallel_evict:
            base = (((t1 + t2) + t3) + t4) + t5
            evw = np.zeros(M)
            _fold_evictions(evw, m_nev, starts, ec_v)
            total = np.where(m_nev > 0, np.maximum(base, evw) + 5e-6, base)
        deltas[m_rel] = total

    # wall trajectory: previct contributions are extra sequential adds
    # *inside* a miss op, so the trajectory is folded over an expanded
    # delta sequence and op boundaries are picked out of it
    if P:
        pv_vr = np.asarray(st.pv_victims, dtype=np.int64)
        pv_ec = tab["ecs"][tab["sizeidx"][pv_vr]]
        pv_wall = pv_ec * (1.0 - mgr.previct_overlap)
        pvc_at_op = np.zeros(n_span, dtype=np.int64)
        pvc_at_op[m_rel] = pvc
        cum_pv = np.cumsum(pvc_at_op)
        didx = np.arange(n_span) + (cum_pv - pvc_at_op)
        exp = np.zeros(n_span + P)
        exp[didx] = deltas
        miss_didx = didx[m_rel]
        pv_starts = np.cumsum(pvc) - pvc
        intra = np.arange(P) - np.repeat(pv_starts, pvc)
        pv_slots = np.repeat(miss_didx, pvc) + 1 + intra
        exp[pv_slots] = pv_wall
        traj = np.cumsum(np.concatenate(([mgr.wall], exp)))
        op_start = traj[didx]
        op_end = traj[didx + 1 + pvc_at_op]
        w_mid = traj[miss_didx + 1]
        pv_event_wall = traj[pv_slots]
    else:
        pv_ec = np.zeros(0)
        pv_vr = _EMPTY_I
        pv_event_wall = np.zeros(0)
        traj = np.cumsum(np.concatenate(([mgr.wall], deltas)))
        op_start = traj[:-1]
        op_end = traj[1:]
        w_mid = op_end[m_rel] if M else np.zeros(0)
    mgr.wall = float(traj[-1])
    mgr.compute_time = float(
        np.cumsum(np.concatenate(([mgr.compute_time], fargs)))[-1])

    if Z:
        mgr.n_zerocopy += Z
        mgr.bytes_zerocopy += int(zc_sizes.sum())

    dup = trig = None
    if M:
        # five-term ledger with previct `alloc` charges interleaved at
        # their scalar positions (zero rows elsewhere: +0.0 is add-identity
        # for the non-negative accumulators)
        miss_rows = np.arange(M) + (np.cumsum(pvc) - pvc)
        R = M + P
        ledger = np.zeros((R + 1, 5))
        ledger[0] = (cost.cpu_unmap, cost.sdma_setup, cost.alloc,
                     cost.cpu_update, cost.misc)
        ledger[miss_rows + 1, 0] = t1
        ledger[miss_rows + 1, 1] = t2
        ledger[miss_rows + 1, 2] = alloc
        ledger[miss_rows + 1, 3] = t4
        ledger[miss_rows + 1, 4] = t5
        if P:
            pv_rows = np.repeat(miss_rows, pvc) + 1 + intra
            ledger[pv_rows + 1, 2] = pv_ec
        (cost.cpu_unmap, cost.sdma_setup, cost.alloc, cost.cpu_update,
         cost.misc) = np.cumsum(ledger, axis=0)[-1].tolist()

        # evict_cost_total: per miss, blocking evictions then previcts —
        # scatter both streams into one sequence at their interleaved
        # positions (blocking ec j of miss i lands after all previcts of
        # earlier misses; previct j of miss i after miss i's blockers)
        if P == 0:
            ec_seq = ec_v
        elif len(ec_v) == 0:
            ec_seq = pv_ec
        else:
            ec_seq = np.empty(len(ec_v) + P)
            ec_seq[np.arange(len(ec_v))
                   + np.repeat(pv_starts, m_nev)] = ec_v
            ec_seq[np.arange(P) + np.repeat(ends, pvc)] = pv_ec
        if len(ec_seq):
            mgr.evict_cost_total = float(np.cumsum(
                np.concatenate(([mgr.evict_cost_total], ec_seq)))[-1])

        # counters
        nmig0 = mgr.n_migrations
        mgr.n_migrations = nmig0 + M
        mgr.n_evictions += len(st.victims) + P
        mgr.bytes_migrated += int(m_nb.sum())
        ev_bytes = 0
        if len(v_rid):
            ev_bytes += int(tab["size_arr"][v_rid].sum())
        if P:
            ev_bytes += int(tab["size_arr"][pv_vr].sum())
        mgr.bytes_evicted += ev_bytes
        mgr.faults_serviceable += M

        dup = _synth_dup(ct, mgr, m_pos, nmig0, M)

        conc_m = ct.concs[m_pos]
        trig = tab["pages"][m_rid] + ct.hints[m_pos]
        high = conc_m >= 32
        if high.any():
            mgr.trigger_pages.update(
                np.concatenate([trig, trig[high] + 1]).tolist())
        else:
            mgr.trigger_pages.update(trig.tolist())

        n_ev_total = len(st.victims) + P
        if n_ev_total:
            mgr.eviction_epoch += n_ev_total
            if mgr._evict_listeners:
                if P == 0:
                    ordered = st.victims
                elif not st.victims:
                    ordered = st.pv_victims
                else:
                    ordered = []
                    for i in range(M):
                        ordered.extend(
                            st.victims[starts[i]:ends[i]])
                        ordered.extend(
                            st.pv_victims[pv_starts[i]:pv_starts[i]
                                          + pvc[i]])
                for v in ordered:
                    for cb in mgr._evict_listeners:
                        cb(v)

    # patch the (write-only) policy timestamps of surviving queue entries
    q = getattr(mgr.policy, "_q", None)
    if q is not None:
        if st.lastpos is None:        # LRF: inserts happen only on misses
            if M:
                wm = w_mid.tolist()
                res_l = res_mask.tolist() if res_mask is not None else None
                m_rid_l = (st.m_rid.tolist()
                           if isinstance(st.m_rid, np.ndarray) else st.m_rid)
                for j, rid in enumerate(m_rid_l):
                    if res_l is not None and not res_l[j]:
                        continue      # deferred granule: never inserted
                    if rid in q:
                        q[rid] = wm[j]
        elif st.lastpos:
            pol_wall = op_end.copy()
            if M:
                pol_wall[m_rel] = w_mid
            for rid, k in st.lastpos.items():
                if rid in q:
                    q[rid] = float(pol_wall[k - s])

    if mgr.profile:
        _emit_profile_general(ct, mgr, s, tab, st, zc_pos, zc_rid,
                              op_start, op_end, w_mid, pv_event_wall,
                              dup, trig)
    return op_end


def _emit_profile(ct, mgr, s, tab, traj, m_pos, miss_rid, starts, ends,
                  victims, dup, trig):
    events = mgr.events
    density = mgr.density
    alloc_ids = tab["alloc_ids"]
    sizes = tab["sizes"]
    traj_l = traj.tolist()
    pos_l = (m_pos - s).tolist()
    starts_l = starts.tolist()
    ends_l = ends.tolist()
    dup_l = dup.tolist()
    trig_l = trig.tolist()
    for i, rid in enumerate(miss_rid):
        j = pos_l[i]
        w_before = traj_l[j]
        w_after = traj_l[j + 1]
        for vi in range(starts_l[i], ends_l[i]):
            v = victims[vi]
            events.append(Event(w_before, "evt", v, alloc_ids[v], sizes[v]))
        events.append(Event(w_after, "mig", rid, alloc_ids[rid], sizes[rid]))
        density.append(DensitySample(w_after, rid, alloc_ids[rid],
                                     1 + dup_l[i], trig_l[i]))


def _emit_profile_general(ct, mgr, s, tab, st: SpanStruct, zc_pos, zc_rid,
                          op_start, op_end, w_mid, pv_event_wall,
                          dup, trig):
    """Scalar-ordered event/density emission for variant spans: blocking
    evictions at the pre-migration wall, the migration at its mid-op wall,
    pre-evictions at their per-eviction walls, zero-copy events at their
    post-touch walls — merged in op order."""
    events = mgr.events
    density = mgr.density
    alloc_ids = tab["alloc_ids"]
    sizes = tab["sizes"]
    M = len(st.m_pos)
    victims = st.victims
    pv_victims = st.pv_victims or []
    m_rel = [p - s for p in (st.m_pos.tolist()
                             if isinstance(st.m_pos, np.ndarray)
                             else st.m_pos)]
    m_rid_l = (st.m_rid.tolist() if isinstance(st.m_rid, np.ndarray)
               else st.m_rid)
    zc_rel = (zc_pos - s).tolist()
    zc_rid_l = zc_rid.tolist()
    nev_l = st.nev.tolist() if M else []
    pvc_l = (st.pv_counts if st.pv_counts is not None else [0] * M)
    nb_l = (np.abs(np.asarray(st.m_nbytes, dtype=np.int64)).tolist()
            if st.m_nbytes is not None
            else [sizes[r] for r in m_rid_l])
    op_start_l = op_start.tolist()
    op_end_l = op_end.tolist()
    w_mid_l = w_mid.tolist() if M else []
    pv_wall_l = pv_event_wall.tolist()
    dup_l = dup.tolist() if dup is not None else []
    trig_l = trig.tolist() if trig is not None else []
    mi = zi = 0
    vcur = pvcur = 0
    while mi < M or zi < len(zc_rel):
        if zi >= len(zc_rel) or (mi < M and m_rel[mi] < zc_rel[zi]):
            p = m_rel[mi]
            rid = m_rid_l[mi]
            w0 = op_start_l[p]
            for _ in range(nev_l[mi]):
                v = victims[vcur]
                vcur += 1
                events.append(Event(w0, "evt", v, alloc_ids[v], sizes[v]))
            wm = w_mid_l[mi]
            events.append(Event(wm, "mig", rid, alloc_ids[rid], nb_l[mi]))
            density.append(DensitySample(wm, rid, alloc_ids[rid],
                                         1 + dup_l[mi], trig_l[mi]))
            for _ in range(pvc_l[mi]):
                v = pv_victims[pvcur]
                events.append(Event(pv_wall_l[pvcur], "evt", v,
                                    alloc_ids[v], sizes[v]))
                pvcur += 1
            mi += 1
        else:
            p = zc_rel[zi]
            rid = zc_rid_l[zi]
            events.append(Event(op_end_l[p], "zc", rid, alloc_ids[rid],
                                sizes[rid]))
            zi += 1
