"""Paper-figure benchmarks: one function per table/figure of Cooper et al.
ICS'24. Each returns (name, us_per_call, derived) rows; artifacts (full
curves/profiles) are written to results/bench/*.json."""

from __future__ import annotations

import json
import os
import time

from repro.core import GB, MB, AddressSpace, UVMManager, dos_sweep, simulate
from repro.core.costmodel import TERMS
from repro.core.traces import Jacobi2d, Sgemm, make_workload

CAP = 8 * GB
DOS_GRID = [50, 78, 95, 100, 109, 125, 140, 156]
ART_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _art(name: str, obj) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


# ---------------------------------------------------------------- figure 2

def fig2_ranges():
    def work():
        space = AddressSpace(48 * GB, base=175 * MB)
        for i in range(3):
            space.alloc(int(1.5 * GB), f"m{i}")
        return space

    space, us = _timed(work)
    sizes = sorted(r.size for r in space.ranges)
    derived = (f"{len(space.ranges)}ranges_min{sizes[0]//MB}MB_"
               f"max{sizes[-1]//MB}MB")
    _art("fig2_ranges", [vars(r) for r in space.ranges])
    return [("fig2_range_construction", us, derived)]


# ---------------------------------------------------------------- figure 5

def fig5_cost():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm"):
        def work(n=name):
            out = {}
            for dos in DOS_GRID:
                res = simulate(make_workload(n, int(CAP * dos / 100)), CAP,
                               profile=False)
                out[dos] = res.summary["cost_breakdown"]
            return out

        curves, us = _timed(work)
        art[name] = curves
        big = curves[156]
        total = sum(big.values())
        derived = (f"alloc_share@156={big['alloc']/total:.2f}"
                   f"_total156={total:.2f}s")
        rows.append((f"fig5_cost_{name}", us, derived))
    _art("fig5_cost_breakdown", art)
    return rows


# ---------------------------------------------------------------- figure 6

def fig6_dos():
    rows = []
    art = {}
    for name in ("stream", "conv2d", "jacobi2d", "bfs", "sgemm", "syr2k",
                 "mvt", "gesummv"):
        def work(n=name):
            return dos_sweep(lambda b: make_workload(n, b), DOS_GRID, CAP)

        sweep, us = _timed(work)
        curve = {round(r["dos"]): round(r["norm_perf"], 4) for r in sweep}
        art[name] = curve
        derived = f"perf109={curve[109]:.3f}_perf156={curve[156]:.3f}"
        rows.append((f"fig6_dos_{name}", us, derived))
    _art("fig6_dos_sweep", art)
    return rows


# ---------------------------------------------------------------- figure 7

def fig7_profiles():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm", "gesummv"):
        def work(n=name):
            return simulate(make_workload(n, int(CAP * 1.09)), CAP,
                            profile=True)

        res, us = _timed(work)
        ev = [(round(e.t, 4), e.kind, e.alloc_id) for e in res.manager.events]
        art[name] = ev[:20000]
        migs = sum(1 for e in res.manager.events if e.kind == "mig")
        evts = sum(1 for e in res.manager.events if e.kind == "evt")
        rows.append((f"fig7_profile_{name}", us, f"migs={migs}_evts={evts}"))
    _art("fig7_profiles_dos109", art)
    return rows


# ------------------------------------------------------------- figures 8/9

def fig8_9_density():
    rows = []
    art = {}
    for name in ("stream", "conv2d", "jacobi2d", "bfs", "sgemm", "syr2k",
                 "mvt", "gesummv"):
        def work(n=name):
            return simulate(make_workload(n, int(CAP * 1.09)), CAP)

        res, us = _timed(work)
        m = res.manager
        dens = [d.faults for d in m.density]
        art[name] = {
            "density_over_time": [(round(d.t, 4), d.faults)
                                  for d in m.density[:5000]],
            "mean": res.summary["mean_fault_density"],
            "serviceable_per_migration":
                res.summary["serviceable_per_migration"],
            "duplicate_share": res.summary["duplicate_share"],
        }
        derived = (f"mean={res.summary['mean_fault_density']:.0f}"
                   f"_svc/mig={res.summary['serviceable_per_migration']:.2f}")
        rows.append((f"fig8_density_{name}", us, derived))
    _art("fig8_9_fault_density", art)
    return rows


# --------------------------------------------------------------- figure 10

def fig10_thrashing():
    rows = []
    art = {}
    for name in ("stream", "conv2d", "jacobi2d", "sgemm", "syr2k", "mvt",
                 "gesummv", "bfs"):
        def work(n=name):
            return dos_sweep(lambda b: make_workload(n, b), DOS_GRID, CAP)

        sweep, us = _timed(work)
        art[name] = {round(r["dos"]): {"e2m": round(r["evict_to_mig"], 3),
                                       "migs": r["migrations"]}
                     for r in sweep}
        d = art[name]
        derived = (f"e2m156={d[156]['e2m']:.2f}"
                   f"_miggrowth={d[156]['migs']/max(d[78]['migs'],1):.1f}x")
        rows.append((f"fig10_thrash_{name}", us, derived))
    _art("fig10_thrashing", art)
    return rows


# ---------------------------------------------------------- figures 11-13

def fig11_13_svm_aware():
    rows = []
    art = {}
    # extend past the measured grid: the paper notes SGEMM-svm-aware stays
    # viable to DOS ~ 300 while naive collapses (orders of magnitude)
    grid = DOS_GRID + [220, 280]
    for cls, label in ((Jacobi2d, "jacobi2d"), (Sgemm, "sgemm")):
        def work(c=cls):
            naive = dos_sweep(lambda b: c(b), grid, CAP)
            aware = dos_sweep(lambda b: c(b, svm_aware=True), grid, CAP)
            return naive, aware

        (naive, aware), us = _timed(work)
        nv = {round(r["dos"]): r["norm_perf"] for r in naive}
        aw = {round(r["dos"]): r["norm_perf"] for r in aware}
        art[label] = {"naive": nv, "aware": aw}
        derived = (f"speedup109={aw[109]/max(nv[109],1e-9):.2f}x"
                   f"_speedup156={aw[156]/max(nv[156],1e-9):.2f}x"
                   f"_speedup280={aw[280]/max(nv[280],1e-9):.0f}x")
        rows.append((f"fig11_13_svm_aware_{label}", us, derived))
    _art("fig11_13_svm_aware", art)
    return rows


# ----------------------------------------------------------------- table 1

def table1_svm_vs_uvm():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm", "gesummv"):
        def work(n=name):
            kw = {}
            if n in ("mvt", "gesummv"):
                kw["retry_override"] = 1   # manager-agnostic trace for UVM
            svm = simulate(make_workload(n, int(CAP * 1.09)), CAP,
                           profile=False)
            uvm = simulate(make_workload(n, int(CAP * 1.09), **kw), CAP,
                           profile=False, manager_cls=UVMManager)
            return svm, uvm

        (svm, uvm), us = _timed(work)
        art[name] = {"svm": svm.summary, "uvm": uvm.summary}
        derived = (f"svm_wall={svm.wall_s:.2f}s_uvm_wall={uvm.wall_s:.2f}s"
                   f"_migs={svm.summary['migrations']}v"
                   f"{uvm.summary['migrations']}")
        rows.append((f"table1_svm_vs_uvm_{name}", us, derived))
    _art("table1_svm_vs_uvm", art)
    return rows


# ------------------------------------------------- beyond-paper §4.2 drivers

def beyond_driver():
    """Measured §4.2 design alternatives on the worst thrashers."""
    rows = []
    art = {}
    variants = {
        "baseline_lrf": {},
        "parallel_evict": {"parallel_evict": True},
        "clock_policy": {"policy": "clock"},
        "lru_policy": {"policy": "lru"},
        "previct": {"previct_watermark": 0.1},
        "defer_granularity": {"defer_granule": 2 * MB, "defer_k": 3},
    }
    for name in ("sgemm", "gesummv", "jacobi2d"):
        def work(n=name):
            out = {}
            for label, kw in variants.items():
                res = simulate(make_workload(n, int(CAP * 1.25)), CAP,
                               profile=False, **kw)
                out[label] = {"wall_s": res.wall_s,
                              "migs": res.summary["migrations"],
                              "evict_to_mig": res.summary["evict_to_mig"]}
            # zero-copy placement for the largest allocation
            wl = make_workload(n, int(CAP * 1.25))
            space_probe = AddressSpace(CAP, base=175 * MB)
            wl.build(space_probe)
            biggest = max(space_probe.allocations, key=lambda a: a.size)
            res = simulate(make_workload(n, int(CAP * 1.25)), CAP,
                           profile=False,
                           zero_copy_alloc_names=(biggest.name,))
            out["zero_copy_biggest"] = {
                "wall_s": res.wall_s, "migs": res.summary["migrations"],
                "evict_to_mig": res.summary["evict_to_mig"]}
            return out

        out, us = _timed(work)
        art[name] = out
        base = out["baseline_lrf"]["wall_s"]
        best = min(out.items(), key=lambda kv: kv[1]["wall_s"])
        derived = f"best={best[0]}_speedup={base/best[1]['wall_s']:.2f}x"
        rows.append((f"beyond_driver_{name}", us, derived))
    _art("beyond_driver_variants", art)
    return rows


ALL = (fig2_ranges, fig5_cost, fig6_dos, fig7_profiles, fig8_9_density,
       fig10_thrashing, fig11_13_svm_aware, table1_svm_vs_uvm,
       beyond_driver)
