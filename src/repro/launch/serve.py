"""Serving launcher: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 16 --decode 16

With ``--svm-budget-frac`` the decode loop additionally rides the SVM
weight-streaming runtime: the model's parameter leaves are planned into
managed ranges against a device pool of the given fraction of total param
bytes, and the whole decode's layer-fetch trace replays through the
compiled-session engine in one fused pass (`StreamingExecutor.
decode_steps` — the per-token segment records and compiles once, then all
N tokens execute as a single concatenated mega-trace; prefetch mode falls
back to per-token `decode_step` replays), reporting the simulated
streaming wall clock, migration/eviction traffic, and session cache stats
next to the real tok/s.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --svm-budget-frac 0.6 --svm-mode svm_aware

With ``--requests N`` (N > 1) the report switches to the **multi-tenant
scheduler** (`repro.svm.scheduler`): N decode requests of this model, a
seeded synthetic arrival process (``--arrival`` = mean interarrival
seconds on the simulated clock; 0 = all at once), contending for one
shared SVM pool under ``--sched-policy fifo|admission|svm_aware`` —
per-request latency percentiles, aggregate tok/s, and eviction pressure
ride along the real decode's tok/s.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --svm-budget-frac 0.6 --requests 8 --sched-policy svm_aware
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import SyntheticLM, modality_stub
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


class WeightStream:
    """SVM weight-streaming accounting riding along a real decode loop.

    Each parameter leaf is one fetch group, visited in model order once
    per token; per-leaf decode FLOPs are estimated as 2 · batch · params.
    All manager driving goes through the executor's `TraceSession` — the
    per-token trace compiles once and replays as cached segments."""

    def __init__(self, params, batch: int, *, budget_frac: float,
                 policy: str, mode: str):
        from repro.svm import StreamingExecutor

        paths, nbytes, nparams = [], [], []
        for path, leaf in StreamingExecutor._leaves(params):
            paths.append(path)
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            nparams.append(n)
            nbytes.append(n * leaf.dtype.itemsize)
        total = sum(nbytes)
        budget = max(int(total * budget_frac), 1)

        kw: dict = {}
        if mode == "svm_aware":
            # pin the embedding-ish hottest leaf (only if it leaves room
            # for streaming the rest — a pinned-full pool deadlocks every
            # later migration) and prefetch the rest
            hot = int(np.argmax(nbytes))
            kw = {"prefetch": True}
            if nbytes[hot] <= budget // 2:
                kw["pin"] = (paths[hot],)
        elif mode == "measured":
            # docs/prefetching.md: profile the first token's touch
            # columns and pin only leaves above the touch-frequency
            # threshold — the measured alternative to svm_aware's
            # hand-picked pin + aggressive prefetch
            kw = {"prefetch_mode": "measured"}
        elif mode == "zero_copy":
            # paper §4.2 hybrid placement: coldest (largest) leaves stay
            # host-resident at remote-access cost, up to half the weights
            order = sorted(range(len(paths)), key=lambda i: -nbytes[i])
            zc, acc = [], 0
            for i in order:
                if acc + nbytes[i] > total // 2:
                    continue     # too big for the budget; smaller may fit
                zc.append(paths[i])
                acc += nbytes[i]
            kw = {"zero_copy": tuple(zc)}

        self.executor = StreamingExecutor(
            params, budget, policy=policy, profile=False, **kw)
        self.layer_paths = [[p] for p in paths]
        self.flops = [2.0 * batch * n for n in nparams]
        self.total_bytes = total
        self.budget = budget

    def step(self) -> None:
        self.executor.decode_step(self.layer_paths, self.flops,
                                  materialize=False)

    def steps(self, n: int) -> None:
        """Fused multi-token accounting: all ``n`` decode steps replay as
        one concatenated segment in a single batched engine pass
        (`decode_steps`; prefetch mode falls back to the per-token
        loop)."""
        self.executor.decode_steps(self.layer_paths, self.flops, n,
                                   materialize=False)

    def report(self, decoded: int) -> str:
        m = self.executor.metrics()
        return (
            f"svm stream: DOS {m['dos']:.0f}% "
            f"(pool {self.budget / 1e6:.1f}MB / "
            f"weights {self.total_bytes / 1e6:.1f}MB), "
            f"simulated decode wall {m['wall_s'] * 1e3:.2f}ms, "
            f"{m['migrations']} migs / {m['evictions']} evicts "
            f"(e2m {m['evict_to_mig']:.2f}), "
            f"session: {m['segment_cache_misses']} compiled / "
            f"{m['segment_cache_hits']} cached replays over "
            f"{decoded} tokens")


def decode_tokens(cfg, serve_step, params, tok, cache, ctx, steps: int):
    """Greedy-decode ``steps`` tokens through a (jitted) serve step.

    Encoder-decoder configs re-encode their modality context and thread
    it through every step; VLMs thread the precomputed image context.
    Decoder-only configs (``ctx`` is None) take the two-argument path.
    Returns (decoded token list, final cache)."""
    outs = []
    for _ in range(steps):
        if ctx is not None and (cfg.is_encdec or cfg.is_vlm):
            from repro.models import encode
            c = encode(params, cfg, ctx) if cfg.is_encdec else ctx
            tok, cache = serve_step(params, tok, cache, c)
        else:
            tok, cache = serve_step(params, tok, cache)
        outs.append(tok)
    return outs, cache


def _chaos_line(r: dict) -> str:
    """One-line chaos/recovery summary (empty without an injector)."""
    ch = r.get("chaos")
    if not ch or "injector" not in ch:
        return ""
    return (
        f"\n  chaos[{ch['injector']['plan']} seed "
        f"{ch['injector']['seed']}]: "
        f"{ch['injector']['events_applied']}/"
        f"{ch['injector']['events_total']} events, "
        f"{ch['migration_faults']} migration faults / "
        f"{ch['retries']} retries ({ch['retry_exhausted']} exhausted), "
        f"{ch['crashes']} crashes, {ch['preemptions']} preemptions, "
        f"{ch['resumes']} resumes, {ch['degraded_rounds']} degraded "
        f"rounds, {r['n_failed']} failed, "
        f"backoff {ch['backoff_wall_s'] * 1e3:.2f}ms")


def schedule_report(r: dict) -> str:
    """Three-line human summary of a `run_schedule` result dict (plus a
    chaos/recovery line when a fault plan was injected)."""
    sc = r["shared_cache"]
    return (
        f"svm sched[{r['policy']}]: {r['n_requests']} reqs, "
        f"offered DOS {r['dos_offered']:.0f}% "
        f"(peak admitted {r['dos_peak']:.0f}%), "
        f"p50/p90/p99 latency "
        f"{r['latency_p50_s'] * 1e3:.1f}/{r['latency_p90_s'] * 1e3:.1f}/"
        f"{r['latency_p99_s'] * 1e3:.1f}ms, "
        f"agg {r['agg_tok_s']:.0f} tok/s\n"
        f"  {r['migrations']} migs / {r['evictions']} evicts "
        f"(e2m {r['evict_to_mig']:.2f}, "
        f"{r['evictions_per_token']:.2f} ev/tok), "
        f"segment hit rate {r['segment_hit_rate'] * 100:.1f}% "
        f"({r['segment_shared_hits']} cross-request replays)\n"
        f"  shared cache: {sc['shared_segments']} segments, "
        f"{sc['shared_lookup_hits']} hits / "
        f"{sc['shared_lookup_misses']} misses, "
        f"{sc['shared_relocations']} relocations, "
        f"{sc['shared_concats']} round concats "
        f"({'fused' if r.get('fused') else 'per-token'} replay)"
        + _chaos_line(r))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--svm-budget-frac", type=float, default=0.0,
                    help="enable SVM weight-streaming accounting with a "
                         "device pool of this fraction of the param bytes")
    ap.add_argument("--svm-policy", default="lrf",
                    choices=["lrf", "lru", "clock", "random"])
    ap.add_argument("--svm-mode", default="naive",
                    choices=["naive", "svm_aware", "measured",
                             "zero_copy"])
    ap.add_argument("--requests", type=int, default=1,
                    help="multi-tenant: N concurrent decode requests of "
                         "this model over one shared SVM pool (needs "
                         "--svm-budget-frac)")
    ap.add_argument("--arrival", type=float, default=0.0,
                    help="mean interarrival seconds (simulated Poisson "
                         "process; 0 = all requests arrive at once)")
    ap.add_argument("--sched-policy", default="svm_aware",
                    choices=["fifo", "admission", "svm_aware"])
    ap.add_argument("--admit-by", default="bytes",
                    choices=["bytes", "measured"],
                    help="what the admission watermark caps: total plan "
                         "bytes, or the measured resident working set "
                         "estimated from the spec's own touch columns "
                         "(docs/prefetching.md)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject the default seeded fault plan into the "
                         "multi-tenant schedule (capacity loss, slow "
                         "pages, migration faults, a crash) and report "
                         "the recovery accounting")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the default fault plan")
    ap.add_argument("--chaos-intensity", type=float, default=1.0,
                    help="scales the number of injected migration faults")
    ap.add_argument("--thrash-watermark", type=float, default=None,
                    help="evictions-per-token watermark for the runtime "
                         "thrash guard (preempt + tighten admission); "
                         "unset = guard off")
    args = ap.parse_args()
    if args.requests > 1 and args.svm_budget_frac <= 0.0:
        ap.error("--requests > 1 needs --svm-budget-frac > 0 "
                 "(the shared pool is sized from it)")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    params = init_params(cfg, jax.random.PRNGKey(0))

    stream = None
    if args.svm_budget_frac > 0.0:
        stream = WeightStream(params, args.batch,
                              budget_frac=args.svm_budget_frac,
                              policy=args.svm_policy, mode=args.svm_mode)

    data = SyntheticLM(vocab=cfg.vocab, seed=1)
    prompts = jnp.asarray(
        data.batch(0, 0, args.batch, args.prompt_len)["tokens"])
    ctx = None
    if cfg.is_vlm:
        ctx = jnp.asarray(modality_stub("image", args.batch,
                                        cfg.image_tokens, cfg.d_model),
                          jnp.bfloat16)
    elif cfg.is_encdec:
        ctx = jnp.asarray(modality_stub("frames", args.batch,
                                        cfg.encoder_frames, cfg.d_model),
                          jnp.bfloat16)

    prefill_jit = jax.jit(make_prefill_step(cfg))
    serve_jit = jax.jit(make_serve_step(cfg))

    with mesh:
        t0 = time.time()
        if ctx is not None:
            logits, cache = prefill_jit(params, prompts, ctx)
        else:
            logits, cache = prefill_jit(params, prompts)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_pre = time.time() - t0
        t0 = time.time()
        decoded, cache = decode_tokens(cfg, serve_jit, params, tok, cache,
                                       ctx, args.decode)
        outs = [tok] + decoded
        t_dec = time.time() - t0
        # the streaming accounting is a pure function of the token count:
        # replay it outside the timed loop so tok/s stays the real number
        if stream is not None:
            stream.steps(args.decode)

    seq = jnp.concatenate(outs, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_pre*1e3:.1f}ms; "
          f"decoded {args.decode} tokens in {t_dec*1e3:.1f}ms "
          f"({args.batch*args.decode/max(t_dec,1e-9):.1f} tok/s)")
    if stream is not None:
        print(stream.report(args.decode))
    if args.requests > 1:
        # multi-tenant accounting: N requests of this model contending
        # for one shared pool (pure simulation — rides the same clock
        # as the single-stream report above)
        from repro.svm import FaultPlan, ModelSpec, run_schedule
        spec = ModelSpec.from_params(args.arch, params, batch=args.batch)
        pool = max(int(spec.total_bytes * args.svm_budget_frac), 1)
        plan = None
        if args.chaos:
            plan = FaultPlan.default(args.chaos_seed,
                                     n_requests=args.requests,
                                     tokens=args.decode,
                                     intensity=args.chaos_intensity)
        sched = run_schedule(
            [spec], args.requests, pool, policy=args.sched_policy,
            admit_by=args.admit_by,
            seed=0, mean_interarrival_s=args.arrival,
            tokens=args.decode, evict_policy=args.svm_policy,
            fault_plan=plan, thrash_watermark=args.thrash_watermark)
        print(schedule_report(sched))
    print("first request continuation:", seq[0].tolist())


if __name__ == "__main__":
    main()
