"""Hot-set estimation and the measured prefetch/admission consumers.

Covers: `HotSetProfile` estimator correctness against a brute-force
per-op replay on small traces, the reuse-interval math and hot-set
queries, congruent-tenant profile sharing through `ProfileCache`,
`StreamingExecutor(prefetch_mode="measured")` (scalar ≡ batched ≡ fused
byte-identity, determinism, eviction reduction vs naive),
`simulate(measured_pin=...)` engine identity on the hot-set adversaries,
and `PoolScheduler(admit_by="measured")` — the conservation contract,
the co-admission win on the dense+MoE gate mix, and tier identity."""

import numpy as np
import pytest

from repro.core import MB
from repro.core.sweep import SweepPoint, run_point
from repro.svm import (
    HotSetProfile,
    ModelSpec,
    ProfileCache,
    StreamingExecutor,
    run_schedule,
    spec_profile,
    token_trace,
)
from repro.svm.planner import plan_leaf_ranges


def brute_force_profile(rid_seq, size_arr):
    """Per-op reference: frequencies and reuse intervals by replaying the
    touch sequence one op at a time."""
    freq: dict = {}
    last_pos: dict = {}
    gaps: dict = {}
    for i, rid in enumerate(rid_seq):
        rid = int(rid)
        freq[rid] = freq.get(rid, 0) + 1
        if rid in last_pos:
            between = sum(int(size_arr[int(r)])
                          for r in rid_seq[last_pos[rid] + 1:i])
            gaps.setdefault(rid, []).append(between)
        last_pos[rid] = i
    return freq, gaps


# ------------------------------------------------------------- estimator

def test_profile_matches_brute_force():
    rng = np.random.default_rng(3)
    size_arr = rng.integers(1, 100, size=16).astype(np.int64)
    rid_seq = rng.integers(0, 16, size=200).astype(np.int64)
    prof = HotSetProfile.from_touches(rid_seq, size_arr)
    freq, gaps = brute_force_profile(rid_seq, size_arr)
    assert prof.n_touches == 200
    for i, rid in enumerate(prof.rids.tolist()):
        assert prof.freq[i] == freq[rid]
        assert prof.sizes[i] == size_arr[rid]
        if rid in gaps:
            assert prof.reuse_min[i] == min(gaps[rid])
            assert prof.reuse_mean[i] == pytest.approx(
                sum(gaps[rid]) / len(gaps[rid]))
        else:
            assert np.isinf(prof.reuse_min[i])
            assert np.isinf(prof.reuse_mean[i])
    all_gaps = [g for gs in gaps.values() for g in gs]
    assert int(prof.reuse_hist.sum()) == len(all_gaps)
    assert prof.touched_bytes == int(
        size_arr[np.unique(rid_seq)].sum())


def test_profile_empty_and_single():
    size_arr = np.array([10, 20], dtype=np.int64)
    empty = HotSetProfile.from_touches(np.zeros(0, dtype=np.int64),
                                       size_arr)
    assert empty.n_touches == 0 and len(empty.rids) == 0
    assert empty.hot_bytes(1 << 30) == 0
    assert empty.resident_bytes(1 << 30) == 0
    one = HotSetProfile.from_touches(np.array([1]), size_arr)
    # a once-touched rid never demonstrates reuse: cold at any pressure,
    # but it still needs its streaming buffer
    assert one.hot_bytes(1 << 30) == 0
    assert one.resident_bytes(1 << 30) == 20


def test_hot_set_queries():
    # rid 0 re-touches with 50 bytes in between, rid 1 with 40, rid 2
    # with 30; rid 3 is touched once (infinite reuse interval)
    rid_seq = np.array([0, 1, 2, 0, 1, 2, 0, 3])
    sizes = np.array([10, 20, 30, 40, 50], dtype=np.int64)
    p = HotSetProfile.from_touches(rid_seq, sizes)
    assert p.freq.tolist() == [3, 2, 2, 1]
    assert p.reuse_mean[:3].tolist() == [50.0, 40.0, 30.0]
    # window 40: rids 1 and 2 are hot (20 + 30 bytes); the largest cold
    # range (rid 3, 40 bytes) is the streaming buffer
    assert p.hot_mask(40).tolist() == [False, True, True, False]
    assert p.hot_bytes(40) == 50
    assert p.resident_bytes(40) == 90
    # selection: frequency-descending prefix under the byte budget
    assert p.select_hot_rids(40, 100).tolist() == [1, 2]
    assert p.select_hot_rids(40, 25).tolist() == [1]
    assert p.select_hot_rids(40, 5).tolist() == []


def test_profile_relative_rids_congruent():
    """Profiles are relative to rid_base: congruent layouts at different
    offsets produce identical profiles."""
    size_arr = np.concatenate([np.arange(1, 9), np.arange(1, 9)]
                              ).astype(np.int64)
    seq = np.array([0, 1, 2, 0, 1, 2], dtype=np.int64)
    p0 = HotSetProfile.from_touches(seq, size_arr, rid_base=0)
    p8 = HotSetProfile.from_touches(seq + 8, size_arr, rid_base=8)
    assert p0.rids.tolist() == p8.rids.tolist()
    assert p0.freq.tolist() == p8.freq.tolist()
    assert p0.sizes.tolist() == p8.sizes.tolist()
    assert p0.reuse_mean.tolist() == p8.reuse_mean.tolist()


def test_profile_arrays_frozen():
    p = HotSetProfile.from_touches(np.array([0, 1, 0]),
                                   np.array([4, 8], dtype=np.int64))
    with pytest.raises(ValueError):
        p.freq[0] = 99


def test_token_trace_profiles_fetch_schedule():
    spec = ModelSpec.synthetic("t", 4, 1 * MB, embed_bytes=2 * MB)
    plan = plan_leaf_ranges(list(spec.leaves), spec.total_bytes)
    ct = token_trace(plan.leaf_ranges, spec.layer_paths, tokens=2)
    per_token = sum(len(plan.leaf_ranges[p])
                    for paths in spec.layer_paths for p in paths)
    assert len(ct.touch_rid_np) == 2 * per_token
    # touch_columns is the exported read-only view the profiler uses
    pos, rid = ct.touch_columns()
    assert rid is ct.touch_rid_np and pos is ct.touch_pos_np
    counts = ct.touch_counts(minlength=len(plan.space.ranges))
    assert int(counts.sum()) == len(rid)


def test_spec_profile_shared_via_cache():
    spec = ModelSpec.synthetic("archA", 4, 1 * MB, embed_bytes=2 * MB)
    cache = ProfileCache()
    p1 = spec_profile(spec, cache=cache)
    p2 = spec_profile(spec, cache=cache)
    assert p1 is p2
    assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1}
    # embed is touched first and last per token: highest frequency
    order = np.argsort(-p1.freq, kind="stable")
    embed_rids = plan_leaf_ranges(
        list(spec.leaves), spec.total_bytes).leaf_ranges["archA/embed"]
    assert int(p1.rids[order[0]]) in [r for r in embed_rids]


def test_moe_spec_untouched_experts_cost_nothing():
    """The measured resident estimate of a sparse MoE spec excludes the
    never-routed experts — the whole point of measuring."""
    moe = ModelSpec.synthetic_moe("moe", 4, 1 * MB, n_experts=8,
                                  active_experts=1, embed_bytes=2 * MB)
    prof = spec_profile(moe)
    touched = prof.touched_bytes
    # embed + per layer (dense + 1 routed expert)
    assert touched == (2 + 4 * 2) * MB
    assert prof.resident_bytes(moe.total_bytes) <= touched + 1 * MB
    assert moe.total_bytes == (2 + 4 * 9) * MB


# ------------------------------------------- measured executor prefetch

def _exec_params(n_layers=6, leaf_kb=256, embed_kb=512):
    p = {"embed": np.ones(embed_kb * 256, np.float32)}
    for i in range(n_layers):
        p[f"layer{i}"] = np.ones(leaf_kb * 256, np.float32)
    return p


_LAYER_PATHS = ([["embed"]] + [[f"layer{i}"] for i in range(6)]
                + [["embed"]])
_FLOPS = [1e9] * len(_LAYER_PATHS)


def _run_measured(mode="measured", scalar=False, steps=6, fused=False):
    ex = StreamingExecutor(_exec_params(), hbm_budget=1 << 20,
                           prefetch_mode=mode, scalar=scalar)
    if fused:
        ex.decode_steps(_LAYER_PATHS, _FLOPS, steps, materialize=False)
    else:
        for _ in range(steps):
            ex.decode_step(_LAYER_PATHS, _FLOPS, materialize=False)
    return ex


def test_measured_mode_pins_hot_leaf():
    ex = _run_measured()
    # embed is touched twice per token — above the threshold; the equal
    # layers are touched once and stay demand-paged
    assert ex.measured_hot_leaves == ("embed",)
    assert ex.measured_hot_bytes == 512 * 1024
    m = ex.metrics()
    assert m["prefetch_mode"] == "measured"
    assert m["measured_hot_bytes"] == 512 * 1024
    naive = _run_measured(mode="none").metrics()
    assert m["evictions"] < naive["evictions"]


def test_measured_mode_scalar_batched_fused_identical():
    mb = _run_measured(scalar=False).metrics()
    ms = _run_measured(scalar=True).metrics()
    mf = _run_measured(fused=True).metrics()
    for k in ("wall_s", "evictions", "migrations", "bytes_migrated",
              "bytes_evicted", "measured_hot_bytes"):
        assert mb[k] == ms[k] == mf[k], k


def test_measured_mode_deterministic():
    a = _run_measured().metrics()
    b = _run_measured().metrics()
    for k in ("wall_s", "evictions", "migrations", "bytes_migrated"):
        assert a[k] == b[k], k


def test_prefetch_mode_validation_and_bool_compat():
    with pytest.raises(ValueError, match="prefetch_mode"):
        StreamingExecutor(_exec_params(), 1 << 20, prefetch_mode="bogus")
    ex = StreamingExecutor(_exec_params(), 1 << 20, prefetch=True)
    assert ex.prefetch_mode == "aggressive" and ex.prefetch
    ex = StreamingExecutor(_exec_params(), 1 << 20)
    assert ex.prefetch_mode == "none" and not ex.prefetch


# ----------------------------------------------- measured_pin simulate

def test_measured_pin_sweep_axis_engine_identity():
    GB = 1 << 30
    kw = dict(wl_kwargs={"mode": "static", "ops": 2048, "seed": 0},
              measured_pin=0.5)
    rb = run_point(SweepPoint.make("hotset", 2 * GB, 1 * GB, **kw))
    rs = run_point(SweepPoint.make("hotset", 2 * GB, 1 * GB,
                                   engine="scalar", **kw))
    assert rb == rs
    r0 = run_point(SweepPoint.make(
        "hotset", 2 * GB, 1 * GB,
        wl_kwargs={"mode": "static", "ops": 2048, "seed": 0}))
    # pinning the measured hot set must reduce eviction churn on the
    # static adversary (the bench figure's headline)
    assert rb["evictions"] < r0["evictions"]


# ------------------------------------------------- measured admission

MOE_SPECS = [
    ModelSpec.synthetic("archA", 8, 3 * MB, embed_bytes=6 * MB),
    ModelSpec.synthetic_moe("moeB", 12, 1 * MB, n_experts=8,
                            expert_bytes=2 * MB, active_experts=1,
                            embed_bytes=4 * MB),
]
MOE_CAP = 100 * MB


def _run_admit(admit_by, **kw):
    return run_schedule(MOE_SPECS, 8, MOE_CAP, policy="svm_aware",
                        seed=7, tokens=8, spec_choice="roundrobin",
                        pin_frac=0.4, admit_by=admit_by, **kw)


def test_measured_admission_co_admits_more_tenants():
    by = _run_admit("bytes")
    me = _run_admit("measured")
    assert me["admit_by"] == "measured"
    assert me["peak_active_requests"] >= 2 * by["peak_active_requests"]
    # ...without thrashing harder: the gate's honesty condition
    assert me["evictions_per_token"] <= \
        by["evictions_per_token"] * 1.05 + 1e-9
    # congruent tenants shared profiles: 2 distinct specs, 8 requests
    assert me["profile_cache"]["entries"] == 2
    assert me["profile_cache"]["misses"] == 2


def test_measured_admission_conservation():
    r = _run_admit("measured")
    c, m = r["conservation"], r["mgr"]
    assert c["svm_wall_s"] == pytest.approx(m["wall_s"], abs=1e-9)
    assert c["migrations"] == m["migrations"]
    assert c["evictions"] == m["evictions"]
    assert c["bytes_migrated"] == m["bytes_migrated"]
    assert c["bytes_evicted"] == m["bytes_evicted"]


def test_measured_admission_tier_identity_and_determinism():
    runs = [_run_admit("measured"),
            _run_admit("measured"),
            _run_admit("measured", fused=False),
            _run_admit("measured", scalar=True)]
    for k in ("makespan_s", "evictions", "migrations", "agg_tok_s",
              "peak_active_requests", "total_tokens"):
        vals = {repr(r[k]) for r in runs}
        assert len(vals) == 1, (k, vals)


def test_admit_by_validation():
    with pytest.raises(ValueError, match="admit_by"):
        run_schedule(MOE_SPECS, 2, MOE_CAP, admit_by="bogus")


def test_measured_cost_capped_at_plan_bytes():
    """A dense spec whose whole working set is hot must not charge more
    than its plan bytes."""
    from repro.svm.scheduler import PoolScheduler
    sched = PoolScheduler(MOE_CAP, admit_by="measured")
    dense = MOE_SPECS[0]
    assert sched._admit_cost(dense) <= dense.total_bytes
    moe = MOE_SPECS[1]
    assert sched._admit_cost(moe) < moe.total_bytes // 4
