"""Paper-figure benchmarks: one function per table/figure of Cooper et al.
ICS'24. Each returns (name, us_per_call, derived) rows; artifacts (full
curves/profiles) are written to results/bench/*.json.

Sweep-shaped figures (6, 10, 11-13, beyond-paper variants) fan their
(workload × DOS × policy × variant) points out through
`repro.core.sweep.run_sweep`: ``JOBS`` worker processes and a
content-keyed on-disk cache (``CACHE_DIR``), so a rerun recomputes only
points invalidated by code changes.  `benchmarks/run.py` exposes both as
CLI flags.  Scheduling is grid-aware: points sharing a `trace_key` (same
workload spec + space geometry, different policy/variant/manager) land on
one worker and replay a single columnar-compiled trace.  Single-run
figures ride the compiled-trace engine via `simulate`'s default
``engine="batched"``."""

from __future__ import annotations

import json
import os
import time

from repro.core import (
    GB,
    MB,
    AddressSpace,
    SweepPoint,
    UVMManager,
    run_sweep,
    simulate,
)
from repro.core.costmodel import TERMS
from repro.core.traces import make_workload

CAP = 8 * GB
DOS_GRID = [50, 78, 95, 100, 109, 125, 140, 156]
ART_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")

# sweep execution knobs (overridden by benchmarks/run.py CLI flags)
JOBS: int | None = 0          # 0/1 serial, None = one worker per CPU
CACHE_DIR: str | None = os.path.join(ART_DIR, ".sweep_cache")


def _art(name: str, obj) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


_GRID_MEMO: dict = {}


def _grid_sweep(names, grid=DOS_GRID, *, wl_kwargs=(), mgr_kwargs=(),
                policy="lrf", zero_copy=(), manager="svm",
                normalize_at=78.0, stats=None):
    """Run a (workload × DOS) grid through the parallel sweep runner and
    return {workload: [row, ...]} with per-workload ``norm_perf``.

    Results are memoised in-process so figures sharing a grid (fig6/fig10)
    compute it once even with the disk cache disabled.  Per-workload
    anchor points (when ``normalize_at`` is not in the grid) ride in the
    same `run_sweep` batch as the main rows."""
    memo_key = (tuple(sorted(names)), tuple(grid),
                tuple(sorted(dict(wl_kwargs).items())),
                tuple(sorted(dict(mgr_kwargs).items())),
                policy, zero_copy, manager, normalize_at)
    if memo_key in _GRID_MEMO:
        if stats is not None:
            stats.update(cached=len(names) * len(grid), computed=0)
        return _GRID_MEMO[memo_key]

    def point(n, d):
        return SweepPoint.make(n, CAP * d / 100.0, CAP, policy=policy,
                               wl_kwargs=dict(wl_kwargs),
                               mgr_kwargs=dict(mgr_kwargs),
                               zero_copy=zero_copy, manager=manager)

    need_anchor = not any(abs(d - normalize_at) < 1e-9 for d in grid)
    points = [point(n, d) for n in names for d in grid]
    if need_anchor:
        points += [point(n, normalize_at) for n in names]
    rows = run_sweep(points, jobs=JOBS, cache_dir=CACHE_DIR, stats=stats)
    out = {}
    for i, n in enumerate(names):
        sub = rows[i * len(grid):(i + 1) * len(grid)]
        if need_anchor:
            base = rows[len(names) * len(grid) + i]["throughput"]
        else:
            base = next(r["throughput"] for d, r in zip(grid, sub)
                        if abs(d - normalize_at) < 1e-9)
        for r in sub:
            r["norm_perf"] = r["throughput"] / base
        out[n] = sub
    _GRID_MEMO[memo_key] = out
    return out


# ---------------------------------------------------------------- figure 2

def fig2_ranges():
    def work():
        space = AddressSpace(48 * GB, base=175 * MB)
        for i in range(3):
            space.alloc(int(1.5 * GB), f"m{i}")
        return space

    space, us = _timed(work)
    sizes = sorted(r.size for r in space.ranges)
    derived = (f"{len(space.ranges)}ranges_min{sizes[0]//MB}MB_"
               f"max{sizes[-1]//MB}MB")
    _art("fig2_ranges", [vars(r) for r in space.ranges])
    return [("fig2_range_construction", us, derived)]


# ---------------------------------------------------------------- figure 5

def fig5_cost():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm"):
        def work(n=name):
            out = {}
            for dos in DOS_GRID:
                res = simulate(make_workload(n, int(CAP * dos / 100)), CAP,
                               profile=False)
                out[dos] = res.summary["cost_breakdown"]
            return out

        curves, us = _timed(work)
        art[name] = curves
        big = curves[156]
        total = sum(big.values())
        derived = (f"alloc_share@156={big['alloc']/total:.2f}"
                   f"_total156={total:.2f}s")
        rows.append((f"fig5_cost_{name}", us, derived))
    _art("fig5_cost_breakdown", art)
    return rows


# ---------------------------------------------------------------- figure 6

def fig6_dos():
    names = ("stream", "conv2d", "jacobi2d", "bfs", "sgemm", "syr2k",
             "mvt", "gesummv")
    stats = {}
    sweeps, us = _timed(lambda: _grid_sweep(names, stats=stats))
    # the grid row carries the honest wall time + cache mix; per-workload
    # rows report us=0 (not individually measured) — their derived curve
    # anchors are the trajectory signal
    rows = [("fig6_grid", us,
             f"computed={stats['computed']}_cached={stats['cached']}"
             f"_tracegroups={stats.get('trace_groups', 0)}_jobs={JOBS}")]
    art = {}
    for name in names:
        curve = {round(r["dos"]): round(r["norm_perf"], 4)
                 for r in sweeps[name]}
        art[name] = curve
        derived = f"perf109={curve[109]:.3f}_perf156={curve[156]:.3f}"
        rows.append((f"fig6_dos_{name}", 0.0, derived))
    _art("fig6_dos_sweep", art)
    return rows


# ------------------------------------------------- figure 6 — variant axes

# §4.2 mitigation / design-point axes swept across the full DOS grid —
# every point executes on the batched tier (defer / previct / zero-copy /
# UVM all have fast-path interpreters since PR 2)
FIG6_VARIANTS = {
    "baseline": {},
    "defer": {"mgr_kwargs": {"defer_granule": 2 * MB, "defer_k": 3}},
    "previct": {"mgr_kwargs": {"previct_watermark": 0.1}},
    "zero_copy": {"zero_copy": "biggest"},
    "uvm": {"manager": "uvm"},
}


def fig6_variants():
    """Fig. 6 DOS sweep under each §4.2 driver variant and the UVM design
    point (Table 1), one (workload × DOS × variant) grid."""
    names = ("stream", "jacobi2d", "sgemm", "gesummv")
    art = {}
    rows = []
    total_stats = {"computed": 0, "cached": 0}

    def tally(stats):
        total_stats["computed"] += stats.get("computed", 0)
        total_stats["cached"] += stats.get("cached", 0)

    def work():
        out = {}
        for label, kw in FIG6_VARIANTS.items():
            wl_kw = dict(kw.get("wl_kwargs", ()))
            if kw.get("manager") == "uvm":
                # manager-agnostic trace for the wave workloads (Table 1);
                # run_sweep resets the stats dict per call, so tally each
                sweeps = {}
                for n in names:
                    stats = {}
                    sweeps[n] = _grid_sweep(
                        (n,), wl_kwargs=(wl_kw | {"retry_override": 1}
                                         if n in ("mvt", "gesummv")
                                         else wl_kw),
                        manager="uvm", stats=stats)[n]
                    tally(stats)
            else:
                stats = {}
                sweeps = _grid_sweep(
                    names, wl_kwargs=wl_kw,
                    mgr_kwargs=kw.get("mgr_kwargs", {}),
                    zero_copy=kw.get("zero_copy", ()), stats=stats)
                tally(stats)
            out[label] = sweeps
        return out

    sweeps, us = _timed(work)
    rows.append(("fig6_variants_grid", us,
                 f"computed={total_stats['computed']}"
                 f"_cached={total_stats['cached']}_jobs={JOBS}"))
    for name in names:
        art[name] = {
            label: {round(r["dos"]): round(r["norm_perf"], 4)
                    for r in sweeps[label][name]}
            for label in FIG6_VARIANTS
        }
        base109 = art[name]["baseline"][109]
        best = max(((lb, art[name][lb][109]) for lb in FIG6_VARIANTS
                    if lb != "baseline"), key=lambda kv: kv[1])
        rows.append((f"fig6_variants_{name}", 0.0,
                     f"base109={base109:.3f}_best109={best[0]}"
                     f"@{best[1]:.3f}"))
    _art("fig6_dos_variants", art)
    return rows


# ---------------------------------------------------------------- figure 7

def fig7_profiles():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm", "gesummv"):
        def work(n=name):
            return simulate(make_workload(n, int(CAP * 1.09)), CAP,
                            profile=True)

        res, us = _timed(work)
        ev = [(round(e.t, 4), e.kind, e.alloc_id) for e in res.manager.events]
        art[name] = ev[:20000]
        migs = sum(1 for e in res.manager.events if e.kind == "mig")
        evts = sum(1 for e in res.manager.events if e.kind == "evt")
        rows.append((f"fig7_profile_{name}", us, f"migs={migs}_evts={evts}"))
    _art("fig7_profiles_dos109", art)
    return rows


# ------------------------------------------------------------- figures 8/9

def fig8_9_density():
    rows = []
    art = {}
    for name in ("stream", "conv2d", "jacobi2d", "bfs", "sgemm", "syr2k",
                 "mvt", "gesummv"):
        def work(n=name):
            return simulate(make_workload(n, int(CAP * 1.09)), CAP)

        res, us = _timed(work)
        m = res.manager
        dens = [d.faults for d in m.density]
        art[name] = {
            "density_over_time": [(round(d.t, 4), d.faults)
                                  for d in m.density[:5000]],
            "mean": res.summary["mean_fault_density"],
            "serviceable_per_migration":
                res.summary["serviceable_per_migration"],
            "duplicate_share": res.summary["duplicate_share"],
        }
        derived = (f"mean={res.summary['mean_fault_density']:.0f}"
                   f"_svc/mig={res.summary['serviceable_per_migration']:.2f}")
        rows.append((f"fig8_density_{name}", us, derived))
    _art("fig8_9_fault_density", art)
    return rows


# --------------------------------------------------------------- figure 10

def fig10_thrashing():
    names = ("stream", "conv2d", "jacobi2d", "sgemm", "syr2k", "mvt",
             "gesummv", "bfs")
    # identical points to fig6 — with the content-keyed cache enabled this
    # is pure cache hits
    stats = {}
    sweeps, us = _timed(lambda: _grid_sweep(names, stats=stats))
    rows = [("fig10_grid", us,
             f"computed={stats['computed']}_cached={stats['cached']}"
             f"_jobs={JOBS}")]
    art = {}
    for name in names:
        art[name] = {round(r["dos"]): {"e2m": round(r["evict_to_mig"], 3),
                                       "migs": r["migrations"]}
                     for r in sweeps[name]}
        d = art[name]
        derived = (f"e2m156={d[156]['e2m']:.2f}"
                   f"_miggrowth={d[156]['migs']/max(d[78]['migs'],1):.1f}x")
        rows.append((f"fig10_thrash_{name}", 0.0, derived))
    _art("fig10_thrashing", art)
    return rows


# ---------------------------------------------------------- figures 11-13

def fig11_13_svm_aware():
    rows = []
    art = {}
    # extend past the measured grid: the paper notes SGEMM-svm-aware stays
    # viable to DOS ~ 300 while naive collapses (orders of magnitude)
    grid = DOS_GRID + [220, 280]
    labels = ("jacobi2d", "sgemm")
    # batched grid calls (not one per label×variant×point): all points of
    # a variant are in flight together.  Besides the paper's app-rewrite
    # comparison, sweep the naive kernels under the §4.2 driver
    # mitigations — does a driver-side fix approach the rewrite?
    (naives, awares, defers, previcts), us = _timed(lambda: (
        _grid_sweep(labels, grid),
        _grid_sweep(labels, grid, wl_kwargs={"svm_aware": True}),
        _grid_sweep(labels, grid,
                    mgr_kwargs={"defer_granule": 2 * MB, "defer_k": 3}),
        _grid_sweep(labels, grid, mgr_kwargs={"previct_watermark": 0.1})))
    rows.append(("fig11_13_grid", us, f"points={8 * len(grid)}_jobs={JOBS}"))
    for label in labels:
        nv = {round(r["dos"]): r["norm_perf"] for r in naives[label]}
        aw = {round(r["dos"]): r["norm_perf"] for r in awares[label]}
        df = {round(r["dos"]): r["norm_perf"] for r in defers[label]}
        pv = {round(r["dos"]): r["norm_perf"] for r in previcts[label]}
        art[label] = {"naive": nv, "aware": aw, "naive_defer": df,
                      "naive_previct": pv}
        best_mit = max(df[156], pv[156])
        derived = (f"speedup109={aw[109]/max(nv[109],1e-9):.2f}x"
                   f"_speedup156={aw[156]/max(nv[156],1e-9):.2f}x"
                   f"_speedup280={aw[280]/max(nv[280],1e-9):.0f}x"
                   f"_bestmit156={best_mit:.3f}")
        rows.append((f"fig11_13_svm_aware_{label}", 0.0, derived))
    _art("fig11_13_svm_aware", art)
    return rows


# ----------------------------------------------------------------- table 1

def table1_svm_vs_uvm():
    rows = []
    art = {}
    for name in ("stream", "jacobi2d", "sgemm", "gesummv"):
        def work(n=name):
            kw = {}
            if n in ("mvt", "gesummv"):
                kw["retry_override"] = 1   # manager-agnostic trace for UVM
            svm = simulate(make_workload(n, int(CAP * 1.09)), CAP,
                           profile=False)
            uvm = simulate(make_workload(n, int(CAP * 1.09), **kw), CAP,
                           profile=False, manager_cls=UVMManager)
            return svm, uvm

        (svm, uvm), us = _timed(work)
        art[name] = {"svm": svm.summary, "uvm": uvm.summary}
        derived = (f"svm_wall={svm.wall_s:.2f}s_uvm_wall={uvm.wall_s:.2f}s"
                   f"_migs={svm.summary['migrations']}v"
                   f"{uvm.summary['migrations']}")
        rows.append((f"table1_svm_vs_uvm_{name}", us, derived))
    _art("table1_svm_vs_uvm", art)
    return rows


# ------------------------------------------------- beyond-paper §4.2 drivers

def beyond_driver():
    """Measured §4.2 design alternatives on the worst thrashers — one flat
    (workload × variant) grid through the parallel sweep runner."""
    variants = {
        "baseline_lrf": {},
        "parallel_evict": {"mgr_kwargs": {"parallel_evict": True}},
        "clock_policy": {"policy": "clock"},
        "lru_policy": {"policy": "lru"},
        "previct": {"mgr_kwargs": {"previct_watermark": 0.1}},
        "defer_granularity": {"mgr_kwargs": {"defer_granule": 2 * MB,
                                             "defer_k": 3}},
        "zero_copy_biggest": {"zero_copy": "biggest"},
    }
    names = ("sgemm", "gesummv", "jacobi2d")

    stats = {}

    def work():
        points = [
            SweepPoint.make(n, CAP * 1.25, CAP,
                            policy=kw.get("policy", "lrf"),
                            mgr_kwargs=kw.get("mgr_kwargs", {}),
                            zero_copy=kw.get("zero_copy", ()))
            for n in names for kw in variants.values()
        ]
        return run_sweep(points, jobs=JOBS, cache_dir=CACHE_DIR,
                         stats=stats)

    flat, us = _timed(work)
    rows = [("beyond_driver_grid", us,
             f"computed={stats['computed']}_cached={stats['cached']}"
             f"_jobs={JOBS}")]
    art = {}
    for i, name in enumerate(names):
        out = {}
        for j, label in enumerate(variants):
            r = flat[i * len(variants) + j]
            out[label] = {"wall_s": r["wall_s"], "migs": r["migrations"],
                          "evict_to_mig": r["evict_to_mig"]}
        art[name] = out
        base = out["baseline_lrf"]["wall_s"]
        best = min(out.items(), key=lambda kv: kv[1]["wall_s"])
        derived = f"best={best[0]}_speedup={base/best[1]['wall_s']:.2f}x"
        rows.append((f"beyond_driver_{name}", 0.0, derived))
    _art("beyond_driver_variants", art)
    return rows


def fig_measured_prefetch():
    """Beyond-paper: measured prefetching vs the aggressive default on
    the hot-set adversaries (docs/prefetching.md).  For each PR-6
    adversary mode (static / dynamic / oscillating) the DOS sweep runs
    twice — the paper's aggressive demand-everything policy
    (``measured_pin=0``) and the measured policy that profiles the
    trace's own touch columns and pins the measured hot set up-front
    (``measured_pin=0.5``) — reproducing the thrashing cliff and showing
    the measured policy flattening it.  One flat (mode × DOS × policy)
    grid through the parallel sweep runner; the measured points share
    the aggressive points' compiled traces (`trace_key` excludes the
    pin axis).  Artifact: ``results/bench/fig_measured_prefetch.json``."""
    modes = ("static", "dynamic", "oscillating")
    pins = (("aggressive", 0.0), ("measured", 0.5))
    grid = [78, 109, 125, 156]
    stats = {}

    def work():
        points = [
            SweepPoint.make("hotset", CAP * d / 100.0, CAP,
                            wl_kwargs={"mode": m, "ops": 4096, "seed": 0},
                            measured_pin=mp)
            for m in modes for _, mp in pins for d in grid
        ]
        return run_sweep(points, jobs=JOBS, cache_dir=CACHE_DIR,
                         stats=stats)

    flat, us = _timed(work)
    rows = [("fig_measured_grid", us,
             f"computed={stats['computed']}_cached={stats['cached']}"
             f"_jobs={JOBS}")]
    art = {}
    i = 0
    for mode in modes:
        curves = {}
        for label, _ in pins:
            curves[label] = {
                d: {"throughput": flat[i + k]["throughput"],
                    "evictions": flat[i + k]["evictions"],
                    "e2m": round(flat[i + k]["evict_to_mig"], 3)}
                for k, d in enumerate(grid)
            }
            i += len(grid)
        art[mode] = curves
        agg, mea = curves["aggressive"], curves["measured"]
        cliff = mea[156]["throughput"] / max(agg[156]["throughput"], 1e-12)
        ev_drop = (agg[156]["evictions"] - mea[156]["evictions"]) \
            / max(agg[156]["evictions"], 1)
        rows.append((f"fig_measured_{mode}", 0.0,
                     f"cliff156={cliff:.2f}x_evdrop156={ev_drop:.2f}"))
    _art("fig_measured_prefetch", art)
    return rows


def serve_scheduler():
    """Multi-tenant serving scheduler (beyond-paper, §5 direction): tail
    latency vs offered load per scheduling policy over one shared SVM
    pool.  A heterogeneous two-architecture request mix (one arch fits
    the pool, one is individually oversubscribed) arrives as a seeded
    Poisson process at increasing rates; each (policy × load) cell is a
    full deterministic `run_schedule` simulation.  Artifact:
    ``results/bench/serve_scheduler.json``."""
    from repro.core import MB as _MB
    from repro.svm import ModelSpec, run_schedule

    specs = [ModelSpec.synthetic("archA", 12, 4 * _MB, embed_bytes=8 * _MB),
             ModelSpec.synthetic("archB", 24, 4 * _MB,
                                 embed_bytes=24 * _MB)]
    cap = 100 * _MB
    # mean interarrival (simulated seconds); 0 = saturating burst
    loads = [0.4, 0.2, 0.1, 0.05, 0.0]
    policies = ("fifo", "admission", "svm_aware")

    art = {p: [] for p in policies}
    rows = []
    for policy in policies:
        def work(policy=policy):
            out = []
            for ia in loads:
                r = run_schedule(specs, 12, cap, policy=policy, seed=11,
                                 mean_interarrival_s=ia, tokens=16,
                                 spec_choice="roundrobin", pin_frac=0.4)
                out.append({
                    "mean_interarrival_s": ia,
                    # null, not inf: the artifact must stay RFC-8259 JSON
                    "offered_req_s": (1.0 / ia) if ia else None,
                    "latency_p50_s": r["latency_p50_s"],
                    "latency_p99_s": r["latency_p99_s"],
                    "ttft_p99_s": r["ttft_p99_s"],
                    "agg_tok_s": r["agg_tok_s"],
                    "evictions_per_token": r["evictions_per_token"],
                    "evict_to_mig": r["evict_to_mig"],
                    "segment_hit_rate": r["segment_hit_rate"],
                    "segment_shared_hits": r["segment_shared_hits"],
                    "dos_peak": r["dos_peak"],
                })
            return out

        curve, us = _timed(work)
        art[policy] = curve
        burst = curve[-1]
        rows.append((f"serve_sched_{policy}", us,
                     f"p99_burst={burst['latency_p99_s'] * 1e3:.1f}ms"
                     f"_evtok={burst['evictions_per_token']:.2f}"
                     f"_hit={burst['segment_hit_rate']:.2f}"))
    _art("serve_scheduler", art)
    return rows


ALL = (fig2_ranges, fig5_cost, fig6_dos, fig6_variants, fig7_profiles,
       fig8_9_density, fig10_thrashing, fig11_13_svm_aware,
       table1_svm_vs_uvm, beyond_driver, fig_measured_prefetch,
       serve_scheduler)
