"""Model assembly: layer stacks (scan-over-periods), decoder-only LMs,
hybrid SSM/attention stacks, VLM cross-attention, and encoder-decoder.

Layers are grouped into *periods* (one repetition of `cfg.layer_pattern`);
periods are executed with `jax.lax.scan` over stacked parameters so HLO size
and compile time are independent of depth. Layers that do not fill a whole
period are unrolled at the end ("remainder"). KV/SSM caches follow the same
layout (leading n_periods axis), so prefill and decode also scan.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.config import ATTN, ATTN_LOCAL, CROSS, MAMBA, MLP, MOE, NONE, ModelConfig
from repro.models.layers import (
    dense_init,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rms_norm,
)

Array = jax.Array
PyTree = Any

# Optional GSPMD hints, set by the launch layer before lowering:
#   LOGITS_SPEC — PartitionSpec for (B,S,V) logits (vocab over 'model')
#   ACT_SPEC    — PartitionSpec for (B,S,D) residual activations; anchors
#                 batch sharding through the embedding gather and the
#                 period-scan boundaries (GSPMD propagation can drop it at
#                 gathers — observed as 17 GB replicated score tensors).
LOGITS_SPEC = None
ACT_SPEC = None

# Roofline instrumentation: XLA cost_analysis counts while-loop bodies once,
# so the dry-run's roofline tier unrolls the period stack (at reduced depth)
# to make HLO FLOP counts exact. Never enabled for real training.
UNROLL_PERIODS = False


def _period_slice(pparams: PyTree, i: int) -> PyTree:
    return jax.tree.map(lambda x: x[i], pparams)


def _anchor(x: Array) -> Array:
    if ACT_SPEC is not None and x.ndim == len(ACT_SPEC):
        return jax.lax.with_sharding_constraint(x, ACT_SPEC)
    return x


# ------------------------------------------------------------------- init

def _init_layer(key, cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": jnp.zeros((cfg.d_model,), jnp.bfloat16)}
    if mixer in (ATTN, ATTN_LOCAL):
        p["mixer"] = attn_lib.attn_init(k1, cfg)
    elif mixer == CROSS:
        p["mixer"] = attn_lib.attn_init(k1, cfg)
        p["gate"] = jnp.zeros((), jnp.bfloat16)   # gated cross (llama-vision)
    elif mixer == MAMBA:
        p["mixer"] = mamba_lib.mamba_init(k1, cfg)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn == MLP:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    elif ffn == MOE:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.bfloat16)
        p["ffn"] = moe_lib.moe_init(k2, cfg)
    elif ffn != NONE:
        raise ValueError(f"unknown ffn {ffn!r}")
    return p


def _init_period(key, cfg: ModelConfig) -> dict:
    pat, fpat = cfg.layer_pattern, cfg.ffn_pattern
    keys = jax.random.split(key, len(pat))
    return {
        f"l{j}": _init_layer(keys[j], cfg, pat[j], fpat[j % len(fpat)])
        for j in range(len(pat))
    }


def init_params(cfg: ModelConfig, key) -> PyTree:
    if len(cfg.layer_pattern) % len(cfg.ffn_pattern) != 0 \
            and len(cfg.ffn_pattern) % len(cfg.layer_pattern) != 0:
        raise ValueError("ffn_pattern must align with layer_pattern periods")
    k_embed, k_per, k_rem, k_head, k_enc = jax.random.split(key, 5)
    params: dict = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head,
                                       (cfg.d_model, cfg.padded_vocab))
    if cfg.n_periods > 0:
        pkeys = jax.random.split(k_per, cfg.n_periods)
        params["periods"] = jax.vmap(
            lambda k: _init_period(k, cfg))(pkeys)
    if cfg.n_remainder > 0:
        rkeys = jax.random.split(k_rem, cfg.n_remainder)
        base = cfg.n_periods * len(cfg.layer_pattern)
        params["remainder"] = {
            f"r{i}": _init_layer(
                rkeys[i], cfg,
                cfg.layer_pattern[(base + i) % len(cfg.layer_pattern)],
                cfg.ffn_pattern[(base + i) % len(cfg.ffn_pattern)])
            for i in range(cfg.n_remainder)
        }
    if cfg.is_encdec:
        ekeys = jax.random.split(k_enc, cfg.encoder_layers + 1)
        params["encoder"] = {
            f"e{i}": {
                "norm1": jnp.zeros((cfg.d_model,), jnp.bfloat16),
                "mixer": attn_lib.attn_init(ekeys[i], cfg),
                "norm2": jnp.zeros((cfg.d_model,), jnp.bfloat16),
                "ffn": mlp_init(jax.random.fold_in(ekeys[i], 1),
                                cfg.d_model, cfg.d_ff, cfg.mlp_gated),
            }
            for i in range(cfg.encoder_layers)
        }
        params["encoder"]["final_norm"] = jnp.zeros((cfg.d_model,),
                                                    jnp.bfloat16)
    return params


# ------------------------------------------------------------------ layers

def _theta_for(cfg: ModelConfig, mixer: str) -> float:
    if mixer == ATTN and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _apply_layer(
    lp: dict,
    cfg: ModelConfig,
    x: Array,
    mixer: str,
    ffn: str,
    *,
    positions: Array,
    ctx: Optional[Array],
    cache: Optional[dict],
    decode: bool,
) -> tuple[Array, Optional[dict], Array]:
    """One residual layer. Returns (x, cache_out, moe_aux)."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    cache_out: Optional[dict] = None
    if mixer in (ATTN, ATTN_LOCAL):
        window = cfg.sliding_window if mixer == ATTN_LOCAL else 0
        o, kv = attn_lib.self_attention(
            lp["mixer"], cfg, h, positions=positions, window=window,
            theta=_theta_for(cfg, mixer),
            cache=cache if decode else None)
        cache_out = kv
    elif mixer == CROSS:
        o = attn_lib.cross_attention(lp["mixer"], cfg, h, ctx)
        o = o * jnp.tanh(lp["gate"].astype(jnp.float32)).astype(o.dtype) \
            if "gate" in lp else o
        cache_out = {}
    elif mixer == MAMBA:
        if decode:
            o, cache_out = mamba_lib.mamba_decode_step(lp["mixer"], cfg, h,
                                                       cache)
        else:
            o = mamba_lib.mamba_forward(lp["mixer"], cfg, h)
            cache_out = None  # prefill state handled separately
    else:
        raise ValueError(mixer)
    x = x + o
    aux = jnp.zeros((), jnp.float32)
    if ffn in (MLP, MOE):
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if ffn == MLP:
            f = mlp_apply(lp["ffn"], h2, cfg.act)
        else:
            f, aux = moe_lib.moe_apply(lp["ffn"], cfg, h2)
        x = x + f
    return x, cache_out, aux


def _kind(cfg: ModelConfig, j: int) -> tuple[str, str]:
    return (cfg.layer_pattern[j % len(cfg.layer_pattern)],
            cfg.ffn_pattern[j % len(cfg.ffn_pattern)])


# --------------------------------------------------------------- forward

def forward(params: PyTree, cfg: ModelConfig, tokens: Array,
            ctx: Optional[Array] = None,
            return_hidden: bool = False) -> tuple[Array, Array]:
    """Teacher-forced full-sequence pass. Returns (logits, moe_aux_mean);
    with return_hidden=True returns the final normed hidden states instead
    of logits (the train loss folds the LM head into a chunked CE)."""
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    x = _anchor(x)
    # batch-free positions: masks stay (1,1,1,S,T), not per-batch-element
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def period_body(carry, pparams):
        xc, aux = carry
        for j, mixer in enumerate(cfg.layer_pattern):
            _, fkind = _kind(cfg, j)
            xc, _, a = _apply_layer(
                pparams[f"l{j}"], cfg, xc, mixer, fkind,
                positions=positions, ctx=ctx, cache=None, decode=False)
            aux = aux + a
        return (_anchor(xc), aux), None

    if cfg.remat == "full":
        period_body = jax.checkpoint(period_body, prevent_cse=False)

    aux = jnp.zeros((), jnp.float32)
    if cfg.n_periods > 0:
        if UNROLL_PERIODS:
            for i in range(cfg.n_periods):
                (x, aux), _ = period_body(
                    (x, aux), _period_slice(params["periods"], i))
        else:
            (x, aux), _ = jax.lax.scan(period_body, (x, aux),
                                       params["periods"])
    base = cfg.n_periods * len(cfg.layer_pattern)
    for i in range(cfg.n_remainder):
        mixer, fkind = _kind(cfg, base + i)
        x, _, a = _apply_layer(
            params["remainder"][f"r{i}"], cfg, x, mixer, fkind,
            positions=positions, ctx=ctx, cache=None, decode=False)
        aux = aux + a
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    n_moe = max(1, sum(1 for _, f in cfg.layer_kinds() if f == MOE))
    if return_hidden:
        return x, aux / n_moe
    logits = _lm_head(params, cfg, x)
    return logits, aux / n_moe


def _lm_head(params: PyTree, cfg: ModelConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    if LOGITS_SPEC is not None:
        logits = jax.lax.with_sharding_constraint(logits, LOGITS_SPEC)
    if cfg.padded_vocab != cfg.vocab:  # mask the padded vocab tail
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-2.0e38, logits.dtype),
                           logits)
    return logits


def encode(params: PyTree, cfg: ModelConfig, frames: Array) -> Array:
    """Encoder stack over precomputed modality-frontend frames (enc-dec)."""
    x = frames
    enc = params["encoder"]
    for i in range(cfg.encoder_layers):
        lp = enc[f"e{i}"]
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn_lib.encoder_self_attention(lp["mixer"], cfg, h)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_apply(lp["ffn"], h2, cfg.act)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ----------------------------------------------------------------- caches

def _layer_cache(cfg: ModelConfig, mixer: str, B: int, S: int) -> dict:
    hd = cfg.resolved_head_dim
    if mixer in (ATTN, ATTN_LOCAL):
        W = S if (mixer == ATTN or not cfg.sliding_window) \
            else min(cfg.sliding_window, S)
        return {
            "k": jnp.zeros((B, cfg.n_kv_heads, W, hd), jnp.bfloat16),
            "v": jnp.zeros((B, cfg.n_kv_heads, W, hd), jnp.bfloat16),
            "pos": jnp.full((B, W), -1, jnp.int32),
        }
    if mixer == MAMBA:
        return mamba_lib.mamba_init_cache(cfg, B)
    if mixer == CROSS:
        return {}
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, B: int, S: int) -> PyTree:
    """Decode cache sized for a context of S tokens."""
    cache: dict = {"t": jnp.zeros((B,), jnp.int32)}
    if cfg.n_periods > 0:
        def one_period(_):
            return {f"l{j}": _layer_cache(cfg, cfg.layer_pattern[j], B, S)
                    for j in range(len(cfg.layer_pattern))}
        cache["periods"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(
                leaf, (cfg.n_periods,) + leaf.shape).copy(),
            one_period(None))
    base = cfg.n_periods * len(cfg.layer_pattern)
    if cfg.n_remainder > 0:
        cache["remainder"] = {
            f"r{i}": _layer_cache(
                cfg, cfg.layer_pattern[(base + i) % len(cfg.layer_pattern)],
                B, S)
            for i in range(cfg.n_remainder)
        }
    return cache


# ---------------------------------------------------------------- prefill

def _kv_to_buffer(kv: dict, W: int) -> dict:
    """Convert full-sequence K/V (B,S,KV,hd) into the rolling decode buffer
    layout (B,KV,W,hd) + per-slot absolute positions."""
    k, v, pos = kv["k"], kv["v"], kv["pos"]
    B, S, KV, hd = k.shape
    take = min(W, S)
    ks = jnp.swapaxes(k[:, S - take:], 1, 2)                  # (B,KV,take,hd)
    vs = jnp.swapaxes(v[:, S - take:], 1, 2)
    ptail = pos[:, S - take:]                                 # (B,take)
    slots = (jnp.arange(S - take, S, dtype=jnp.int32) % W)    # (take,)
    bk = jnp.zeros((B, KV, W, hd), ks.dtype).at[:, :, slots].set(ks)
    bv = jnp.zeros((B, KV, W, hd), vs.dtype).at[:, :, slots].set(vs)
    bpos = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(ptail)
    return {"k": bk, "v": bv, "pos": bpos}


def prefill(params: PyTree, cfg: ModelConfig, tokens: Array,
            ctx: Optional[Array] = None, cache_len: int | None = None
            ) -> tuple[Array, PyTree]:
    """Process a prompt, returning (logits, decode cache)."""
    B, S = tokens.shape
    CL = cache_len or S
    x = embed_apply(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    x = _anchor(x)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def run_layer(lp, xc, mixer, fkind):
        h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
        if mixer in (ATTN, ATTN_LOCAL):
            window = cfg.sliding_window if mixer == ATTN_LOCAL else 0
            o, kv = attn_lib.self_attention(
                lp["mixer"], cfg, h, positions=positions, window=window,
                theta=_theta_for(cfg, mixer))
            W = CL if (mixer == ATTN or not cfg.sliding_window) \
                else min(cfg.sliding_window, CL)
            c_out = _kv_to_buffer(kv, W)
        elif mixer == CROSS:
            o = attn_lib.cross_attention(lp["mixer"], cfg, h, ctx)
            o = o * jnp.tanh(lp["gate"].astype(jnp.float32)).astype(o.dtype)
            c_out = {}
        elif mixer == MAMBA:
            o, c_out = mamba_lib.mamba_forward(lp["mixer"], cfg, h,
                                               return_state=True)
        else:
            raise ValueError(mixer)
        xc = xc + o
        if fkind in (MLP, MOE):
            h2 = rms_norm(xc, lp["norm2"], cfg.norm_eps)
            f = (mlp_apply(lp["ffn"], h2, cfg.act) if fkind == MLP
                 else moe_lib.moe_apply(lp["ffn"], cfg, h2)[0])
            xc = xc + f
        return xc, c_out

    cache: dict = {"t": jnp.full((B,), S, jnp.int32)}

    def period_body(xc, pparams):
        outs = {}
        for j, mixer in enumerate(cfg.layer_pattern):
            _, fkind = _kind(cfg, j)
            xc, outs[f"l{j}"] = run_layer(pparams[f"l{j}"], xc, mixer, fkind)
        return xc, outs

    if cfg.n_periods > 0:
        if UNROLL_PERIODS:
            outs = []
            for i in range(cfg.n_periods):
                x, o = period_body(x, _period_slice(params["periods"], i))
                outs.append(o)
            cache["periods"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x, cache["periods"] = jax.lax.scan(period_body, x,
                                               params["periods"])
    base = cfg.n_periods * len(cfg.layer_pattern)
    if cfg.n_remainder > 0:
        cache["remainder"] = {}
        for i in range(cfg.n_remainder):
            mixer, fkind = _kind(cfg, base + i)
            x, c_out = run_layer(params["remainder"][f"r{i}"], x, mixer,
                                 fkind)
            cache["remainder"][f"r{i}"] = c_out
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, cfg, x)
    return logits, cache


# ----------------------------------------------------------------- decode

def decode_step(params: PyTree, cfg: ModelConfig, token: Array,
                cache: PyTree, ctx: Optional[Array] = None
                ) -> tuple[Array, PyTree]:
    """One greedy decode step. token: (B, 1) int32."""
    B = token.shape[0]
    x = embed_apply(params["embed"], token, cfg.embed_scale, cfg.d_model)
    positions = cache["t"][:, None]                            # (B,1)
    new_cache: dict = {"t": cache["t"] + 1}

    def period_body(xc, scanned):
        pparams, pcache = scanned
        outs = {}
        for j, mixer in enumerate(cfg.layer_pattern):
            _, fkind = _kind(cfg, j)
            xc, c_out, _ = _apply_layer(
                pparams[f"l{j}"], cfg, xc, mixer, fkind,
                positions=positions, ctx=ctx,
                cache=pcache[f"l{j}"], decode=True)
            outs[f"l{j}"] = c_out if c_out is not None else pcache[f"l{j}"]
        return xc, outs

    if cfg.n_periods > 0:
        if UNROLL_PERIODS:
            outs = []
            for i in range(cfg.n_periods):
                x, o = period_body(
                    x, (_period_slice(params["periods"], i),
                        _period_slice(cache["periods"], i)))
                outs.append(o)
            new_cache["periods"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_cache["periods"] = jax.lax.scan(
                period_body, x, (params["periods"], cache["periods"]))
    base = cfg.n_periods * len(cfg.layer_pattern)
    if cfg.n_remainder > 0:
        new_cache["remainder"] = {}
        for i in range(cfg.n_remainder):
            mixer, fkind = _kind(cfg, base + i)
            x, c_out, _ = _apply_layer(
                params["remainder"][f"r{i}"], cfg, x, mixer, fkind,
                positions=positions, ctx=ctx,
                cache=cache["remainder"][f"r{i}"], decode=True)
            new_cache["remainder"][f"r{i}"] = (
                c_out if c_out is not None else cache["remainder"][f"r{i}"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, cfg, x)
    return logits, new_cache
